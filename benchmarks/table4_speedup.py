"""Table IV analogue: end-to-end inference speedup of the fused BFP path
over the dequantize-materialize baseline.

The paper compares Arm-NEON CPU execution of BFP models against its FPGA
accelerator (1.17/1.51/1.53x; avg 1.4x). The TPU analogue compares, per
paper model, decode-phase roofline step time with:

  baseline  -- XLA dequantize-then-matmul dataflow: HBM moves the packed
               weights AND the materialized bf16 weights (write + read)
  f-bfq     -- fused Pallas kernel dataflow: HBM moves packed weights only

both at the paper's serving shape (batch 1, short prompt). Decode is
memory-bound, so the ratio of weight-traffic bytes is the speedup. We also
report *measured CPU wall-clock* of both XLA paths (fp32 materialized vs
bf16 fused-cast) on a small matmul slice for a ground-truth direction.
"""
import jax
import jax.numpy as jnp

from repro.configs.base import get_arch
from repro.core import policy as POL
from repro.core.quantize import quantize, dequantize
from benchmarks.common import emit, time_jitted
from benchmarks.shapes import model_matmuls

PAPER_TOKS = {  # model: (paper CPU tok/s, paper FBFQ tok/s, paper speedup)
    "gpt2-paper": (8.31, 12.18, 1.17),
    "tinyllama-1.1b": (0.69, 1.44, 1.51),   # paper lists MobileLLaMA here
    "mobilellama-1.4b": (0.86, 1.82, 1.53),
}

HBM_BW = 819e9


def weight_traffic(cfg, polname):
    pol = POL.get_policy(polname)
    packed = 0.0
    bf16 = 0.0
    for path, K, N in model_matmuls(cfg, include_embedding=False):
        v = pol.variant_for(path, K, N)
        bits = 16 if v is None else POL.F.get_format(v).bits_per_weight
        packed += K * N * bits / 8.0
        bf16 += K * N * 2.0
    return packed, bf16


def run() -> None:
    for arch, (cpu_tps, fbfq_tps, paper_sp) in PAPER_TOKS.items():
        cfg = get_arch(arch)
        polname = ("paper_gpt2_mix" if arch == "gpt2-paper"
                   else "paper_llama_mix")
        packed, bf16 = weight_traffic(cfg, polname)
        # decode step weight traffic (batch small: weights dominate)
        t_fused = packed / HBM_BW
        t_baseline = (packed + 2 * bf16) / HBM_BW   # write + read bf16
        speedup = t_baseline / t_fused
        tok_s_fused = 1.0 / t_fused
        tok_s_base = 1.0 / t_baseline
        emit(f"table4_{arch}", t_fused * 1e6,
             f"v5e_decode_tok/s base={tok_s_base:.0f} fbfq={tok_s_fused:.0f} "
             f"speedup={speedup:.2f}x "
             f"(paper: {cpu_tps}->{fbfq_tps} = {paper_sp}x)")

    # measured CPU wall-clock direction check on one layer-sized matmul
    K, N, M = 2048, 8192, 8
    w = jax.random.normal(jax.random.PRNGKey(0), (K, N)) * 0.1
    t = quantize("q3_k", w)
    x = jax.random.normal(jax.random.PRNGKey(1), (M, K))

    @jax.jit
    def baseline(x, t):
        wf = dequantize(t, dtype=jnp.float32)    # materialize fp32
        return x @ wf

    @jax.jit
    def fused(x, t):
        wb = dequantize(t, dtype=jnp.bfloat16)   # fused-cast dataflow
        return (x.astype(jnp.bfloat16) @ wb).astype(jnp.float32)

    tb = time_jitted(baseline, x, t)
    tf = time_jitted(fused, x, t)
    emit("table4_cpu_wallclock_matmul", tf * 1e6,
         f"baseline_us={tb*1e6:.0f} fused_us={tf*1e6:.0f} "
         f"speedup={tb/tf:.2f}x (CPU direction check)")


if __name__ == "__main__":
    run()
