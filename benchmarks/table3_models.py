"""Table III reproduction: per-model Q2_K/Q3_K MatMul layer counts,
parameter counts and quantized model sizes vs the paper's numbers."""
from repro.configs.base import get_arch
from repro.core import policy as POL
from benchmarks.common import emit
from benchmarks.shapes import model_matmuls

PAPER = {  # arch: (q2 layers, q3 layers, params, size MB)
    "gpt2-paper": (25, 24, 163e6, 77),
    "tinyllama-1.1b": (45, 110, 1.1e9, 460),
    "mobilellama-1.4b": (49, 120, 1.4e9, 560),
}


def run() -> None:
    for arch, (q2, q3, nparams, size_mb) in PAPER.items():
        cfg = get_arch(arch)
        pol = POL.get_policy("paper_gpt2_mix" if arch == "gpt2-paper"
                             else "paper_llama_mix")
        mms = model_matmuls(cfg)
        summ = POL.summarize(pol, mms)
        emb = [("wte", cfg.d_model, cfg.vocab_size)]
        extra = ([("wpe", cfg.max_position * cfg.d_model)]
                 if cfg.pos_emb == "learned" else [])
        summ_sz = POL.summarize(pol, mms + emb, extra_f16=extra)
        got_mb = summ_sz["size_bytes_gguf"] / 1e6
        got_mb_ours = summ_sz["size_bytes"] / 1e6
        total_params = sum(summ_sz["params"].values()) + sum(
            n for _, n in extra)
        emit(f"table3_{arch}", 0.0,
             f"q2_layers={summ['counts'].get('q2_k', 0)}/{q2} "
             f"q3_layers={summ['counts'].get('q3_k', 0)}/{q3} "
             f"params={total_params/1e6:.0f}M/{nparams/1e6:.0f}M "
             f"size={got_mb:.0f}MB/{size_mb}MB(paper) "
             f"size_soa={got_mb_ours:.0f}MB")


if __name__ == "__main__":
    run()
