"""Shared benchmark utilities: timing + CSV/JSON emission."""
import json
import os
import time
from typing import Callable, Optional

import jax


def time_jitted(fn: Callable, *args, iters: int = 10, warmup: int = 2):
    """Median wall time of a jitted callable (seconds)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def emit_json(payload: dict, path: Optional[str] = None) -> None:
    """Print a machine-readable result blob (and optionally persist it) so
    successive PRs can diff the perf trajectory."""
    text = json.dumps(payload, indent=2, sort_keys=True)
    print(text)
    if path:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            f.write(text + "\n")
