"""Shared benchmark utilities: timing + CSV emission."""
import time
from typing import Callable

import jax


def time_jitted(fn: Callable, *args, iters: int = 10, warmup: int = 2):
    """Median wall time of a jitted callable (seconds)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}")
