"""Kernel microbenchmark: fused BFP matmul roofline terms per
(variant x shape), plus interpret-mode correctness spot check and measured
CPU wall time of the XLA dataflow.

``--smoke`` runs just one interpret-mode shape (CI compile-only gate)."""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import get_format
from repro.core.quantize import quantize
from repro.distributed.sharding import lane_shard_qtensor
from repro.kernels import ops, ref
from repro.kernels.bfp_matmul import bfp_matmul_pallas
from benchmarks.common import emit, time_jitted

PEAK = 197e12
HBM = 819e9

SHAPES = [
    ("decode", 8, 2048, 8192),
    ("prefill", 2048, 2048, 8192),
    ("train_fwd", 8192, 8192, 29568),
]


def smoke() -> None:
    """One kernel shape through the interpret-mode Pallas path; asserts
    against the oracle. Cheap enough for a CPU-only CI job."""
    M, K, N = 16, 512, 128
    x = jax.random.normal(jax.random.PRNGKey(0), (M, K))
    w = jax.random.normal(jax.random.PRNGKey(1), (K, N)) * 0.1
    t = quantize("q3_k", w)
    o_ref = np.asarray(ref.matmul_ref(x, t))
    o_pal = np.asarray(bfp_matmul_pallas(
        x, t, interpret=True, compute_dtype=jnp.float32,
        out_dtype=jnp.float32, block_m=16, block_n=128, block_k=256))
    err = np.abs(o_pal - o_ref).max() / (np.abs(o_ref).max() + 1e-9)
    assert err < 1e-5, err
    emit("kernel_smoke_q3_k", 0.0, f"pallas_vs_ref_rel_err={err:.2e}")

    # fused sliced-TP gemm: each lane shard's packed payload goes
    # straight through the fused dequant-matmul and must reproduce the
    # matching columns of the full-matrix run BIT-exactly (packing runs
    # along K, so lane slicing never crosses a quantization group --
    # this is the invariant the sliced serving datapath rides on)
    shards = 2
    worst = 0.0
    for i in range(shards):
        tl = lane_shard_qtensor(t, i, shards)
        o_sh = np.asarray(bfp_matmul_pallas(
            x, tl, interpret=True, compute_dtype=jnp.float32,
            out_dtype=jnp.float32, block_m=16, block_n=64, block_k=256))
        n = N // shards
        worst = max(worst, np.abs(o_sh - o_pal[:, i*n:(i+1)*n]).max())
    assert worst == 0.0, worst
    emit("kernel_smoke_sliced_q3_k", 0.0,
         f"shard_vs_full_maxabs={worst:.1e} shards={shards}")


def run() -> None:
    for v in ("q2_k", "q3_k", "q4_k", "q6_k"):
        fmt = get_format(v)
        for name, M, K, N in SHAPES:
            flops = 2 * M * K * N
            w_bytes = fmt.nbytes(K, N)
            io = M * K * 2 + M * N * 4
            t_c = flops / PEAK
            t_m = (w_bytes + io) / HBM
            t_m_bf16 = (K * N * 2 + io) / HBM
            bound = "compute" if t_c > t_m else "memory"
            emit(f"kernel_{v}_{name}", max(t_c, t_m) * 1e6,
                 f"v5e_{bound}-bound mem_vs_bf16={t_m_bf16/t_m:.2f}x "
                 f"ai={flops/(w_bytes+io):.0f}")

    # correctness spot check (interpret kernel vs oracle) + CPU wall time
    M, K, N = 16, 1024, 512
    x = jax.random.normal(jax.random.PRNGKey(0), (M, K))
    w = jax.random.normal(jax.random.PRNGKey(1), (K, N)) * 0.1
    for v in ("q2_k", "q3_k"):
        t = quantize(v, w)
        o_ref = np.asarray(ref.matmul_ref(x, t))
        o_pal = np.asarray(bfp_matmul_pallas(
            x, t, interpret=True, compute_dtype=jnp.float32,
            out_dtype=jnp.float32, block_m=16, block_n=128, block_k=256))
        err = np.abs(o_pal - o_ref).max() / (np.abs(o_ref).max() + 1e-9)
        f = jax.jit(lambda xx, tt: ops.bfp_matmul(xx, tt, impl="xla"))
        wall = time_jitted(f, x, t)
        emit(f"kernel_validate_{v}", wall * 1e6,
             f"pallas_vs_ref_rel_err={err:.2e}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    if ap.parse_args().smoke:
        smoke()
    else:
        run()
