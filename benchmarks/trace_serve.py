"""Trace-driven serving bench: latency under load, not a depth sweep.

The e2e bench submits its whole queue up front and drains it -- that
measures throughput at fixed depth, but the ROADMAP north star ("heavy
traffic") is a latency-under-load curve: requests ARRIVE over time, and
the contested metrics are tail TTFT, tail inter-token latency, and
goodput under an SLO. This bench drives the engine with Poisson arrival
traces over two workload mixes and reports exactly those:

* ``chat``: a shared-system-prompt population (60% of requests share a
  24-token system prefix) with short unique suffixes, prefix cache ON --
  the workload the paged KV cache exists for.
* ``mixed``: no shared prefix, broader prompt/output length spread,
  prefix cache OFF -- the cold-path curve.

Arrivals are injected mid-cycle through ``Engine.run(poll=...)``: the
poll hook submits every trace entry whose timestamp has come due, so
requests land between decode chunks exactly as a front-end would inject
them. Per-request we record the arrival-stamped submit wall time, every
token's wall time, and the run()-entry wall time of the cycle that
served the first token -- which lets each row report BOTH the fixed TTFT
(first token - arrival) and the old run-entry-stamped value
(``ttft_runentry_*``). At matched load the fixed value is <= the old one
for every request (run entry always precedes a mid-cycle arrival); the
bench asserts that per request, and ``check_trace`` gates it
structurally, pinning the arrival-time accounting bugfix.

Goodput counts a request iff it completed its full token budget AND met
the TTFT SLO; ``saturation_rps`` per mix is the highest swept offered
rate whose goodput fraction stays above the floor.

Output mirrors e2e_serve: human CSV rows plus one JSON blob;
``--smoke`` runs the reduced sweep CI gates with ``check_trace``
(scripts/check_bench_regression.py) against the committed baseline at
benchmarks/results/trace_serve.json.
"""
import argparse
import collections
import time

import jax
import numpy as np

from repro.configs.base import get_arch
from repro.core.policy import get_policy
from repro.core.qlinear import quantize_params
from repro.models import transformer as T
from repro.serving.engine import Engine, ServeConfig
from benchmarks.common import emit, emit_json

MIXES = {
    # shared-system-prompt population: the prefix-cache serving workload
    "chat": dict(shared_frac=0.6, shared_len=24, unique_lo=4,
                 unique_hi=12, out_lo=6, out_hi=14, prefix_cache=True),
    # no sharing, broader length spread: the cold-path curve
    "mixed": dict(shared_frac=0.0, shared_len=0, unique_lo=4,
                  unique_hi=28, out_lo=4, out_hi=16, prefix_cache=False),
}
RATES = (8.0, 32.0, 128.0)       # offered req/s per mix (sweep)
SMOKE_RATES = (8.0, 32.0)        # CI subset (same keys as the baseline)
N_REQUESTS = 48
SMOKE_REQUESTS = 20
SLO_TTFT_S = 0.5                 # TTFT SLO goodput is conditioned on
GOODPUT_FLOOR = 0.9              # goodput_frac >= this => rate "met"
MAX_SLOTS = 8
DECODE_CHUNK = 4                 # short chunks: honest inter-token tails
SEED = 0


def _gen_trace(cfg, mix: str, rate: float, n: int, seed: int):
    """(arrival_s, prompt, out_budget) triples; Poisson arrivals at
    ``rate`` req/s, lengths drawn from the mix. Deterministic per
    (mix, rate, n, seed) so baseline and CI runs replay the same trace."""
    rng = np.random.default_rng(seed)
    m = MIXES[mix]
    shared = ([int(t) for t in rng.integers(0, cfg.vocab_size,
                                            m["shared_len"])]
              if m["shared_len"] else [])
    t, trace = 0.0, []
    for _ in range(n):
        t += float(rng.exponential(1.0 / rate))
        plen = int(rng.integers(m["unique_lo"], m["unique_hi"] + 1))
        head = shared if rng.random() < m["shared_frac"] else []
        prompt = head + [int(x) for x in
                         rng.integers(0, cfg.vocab_size, plen)]
        out = int(rng.integers(m["out_lo"], m["out_hi"] + 1))
        trace.append((t, prompt, out))
    return trace


def _drive(eng: Engine, trace):
    """Replay ``trace`` against a live engine; returns per-request
    records. Arrivals are injected from run(poll=...) so they land
    between decode chunks; when the engine idles ahead of the next
    arrival we sleep the gap out and re-enter run()."""
    state = {}
    pending = collections.deque(trace)
    run_entry = [None]          # wall stamp of the current run() cycle
    t0 = time.perf_counter()

    def on_token(rid, tok):
        st = state[rid]
        if not st["tok_t"]:
            st["run_entry"] = run_entry[0]
        st["tok_t"].append(time.perf_counter())

    def on_done(req):
        state[req.id]["req"] = req

    def poll():
        now = time.perf_counter() - t0
        while pending and pending[0][0] <= now:
            at, prompt, out = pending.popleft()
            rid = eng.submit(list(prompt), max_new_tokens=out,
                             on_token=on_token, on_done=on_done)
            state[rid] = dict(arrival=at, submit=time.perf_counter(),
                              tok_t=[], run_entry=None, req=None,
                              budget=out)

    while pending or eng._queue:
        now = time.perf_counter() - t0
        if not eng._queue and pending and pending[0][0] > now:
            time.sleep(pending[0][0] - now)
        # the cycle stamp is taken BEFORE run() (and poll() only runs
        # inside it), so run_entry <= submit for every request this cycle
        # serves -- which is why fixed TTFT <= run-entry TTFT per request
        run_entry[0] = time.perf_counter()
        eng.run(poll=poll)
    wall = time.perf_counter() - t0
    return state, wall


def _pct(xs, q):
    return float(np.percentile(xs, q)) if xs else 0.0


def _metrics(state, wall: float, slo_ttft_s: float) -> dict:
    ttfts, old_ttfts, itls, waits = [], [], [], []
    completed = good = 0
    for st in state.values():
        if not st["tok_t"]:
            continue
        ttft = st["tok_t"][0] - st["submit"]
        old = st["tok_t"][0] - st["run_entry"]
        # the arrival-time accounting contract: the fixed stamp can only
        # shrink TTFT relative to the old run()-entry stamp
        assert ttft <= old + 1e-6, (ttft, old)
        ttfts.append(ttft)
        old_ttfts.append(old)
        itls += [b - a for a, b in zip(st["tok_t"], st["tok_t"][1:])]
        req = st["req"]
        if req is not None and req.queue_wait_s is not None:
            waits.append(req.queue_wait_s)
        done_ok = (req is not None and not req.cancelled
                   and len(req.tokens) == st["budget"])
        completed += done_ok
        good += done_ok and ttft <= slo_ttft_s
    n = len(state)
    return dict(
        requests=n, completed=completed, wall_s=round(wall, 4),
        ttft_mean_s=round(float(np.mean(ttfts)), 5) if ttfts else 0.0,
        ttft_p50_s=round(_pct(ttfts, 50), 5),
        ttft_p99_s=round(_pct(ttfts, 99), 5),
        ttft_runentry_p50_s=round(_pct(old_ttfts, 50), 5),
        ttft_runentry_p99_s=round(_pct(old_ttfts, 99), 5),
        itl_p50_s=round(_pct(itls, 50), 6),
        itl_p99_s=round(_pct(itls, 99), 6),
        queue_wait_p99_s=round(_pct(waits, 99), 5),
        slo_ttft_s=slo_ttft_s,
        goodput_frac=round(good / n, 4) if n else 0.0,
        goodput_rps=round(good / wall, 2) if wall > 0 else 0.0,
    )


def _mix_engine(cfg, params, mix: str) -> Engine:
    # prefill_batch=1: one prefill dispatch per admission, so the compile
    # surface is fixed (length buckets only). Grouped admission compiles
    # one program PER GROUP SIZE, and under Poisson arrivals the measured
    # run hits group sizes warmup never saw -- multi-second compiles in
    # the middle of a latency measurement.
    m = MIXES[mix]
    return Engine(cfg, params, ServeConfig(
        max_new_tokens=m["out_hi"], max_slots=MAX_SLOTS,
        decode_chunk=DECODE_CHUNK, cache_len=64, prefill_bucket=16,
        prefill_batch=1, prefix_cache=m["prefix_cache"],
        prefix_page=8))


def _warm(eng: Engine, trace) -> None:
    """Compile the shapes the measured run will hit: one batch drain
    (largest prefill groups + decode chunk) and one one-at-a-time pass
    (size-1 groups per length bucket, the common mid-cycle arrival
    shape). Also pre-populates the chat mix's radix tree, so measured
    runs serve a warm shared-prefix population."""
    prompts = [p for _, p, _ in trace]
    eng.generate(prompts)
    for p in prompts:
        eng.generate([p])


def run(out_path: str = None, smoke: bool = False) -> dict:
    cfg = get_arch("tinyllama-1.1b", reduced=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    qp, _ = quantize_params(params, get_policy("paper_llama_mix"))
    rates = SMOKE_RATES if smoke else RATES
    n = SMOKE_REQUESTS if smoke else N_REQUESTS

    results = dict(
        benchmark="trace_serve",
        arch="tinyllama-1.1b(reduced)",
        workload=dict(mixes={k: {kk: vv for kk, vv in v.items()}
                             for k, v in MIXES.items()},
                      rates_rps=list(rates), requests_per_rate=n,
                      slo_ttft_s=SLO_TTFT_S,
                      goodput_floor=GOODPUT_FLOOR,
                      max_slots=MAX_SLOTS, decode_chunk=DECODE_CHUNK,
                      seed=SEED, smoke=smoke),
        runs=[], summary={},
    )
    for mix in MIXES:
        eng = _mix_engine(cfg, qp, mix)
        _warm(eng, _gen_trace(cfg, mix, max(rates), n, SEED))
        mix_rows = []
        for rate in rates:
            trace = _gen_trace(cfg, mix, rate, n, SEED)
            state, wall = _drive(eng, trace)
            row = dict(mix=mix, rate_rps=rate,
                       params="fbfq_mixed_q2q3", **_metrics(
                           state, wall, SLO_TTFT_S))
            results["runs"].append(row)
            mix_rows.append(row)
            emit(f"trace_serve_{mix}_r{rate:g}",
                 row["ttft_p99_s"] * 1e6,
                 f"ttft_p50={row['ttft_p50_s']} "
                 f"ttft_p99={row['ttft_p99_s']} "
                 f"itl_p99={row['itl_p99_s']} "
                 f"goodput={row['goodput_frac']} "
                 f"({row['goodput_rps']} rps good)")
        met = [r["rate_rps"] for r in mix_rows
               if r["goodput_frac"] >= GOODPUT_FLOOR]
        results["summary"][mix] = dict(
            saturation_rps=max(met) if met else 0.0,
            rates_met=met, rates_swept=list(rates))
        emit(f"trace_serve_{mix}_saturation",
             results["summary"][mix]["saturation_rps"],
             f"rates_met={met} of {list(rates)} "
             f"(goodput_floor={GOODPUT_FLOOR})")
    emit_json(results, out_path)
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None,
                    help="where to persist the JSON blob ('' to skip; "
                         "default: the committed baseline path for the "
                         "full sweep, nowhere for --smoke so a partial "
                         "sweep can never clobber the baseline)")
    ap.add_argument("--smoke", action="store_true",
                    help="quick sweep (CI check_trace gate): rates "
                         f"{SMOKE_RATES} x {SMOKE_REQUESTS} requests "
                         "per mix")
    args = ap.parse_args()
    out = args.out
    if out is None:
        out = "" if args.smoke else "benchmarks/results/trace_serve.json"
    run(out or None, smoke=args.smoke)
