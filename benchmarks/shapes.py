"""Per-arch MatMul tensor inventories (path, K, N) for size/distribution
accounting (Fig. 1 / Table III)."""
from repro.configs.base import ModelConfig


def model_matmuls(cfg: ModelConfig, include_embedding: bool = False):
    d, L = cfg.d_model, cfg.n_layers
    out = []
    if cfg.family == "gpt2":
        f = cfg.d_ff
        for _ in range(L):
            out += [("layers/attn/c_attn", d, 3 * d),
                    ("layers/attn/c_proj", d, d),
                    ("layers/mlp/c_fc", d, f),
                    ("layers/mlp/c_proj", f, d)]
    else:
        H, KH, Dh, f = cfg.n_heads, cfg.n_kv_heads, cfg.d_head, cfg.d_ff
        for _ in range(L):
            out += [("layers/attn/wq", d, H * Dh),
                    ("layers/attn/wk", d, KH * Dh),
                    ("layers/attn/wv", d, KH * Dh),
                    ("layers/attn/wo", H * Dh, d),
                    ("layers/mlp/w_gate", d, f),
                    ("layers/mlp/w_up", d, f),
                    ("layers/mlp/w_down", f, d)]
    out.append(("lm_head", d, cfg.vocab_size))
    if include_embedding:
        out.append(("wte", d, cfg.vocab_size))
    return out
