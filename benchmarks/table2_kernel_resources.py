"""Table II analogue: the paper reports FPGA resource utilization (BRAM/
DSP/FF/LUT). The TPU kernel's equivalents are VMEM tile footprint, grid
occupancy, and arithmetic intensity per variant."""
import jax.numpy as jnp

from repro.core.formats import get_format, WEIGHT_VARIANTS
from repro.kernels.bfp_matmul import vmem_bytes
from benchmarks.common import emit

BM, BN, BK = 128, 256, 512
VMEM_LIMIT = 16 * 2**20          # v5e per-core VMEM


def run() -> None:
    for v in WEIGHT_VARIANTS:
        fmt = get_format(v)
        b = vmem_bytes(v, BM, BN, BK)
        # arithmetic intensity of the fused kernel: flops per HBM byte
        flops = 2 * BM * BN * BK
        hbm = (b["x_tile"] + b["w_packed_tile"]
               + BM * BN * 4 / (1))           # out written once per tile
        emit(f"table2_kernel_{v}", 0.0,
             f"vmem_tile={b['total']/2**10:.0f}KiB "
             f"({100*b['total']/VMEM_LIMIT:.1f}% of VMEM) "
             f"packed_w={b['w_packed_tile']/2**10:.0f}KiB "
             f"bits/w={fmt.bits_per_weight} "
             f"arith_intensity={flops/hbm:.0f}flops/B")


if __name__ == "__main__":
    run()
