"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Roofline terms for the full
(arch x shape) grid come from the dry-run artifacts (launch/dryrun.py);
benches here are self-contained CPU-runnable reproductions.
"""
import sys
import traceback


def main() -> None:
    from benchmarks import (fig1_distribution, table2_kernel_resources,
                            table3_models, table4_speedup,
                            kernel_microbench, e2e_serve)
    print("name,us_per_call,derived")
    failed = 0
    for mod in (fig1_distribution, table2_kernel_resources, table3_models,
                table4_speedup, kernel_microbench, e2e_serve):
        try:
            mod.run()
        except Exception:
            failed += 1
            print(f"{mod.__name__},ERROR,", file=sys.stderr)
            traceback.print_exc()
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
