"""Fig. 1 reproduction: per-model weight-quantization distribution.

The paper's Figure 1 shows what fraction of each LLM's weights sit in each
BFP variant under llama.cpp's mixed quantization. We reproduce it from our
policy presets over the actual tensor shapes of the three paper models."""
from repro.configs.base import get_arch
from repro.core import policy as POL
from benchmarks.common import emit
from benchmarks.shapes import model_matmuls


def run() -> None:
    for arch, polname in [("gpt2-paper", "paper_gpt2_mix"),
                          ("tinyllama-1.1b", "paper_llama_mix"),
                          ("mobilellama-1.4b", "paper_llama_mix")]:
        cfg = get_arch(arch)
        mms = model_matmuls(cfg, include_embedding=True)
        pol = POL.get_policy(polname)
        summ = POL.summarize(pol, mms)
        total = sum(summ["params"].values())
        dist = {k: 100.0 * v / total for k, v in summ["params"].items()}
        derived = " ".join(f"{k}={v:.1f}%" for k, v in sorted(dist.items()))
        emit(f"fig1_distribution_{arch}", 0.0, derived)


if __name__ == "__main__":
    run()
