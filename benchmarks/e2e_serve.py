"""End-to-end serving bench (paper's llama-cli experiment, reduced scale):
quantize a TinyLlama-family reduced model with the paper's mixed policy,
serve the paper's workload shape (6-token prompt, 10 new tokens), report
measured tok/s on CPU for the quantized vs unquantized model."""
import jax
import numpy as np

from repro.configs.base import get_arch
from repro.core.policy import get_policy
from repro.core.qlinear import quantize_params
from repro.models import transformer as T
from repro.serving.engine import Engine, ServeConfig
from benchmarks.common import emit


def run() -> None:
    cfg = get_arch("tinyllama-1.1b", reduced=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    qp, _ = quantize_params(params, get_policy("paper_llama_mix"))
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(0, cfg.vocab_size, 6)) for _ in range(4)]

    for tag, p in [("fp32", params), ("fbfq_mixed_q2q3", qp)]:
        eng = Engine(cfg, p, ServeConfig(max_new_tokens=10))
        eng.generate(prompts)          # warmup + compile
        outs = eng.generate(prompts)
        s = eng.stats
        emit(f"e2e_serve_{tag}", s["decode_s"] / max(s["tokens"], 1) * 1e6,
             f"tok/s={s['tok_per_s']:.1f} prefill_s={s['prefill_s']:.3f} "
             f"(paper workload: 6-tok prompt, 10 new tokens)")


if __name__ == "__main__":
    run()
