"""End-to-end serving bench (paper's llama-cli experiment, reduced scale).

Quantizes a TinyLlama-family reduced model with the paper's mixed policy
and drives the continuous-batching engine at queue depths 1 / 4 / 8 / 32
over the paper's workload shape (6-token prompt, 10 new tokens).  Reports
decode tok/s, prefill/decode wall time, and -- the quantity the on-device
decode loop exists to minimize -- host syncs per request.

Output: human CSV rows (``emit``) plus one machine-readable JSON blob
(``--out`` to persist, default benchmarks/results/e2e_serve.json when run
as a script) so future PRs can track the perf trajectory.
"""
import argparse

import jax
import numpy as np

from repro.configs.base import get_arch
from repro.core.policy import get_policy
from repro.core.qlinear import quantize_params
from repro.models import transformer as T
from repro.serving.engine import Engine, ServeConfig
from benchmarks.common import emit, emit_json

PROMPT_LEN = 6            # paper workload
NEW_TOKENS = 10
QUEUE_DEPTHS = (1, 4, 8, 32)     # 4 = the seed benchmark's batch shape
MAX_SLOTS = 8


def _bench_one(cfg, params, depth: int) -> dict:
    slots = min(depth, MAX_SLOTS)
    eng = Engine(cfg, params, ServeConfig(
        max_new_tokens=NEW_TOKENS, max_slots=slots,
        decode_chunk=NEW_TOKENS, cache_len=32, prefill_bucket=8))
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(0, cfg.vocab_size, PROMPT_LEN))
               for _ in range(depth)]
    for _ in range(2):                         # compile + cache-donation warm
        eng.generate(prompts)
    stats = []
    for _ in range(3):
        outs = eng.generate(prompts)
        assert all(len(o) == NEW_TOKENS for o in outs)
        stats.append(dict(eng.stats))
    s = sorted(stats, key=lambda d: d["decode_s"])[1]      # median run
    return dict(queue_depth=depth, slots=slots,
                tokens=int(s["tokens"]),
                tok_per_s=round(s["tok_per_s"], 1),
                prefill_s=round(s["prefill_s"], 4),
                decode_s=round(s["decode_s"], 4),
                host_syncs=int(s["host_syncs"]),
                syncs_per_request=round(s["host_syncs"] / depth, 2),
                chunks=int(s["chunks"]))


def run(out_path: str = None) -> dict:
    cfg = get_arch("tinyllama-1.1b", reduced=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    qp, _ = quantize_params(params, get_policy("paper_llama_mix"))

    results = dict(
        benchmark="e2e_serve",
        arch="tinyllama-1.1b(reduced)",
        workload=dict(prompt_len=PROMPT_LEN, new_tokens=NEW_TOKENS,
                      queue_depths=list(QUEUE_DEPTHS), max_slots=MAX_SLOTS),
        runs=[],
    )
    for tag, p in [("fp32", params), ("fbfq_mixed_q2q3", qp)]:
        for depth in QUEUE_DEPTHS:
            rec = _bench_one(cfg, p, depth)
            rec["params"] = tag
            results["runs"].append(rec)
            emit(f"e2e_serve_{tag}_d{depth}",
                 rec["decode_s"] / max(rec["tokens"], 1) * 1e6,
                 f"tok/s={rec['tok_per_s']} host_syncs={rec['host_syncs']} "
                 f"({rec['syncs_per_request']}/req) "
                 f"prefill_s={rec['prefill_s']}")
    emit_json(results, out_path)
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="benchmarks/results/e2e_serve.json",
                    help="where to persist the JSON blob ('' to skip)")
    args = ap.parse_args()
    run(args.out or None)
