"""End-to-end serving bench (paper's llama-cli experiment, reduced scale).

Quantizes a TinyLlama-family reduced model with the paper's mixed policy
and drives the continuous-batching engine at queue depths 1 / 4 / 8 / 32
over the paper's workload shape (6-token prompt, 10 new tokens).  Reports
decode tok/s, prefill tok/s, mean time-to-first-token, wall times, and --
the quantity the on-device decode loop exists to minimize -- host syncs
per request.  Prefill runs through the batched chunked admission pipeline
(one fused prefill per group of up to ``max_slots`` requests).

Speculative rows (queue depths 1 / 8 / 32, quantized params, prompt-lookup
drafter over a repetitive prompt) report ``accept_rate`` and
``spec_tok_per_s`` next to the plain columns; speculation-off rows are
unchanged, so the regression gate still sees the plain decode path.

Shared-prefix rows (queue depths 8 / 32) drive the shared-system-prompt
workload -- every request is a 48-token shared prefix plus a unique
suffix -- once with the paged KV prefix cache off (the ttft baseline on
that workload) and once with it on (warm radix tree, suffix-only
prefill), reporting ``prefix_hit_rate``, ``prefix_tokens_reused`` and
``prefix_evictions``.

Tensor-parallel rows (tp=1 vs tp=2 at queue depth 8, quantized params)
report the same decode/prefill/ttft columns under the shard_map TP
engine; they need >= 2 devices, so on a CPU-only box set
REPRO_FORCE_HOST_DEVICES=2 (honored below BEFORE jax initializes) and
they are skipped otherwise (CI's 1-device smoke sweep never produces
them, and the regression gate skips absent rows/metrics).

Disaggregated rows (queue depth 8, quantized params, shared-prefix
workload) pair one monolithic engine against a 1-prefill + 1-decode
worker DisaggEngine at matched depth; the disagg row reports
``migrated_pages`` and the decode workers' ``prefix_hit_rate``, and the
pair rides the same-run ``check_disagg`` structural gate (plus an
in-bench token-identity assert, so a parity break can never publish a
row).

Recurrent rows (mamba2 ssm + zamba2 hybrid, fp32, queue depth 8) drive
ragged distinct-length prompts through the batched fixed-grid chunked
prefill path and report, next to the usual columns, the throughput of
the OLD exact-length prefill (``exact_prefill_tok_per_s``: one freshly
jitted program per prompt length -- the compile-per-length cost that
path actually paid on every new length). A second row per arch runs the
shared-system-prompt workload with the checkpoint-mode prefix cache on
and reports ``prefix_hit_rate``. Both ride the same-run
``check_recurrent_prefill`` structural gate (batched must beat
exact-length; see scripts/check_bench_regression.py) and are part of
the --smoke sweep.

Auto-policy rows (queue depth 4, reduced configs tinyllama / gpt2 /
mobilellama; tinyllama only in --smoke) run the calibrated policy
search (``launch/policy_search.py``) and serve the searched assignment
next to ``default_serve_mix``, reporting quality (teacher-logit ``kl``)
and ``model_bytes`` alongside the usual perf columns, with metric-only
``pure_q2_k`` / ``pure_q6_k`` anchor rows (emitted only for anchor
variants in the sweep's candidate set -- the smoke sweep searches
without q6_k, so it carries just ``pure_q2_k``); they ride the same-run
``check_policy_auto`` structural gate (auto must dominate-or-match the
default on both axes and beat the anchors on quality / size when
present).

Output: human CSV rows (``emit``) plus one machine-readable JSON blob
(``--out`` to persist, default benchmarks/results/e2e_serve.json when run
as a script) so future PRs can track the perf trajectory.  ``--smoke``
runs the reduced sweep CI uses for regression gating -- including one
spec-decode run (see scripts/check_bench_regression.py).
"""
import argparse
import functools
import os
import time

from repro.launch.hostdev import force_host_devices

force_host_devices(os.environ.get("REPRO_FORCE_HOST_DEVICES"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_arch
from repro.core.policy import get_policy
from repro.core.qlinear import quantize_params
from repro.models import transformer as T
from repro.serving.disagg import DisaggEngine
from repro.serving.engine import Engine, ServeConfig
from benchmarks.common import emit, emit_json

PROMPT_LEN = 6            # paper workload
NEW_TOKENS = 10
QUEUE_DEPTHS = (1, 4, 8, 32)     # 4 = the seed benchmark's batch shape
SMOKE_DEPTHS = (4, 8)            # CI regression sweep
SPEC_DEPTHS = (1, 8, 32)         # speculative-decoding sweep
SPEC_SMOKE_DEPTHS = (8,)         # CI spec smoke run
PREFIX_DEPTHS = (8, 32)          # shared-system-prompt sweep
PREFIX_SMOKE_DEPTHS = (8,)       # CI prefix smoke run
TP_DEPTH = 8                     # tensor-parallel row (tp=1 vs tp=2)
DISAGG_DEPTH = 8                 # mono-vs-disagg row pair (1P+1D)
RECURRENT_ARCHS = ("mamba2-2.7b", "zamba2-1.2b")   # ssm + hybrid rows
RECURRENT_DEPTH = 8
# auto-policy quality-at-size rows (--policy auto): searched assignment
# vs default_serve_mix, with pure_q2_k / pure_q6_k anchors, per arch
AUTO_ARCHS = ("tinyllama-1.1b", "gpt2-paper", "mobilellama-1.4b")
AUTO_SMOKE_ARCHS = ("tinyllama-1.1b",)
AUTO_DEPTH = 4
SHARED_PREFIX_LEN = 48           # shared system prompt tokens
UNIQUE_LEN = 6                   # per-request unique suffix tokens
MAX_SLOTS = 8
DRAFT_K = 4


def _bench_one(cfg, params, depth: int, drafter: str = None,
               prefix: bool = None, tp: int = 1,
               tp_matmul: str = "padded") -> dict:
    """One engine sweep. ``prefix`` selects the shared-system-prompt
    workload (every request = SHARED_PREFIX_LEN shared tokens + a unique
    suffix): False runs it with the prefix cache OFF (the ttft baseline),
    True with it ON -- the warm-up generates populate the radix tree, so
    the measured runs hit."""
    slots = min(depth, MAX_SLOTS)
    eng = Engine(cfg, params, ServeConfig(
        max_new_tokens=NEW_TOKENS, max_slots=slots,
        decode_chunk=NEW_TOKENS,
        cache_len=64 if prefix is not None else 32, prefill_bucket=8,
        prefill_batch=slots, drafter=drafter, draft_k=DRAFT_K,
        prefix_cache=bool(prefix), prefix_page=8, tp=tp,
        tp_matmul=tp_matmul))
    rng = np.random.default_rng(0)
    if prefix is not None:
        shared = list(rng.integers(0, cfg.vocab_size, SHARED_PREFIX_LEN))
        prompts = [shared + list(rng.integers(0, cfg.vocab_size, UNIQUE_LEN))
                   for _ in range(depth)]
    elif drafter is None:
        prompts = [list(rng.integers(0, cfg.vocab_size, PROMPT_LEN))
                   for _ in range(depth)]
    else:
        # prompt-lookup's workload: repetitive prompts (cycled 2-grams)
        prompts = [[int(a), int(b)] * (PROMPT_LEN // 2)
                   for a, b in rng.integers(0, cfg.vocab_size, (depth, 2))]
    for _ in range(2):                         # compile + cache-donation warm
        eng.generate(prompts)
    stats = []
    for _ in range(3):
        outs = eng.generate(prompts)
        assert all(len(o) == NEW_TOKENS for o in outs)
        stats.append(dict(eng.stats))
    s = sorted(stats, key=lambda d: d["decode_s"])[1]      # median run
    rec = dict(queue_depth=depth, slots=slots,
               tokens=int(s["tokens"]),
               tok_per_s=round(s["tok_per_s"], 1),
               prefill_tok_per_s=round(s["prefill_tok_per_s"], 1),
               ttft_s=round(s["ttft_s"], 5),
               ttft_p50_s=round(s["ttft_p50_s"], 5),
               ttft_p99_s=round(s["ttft_p99_s"], 5),
               queue_wait_s=round(s["queue_wait_s"], 5),
               prefill_s=round(s["prefill_s"], 4),
               decode_s=round(s["decode_s"], 4),
               host_syncs=int(s["host_syncs"]),
               syncs_per_request=round(s["host_syncs"] / depth, 2),
               prefill_groups=int(s["prefill_groups"]),
               chunks=int(s["chunks"]))
    if drafter is not None:
        rec["drafter"] = drafter
        rec["draft_k"] = DRAFT_K
        rec["accept_rate"] = round(s["accept_rate"], 4)
        rec["spec_tok_per_s"] = rec["tok_per_s"]
        rec["spec_rounds"] = int(s["spec_rounds"])
    if prefix is not None:
        rec["shared_prefix_len"] = SHARED_PREFIX_LEN
        rec["prefix_hit_rate"] = round(s["prefix_hits"] / depth, 4)
        rec["prefix_tokens_reused"] = int(s["prefix_tokens_reused"])
        rec["prefix_evictions"] = int(s["prefix_evictions"])
    return rec


def _bench_disagg(cfg, params, depth: int) -> list:
    """Monolithic-vs-disaggregated row PAIR at matched queue depth over
    the shared-system-prompt workload (SHARED_PREFIX_LEN + unique
    suffix, so KV pages actually migrate prefill-worker -> decode-worker
    on the 1P+1D row). Both rows carry a ``disagg`` field
    (``"mono"`` / ``"1p1d"``) for the same-run structural gate in
    scripts/check_bench_regression.py; the measured outputs are asserted
    token-identical here too, so a parity break can never publish a
    benchmark row."""
    slots = min(depth, MAX_SLOTS)
    scfg = ServeConfig(max_new_tokens=NEW_TOKENS, max_slots=slots,
                       decode_chunk=NEW_TOKENS, cache_len=64,
                       prefill_bucket=8, prefill_batch=slots,
                       prefix_page=8)
    rng = np.random.default_rng(0)
    shared = list(rng.integers(0, cfg.vocab_size, SHARED_PREFIX_LEN))
    prompts = [shared + list(rng.integers(0, cfg.vocab_size, UNIQUE_LEN))
               for _ in range(depth)]
    rows, outs_by_tag = [], {}
    for tag, eng in (("mono", Engine(cfg, params, scfg)),
                     ("1p1d", DisaggEngine(cfg, params, scfg,
                                           prefill_workers=1,
                                           decode_workers=1))):
        for _ in range(2):                     # compile + warm radix trees
            eng.generate(prompts)
        stats = []
        for _ in range(3):
            outs = eng.generate(prompts)
            assert all(len(o) == NEW_TOKENS for o in outs)
            stats.append(dict(eng.stats))
        outs_by_tag[tag] = outs
        s = sorted(stats, key=lambda d: d["decode_s"])[1]      # median run
        rec = dict(queue_depth=depth, slots=slots, disagg=tag,
                   tokens=int(s["tokens"]),
                   tok_per_s=round(s["tok_per_s"], 1),
                   prefill_tok_per_s=round(s["prefill_tok_per_s"], 1),
                   ttft_s=round(s["ttft_s"], 5),
                   ttft_p50_s=round(s["ttft_p50_s"], 5),
                   ttft_p99_s=round(s["ttft_p99_s"], 5),
                   prefill_s=round(s["prefill_s"], 4),
                   decode_s=round(s["decode_s"], 4),
                   host_syncs=int(s["host_syncs"]),
                   shared_prefix_len=SHARED_PREFIX_LEN)
        if tag != "mono":
            router = s["router"]
            rec["prefill_workers"] = router["prefill_workers"]
            rec["decode_workers"] = router["decode_workers"]
            # lifetime totals: migration happens on the first (warm-up)
            # pass; measured passes re-hit the decode worker's radix tree
            rec["migrated_pages"] = int(router["migrated_pages_total"])
            rec["prefix_hit_rate"] = round(s["prefix_hits"] / depth, 4)
            rec["prefix_tokens_reused"] = int(s["prefix_tokens_reused"])
        rows.append(rec)
    assert outs_by_tag["1p1d"] == outs_by_tag["mono"], \
        "disaggregated output diverged from monolithic (parity contract)"
    return rows


def _exact_prefill_tok_per_s(cfg, params, prompts) -> float:
    """Throughput of the pre-refactor recurrent prefill: one EXACT-length
    program per prompt, so every new length pays a fresh compile -- the
    cost the old ``_prefill_impl`` paid on first sight of each length (a
    fresh jit wrapper per prompt defeats jax's cache the same way a new
    length did). The batched fixed-grid path amortizes ONE compiled
    (B, C) chunk program over all lengths; this oracle is what the
    ``check_recurrent_prefill`` gate compares it against."""
    total_s, total_tok = 0.0, 0
    for p in prompts:
        L = len(p)
        cache = T.init_cache(cfg, 1, 64)
        fn = jax.jit(functools.partial(
            T.prefill_chunk, params, cfg))            # fresh cache entry
        tok = jnp.asarray([p], jnp.int32)
        lens = jnp.asarray([L], jnp.int32)
        t0 = time.perf_counter()
        out = fn(cache, tokens=tok, start=jnp.int32(0), lengths=lens)
        jax.block_until_ready(out)
        total_s += time.perf_counter() - t0
        total_tok += L
    return total_tok / total_s


def _bench_recurrent(arch: str, depth: int) -> list:
    """Two rows for a recurrent arch (fp32): ragged distinct-length
    prompts through the batched fixed-grid chunked prefill (plus the
    exact-length oracle throughput for the structural gate), and the
    shared-system-prompt workload with the checkpoint-mode prefix cache
    on (hit rate must be total: every measured pass is warm)."""
    cfg = get_arch(arch, reduced=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    slots = min(depth, MAX_SLOTS)
    rng = np.random.default_rng(0)
    # distinct lengths: the exact-length path compiles per length
    lens = list(18 + rng.permutation(30)[:depth])
    shared = list(rng.integers(0, cfg.vocab_size, SHARED_PREFIX_LEN))
    rows = []
    for tag, prefix_on in (("batched", None), ("prefix_on", True)):
        eng = Engine(cfg, params, ServeConfig(
            max_new_tokens=NEW_TOKENS, max_slots=slots,
            decode_chunk=NEW_TOKENS, cache_len=64, prefill_bucket=8,
            prefill_chunk=16, prefill_batch=slots,
            prefix_cache=bool(prefix_on)))
        if prefix_on:
            prompts = [shared + list(rng.integers(0, cfg.vocab_size,
                                                  UNIQUE_LEN))
                       for _ in range(depth)]
        else:
            prompts = [list(rng.integers(0, cfg.vocab_size, int(L)))
                       for L in lens]
        for _ in range(2):                 # compile + warm checkpoint tree
            eng.generate(prompts)
        stats = []
        for _ in range(3):
            outs = eng.generate(prompts)
            assert all(len(o) == NEW_TOKENS for o in outs)
            stats.append(dict(eng.stats))
        s = sorted(stats, key=lambda d: d["decode_s"])[1]      # median run
        rec = dict(queue_depth=depth, slots=slots, arch=arch,
                   family=cfg.family, prefill_mode=tag,
                   tokens=int(s["tokens"]),
                   tok_per_s=round(s["tok_per_s"], 1),
                   prefill_tok_per_s=round(s["prefill_tok_per_s"], 1),
                   ttft_s=round(s["ttft_s"], 5),
                   ttft_p50_s=round(s["ttft_p50_s"], 5),
                   ttft_p99_s=round(s["ttft_p99_s"], 5),
                   prefill_s=round(s["prefill_s"], 4),
                   decode_s=round(s["decode_s"], 4),
                   host_syncs=int(s["host_syncs"]))
        if prefix_on:
            rec["shared_prefix_len"] = SHARED_PREFIX_LEN
            rec["prefix_hit_rate"] = round(s["prefix_hits"] / depth, 4)
            rec["prefix_tokens_reused"] = int(s["prefix_tokens_reused"])
        else:
            rec["exact_prefill_tok_per_s"] = round(
                _exact_prefill_tok_per_s(cfg, params, prompts), 1)
        rows.append(rec)
    return rows


def _bench_policy_auto(smoke: bool) -> list:
    """Auto-policy rows: per arch, run the calibrated policy search and
    serve both the searched assignment and default_serve_mix at matched
    depth; quality (teacher-logit KL) and model bytes ride along from
    the search's own verified evals, with the pure_q2_k / pure_q6_k
    anchors as metric-only rows (anchors exist only for variants the
    sweep searched; smoke drops q6_k). The searched policy dominates-or-
    matches the seed by construction -- check_policy_auto pins that."""
    from repro.core import calibrate as CAL
    from repro.launch.policy_search import search_policy
    archs = AUTO_SMOKE_ARCHS if smoke else AUTO_ARCHS
    candidates = (("q2_k", "q3_k", "none") if smoke else
                  ("q2_k", "q3_k", "q3_k_o", "q4_k", "q6_k", "none"))
    rows = []
    for arch in archs:
        cfg = get_arch(arch, reduced=True)
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        stats = CAL.run_calibration(params, cfg,
                                    n_batches=1 if smoke else 2,
                                    seq=32 if smoke else 64)
        policy, info = search_policy(
            cfg, params, arch=arch, candidates=candidates,
            rounds=1 if smoke else 2, stats=stats,
            eval_seq=32 if smoke else 64, verbose=False)
        meta = info["meta"]
        calib = stats.for_paths([p for p, _ in policy.rules])
        qp_auto, _ = quantize_params(params, policy, calib=calib)
        qp_def, _ = quantize_params(params,
                                    get_policy("default_serve_mix"))
        served = (("auto", qp_auto, meta["final"]),
                  ("default_serve_mix", qp_def, meta["seed"]))
        for tag, qp, m in served:
            rec = _bench_one(cfg, qp, AUTO_DEPTH)
            rec["params"] = f"policy_{tag}_{arch}"
            rec["policy"] = tag
            rec["policy_arch"] = arch
            rec["kl"] = round(m["kl"], 6)
            rec["model_bytes"] = int(m["bytes"])
            if "pseudo_ppl" in m:
                rec["pseudo_ppl"] = round(m["pseudo_ppl"], 3)
            rows.append(rec)
        for v, m in meta["anchors"].items():
            rows.append(dict(
                params=f"policy_{v}_{arch}", queue_depth=AUTO_DEPTH,
                policy=v, policy_arch=arch, kl=round(m["kl"], 6),
                model_bytes=int(m["bytes"]),
                pseudo_ppl=round(m["pseudo_ppl"], 3)))
    return rows


def run(out_path: str = None, smoke: bool = False) -> dict:
    cfg = get_arch("tinyllama-1.1b", reduced=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    qp, _ = quantize_params(params, get_policy("paper_llama_mix"))
    depths = SMOKE_DEPTHS if smoke else QUEUE_DEPTHS
    spec_depths = SPEC_SMOKE_DEPTHS if smoke else SPEC_DEPTHS
    prefix_depths = PREFIX_SMOKE_DEPTHS if smoke else PREFIX_DEPTHS

    results = dict(
        benchmark="e2e_serve",
        arch="tinyllama-1.1b(reduced)",
        workload=dict(prompt_len=PROMPT_LEN, new_tokens=NEW_TOKENS,
                      queue_depths=list(depths),
                      spec_queue_depths=list(spec_depths),
                      prefix_queue_depths=list(prefix_depths),
                      shared_prefix_len=SHARED_PREFIX_LEN,
                      unique_len=UNIQUE_LEN, tp_depth=TP_DEPTH,
                      disagg_depth=DISAGG_DEPTH,
                      recurrent_archs=list(RECURRENT_ARCHS),
                      recurrent_depth=RECURRENT_DEPTH,
                      auto_archs=list(AUTO_SMOKE_ARCHS if smoke
                                      else AUTO_ARCHS),
                      auto_depth=AUTO_DEPTH,
                      draft_k=DRAFT_K, max_slots=MAX_SLOTS,
                      smoke=smoke),
        runs=[],
    )
    for tag, p in [("fp32", params), ("fbfq_mixed_q2q3", qp)]:
        for depth in depths:
            rec = _bench_one(cfg, p, depth)
            rec["params"] = tag
            results["runs"].append(rec)
            emit(f"e2e_serve_{tag}_d{depth}",
                 rec["decode_s"] / max(rec["tokens"], 1) * 1e6,
                 f"tok/s={rec['tok_per_s']} "
                 f"prefill_tok/s={rec['prefill_tok_per_s']} "
                 f"ttft_s={rec['ttft_s']} "
                 f"host_syncs={rec['host_syncs']} "
                 f"({rec['syncs_per_request']}/req)")
    for depth in spec_depths:
        rec = _bench_one(cfg, qp, depth, drafter="ngram")
        rec["params"] = "fbfq_mixed_q2q3_spec_ngram"
        results["runs"].append(rec)
        emit(f"e2e_serve_spec_ngram_d{depth}",
             rec["decode_s"] / max(rec["tokens"], 1) * 1e6,
             f"spec_tok/s={rec['spec_tok_per_s']} "
             f"accept_rate={rec['accept_rate']} "
             f"rounds={rec['spec_rounds']} "
             f"ttft_s={rec['ttft_s']}")
    # tensor-parallel rows: same workload/params at tp=1 vs tp=2 under
    # the shard_map engine, one row per (tp, matmul datapath) so
    # baselines compare like-for-like:
    #   padded     -- token-identical output, replicated FLOPs (tracks
    #                 the TP engine's overhead)
    #   sliced     -- lane-sliced gemms, 1/size FLOPs, f32-ulp fidelity
    #   sliced_row -- sliced + row-parallel o-/down-proj (half the
    #                 collectives per layer; activation-ulp fidelity) --
    #                 the throughput datapath
    # Skipped when the backend exposes a single device.
    if not smoke and len(jax.devices()) >= 2:
        tp_rows = [(1, "padded")] + [(2, mm) for mm in
                                     ("padded", "sliced", "sliced_row")]
        for tp, mm in tp_rows:
            rec = _bench_one(cfg, qp, TP_DEPTH, tp=tp, tp_matmul=mm)
            rec["params"] = f"fbfq_mixed_q2q3_tp{tp}_{mm}"
            rec["tp"] = tp
            rec["tp_matmul"] = mm
            results["runs"].append(rec)
            emit(f"e2e_serve_tp{tp}_{mm}_d{TP_DEPTH}",
                 rec["decode_s"] / max(rec["tokens"], 1) * 1e6,
                 f"tok/s={rec['tok_per_s']} "
                 f"prefill_tok/s={rec['prefill_tok_per_s']} "
                 f"ttft_s={rec['ttft_s']}")
    # shared-system-prompt workload: prefix cache off (ttft baseline on
    # the SAME prompts) vs on (warm radix tree -> suffix-only prefill)
    for depth in prefix_depths:
        for tag, on in (("prefix_off", False), ("prefix_on", True)):
            rec = _bench_one(cfg, qp, depth, prefix=on)
            rec["params"] = f"fbfq_mixed_q2q3_{tag}"
            results["runs"].append(rec)
            emit(f"e2e_serve_{tag}_d{depth}",
                 rec["decode_s"] / max(rec["tokens"], 1) * 1e6,
                 f"prefill_tok/s={rec['prefill_tok_per_s']} "
                 f"ttft_s={rec['ttft_s']} "
                 + (f"prefix_hit_rate={rec['prefix_hit_rate']} "
                    f"reused={rec['prefix_tokens_reused']}" if on else ""))
    # recurrent rows (ssm + hybrid, fp32): batched fixed-grid chunked
    # prefill vs the old exact-length oracle, plus a checkpoint-mode
    # prefix-cache row -- both in the smoke sweep for the same-run
    # check_recurrent_prefill structural gate
    for arch in RECURRENT_ARCHS:
        for rec in _bench_recurrent(arch, RECURRENT_DEPTH):
            rec["params"] = f"fp32_{rec['family']}_{rec['prefill_mode']}"
            results["runs"].append(rec)
            fam = rec["family"]
            extra = (f"prefix_hit_rate={rec['prefix_hit_rate']} "
                     f"reused={rec['prefix_tokens_reused']}"
                     if rec["prefill_mode"] == "prefix_on" else
                     f"exact_prefill_tok/s={rec['exact_prefill_tok_per_s']}")
            emit(f"e2e_serve_{fam}_{rec['prefill_mode']}_d{RECURRENT_DEPTH}",
                 rec["decode_s"] / max(rec["tokens"], 1) * 1e6,
                 f"tok/s={rec['tok_per_s']} "
                 f"prefill_tok/s={rec['prefill_tok_per_s']} "
                 f"ttft_s={rec['ttft_s']} {extra}")
    # monolithic-vs-disaggregated pair at matched depth (1 prefill + 1
    # decode worker; shared-prefix workload so pages migrate) -- included
    # in the smoke sweep for the same-run check_disagg structural gate
    for rec in _bench_disagg(cfg, qp, DISAGG_DEPTH):
        rec["params"] = f"fbfq_mixed_q2q3_disagg_{rec['disagg']}" \
            if rec["disagg"] != "mono" else "fbfq_mixed_q2q3_mono"
        results["runs"].append(rec)
        extra = (f"migrated_pages={rec['migrated_pages']} "
                 f"prefix_hit_rate={rec['prefix_hit_rate']}"
                 if rec["disagg"] != "mono" else "")
        emit(f"e2e_serve_disagg_{rec['disagg']}_d{DISAGG_DEPTH}",
             rec["decode_s"] / max(rec["tokens"], 1) * 1e6,
             f"tok/s={rec['tok_per_s']} "
             f"prefill_tok/s={rec['prefill_tok_per_s']} "
             f"ttft_s={rec['ttft_s']} {extra}")
    # auto-policy quality-at-size rows (searched vs default_serve_mix +
    # anchors) -- in the smoke sweep too for the same-run
    # check_policy_auto structural gate
    for rec in _bench_policy_auto(smoke):
        results["runs"].append(rec)
        perf = (f"tok/s={rec['tok_per_s']} ttft_s={rec['ttft_s']} "
                if "tok_per_s" in rec else "")
        emit(f"e2e_serve_{rec['params']}_d{rec['queue_depth']}",
             rec["kl"] * 1e3,
             f"kl={rec['kl']} bytes={rec['model_bytes']} {perf}")
    emit_json(results, out_path)
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None,
                    help="where to persist the JSON blob ('' to skip; "
                         "default: the committed baseline path for the "
                         "full sweep, nowhere for --smoke so a partial "
                         "sweep can never clobber the baseline)")
    ap.add_argument("--smoke", action="store_true",
                    help="quick sweep (CI regression gate): depths "
                         f"{SMOKE_DEPTHS} plus one spec run at depth "
                         f"{SPEC_SMOKE_DEPTHS[0]}")
    args = ap.parse_args()
    out = args.out
    if out is None:
        out = "" if args.smoke else "benchmarks/results/e2e_serve.json"
    run(out or None, smoke=args.smoke)
