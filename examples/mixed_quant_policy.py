"""Explore mixed-quantization policies: quality-vs-size tradeoff across the
BFP variant ladder (paper Fig. 1 motivation + future-work variants).

  PYTHONPATH=src python examples/mixed_quant_policy.py
"""
import jax
import jax.numpy as jnp

from repro.configs.base import get_arch
from repro.core.policy import get_policy, pure
from repro.core.qlinear import quantize_params, quantized_param_bytes
from repro.models import transformer as T

cfg = get_arch("llama3.2-1b", reduced=True)
params = T.init_params(cfg, jax.random.PRNGKey(0))
toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                          cfg.vocab_size)
logits_fp, _, _ = T.forward_seq(params, cfg, tokens=toks)
p_fp = jax.nn.softmax(logits_fp, axis=-1)

print(f"{'policy':24s} {'MiB':>8s} {'KL(fp||q)':>10s}")
for pol in (pure("q2_k"), pure("q3_k"), pure("q4_k"), pure("q6_k"),
            get_policy("paper_llama_mix"), get_policy("extended_mix")):
    qp, _ = quantize_params(params, pol)
    sizes = quantized_param_bytes(qp)
    logits_q, _, _ = T.forward_seq(qp, cfg, tokens=toks)
    logp_q = jax.nn.log_softmax(logits_q, axis=-1)
    kl = float(jnp.sum(p_fp * (jnp.log(p_fp + 1e-9) - logp_q), axis=-1)
               .mean())
    print(f"{pol.name:24s} {sizes['total']/2**20:8.1f} {kl:10.4f}")
