"""Quickstart: quantize a weight matrix with the paper's two BFP variants,
run the fused MatMul kernel, and verify against the oracle -- the F-BFQ
accelerator datapath in five steps.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantize import quantize, quantize_q8_k
from repro.core import isa
from repro.kernels import ref
from repro.kernels.bfp_matmul import bfp_matmul_pallas

key = jax.random.PRNGKey(0)
M, K, N = 16, 1024, 512
x = jax.random.normal(key, (M, K))
w = jax.random.normal(jax.random.PRNGKey(1), (K, N)) * 0.1

for variant in ("q2_k", "q3_k"):
    # 1) quantize weights to the packed BFP format (llama.cpp semantics)
    t = quantize(variant, w)
    print(f"[{variant}] packed {w.size * 4 / 2**20:.2f} MiB fp32 -> "
          f"{t.nbytes / 2**20:.2f} MiB ({t.bits_per_weight} bits/weight)")

    # 2) fused dequant-matmul Pallas kernel (interpret=True on CPU)
    out = bfp_matmul_pallas(x, t, interpret=True,
                            compute_dtype=jnp.float32,
                            out_dtype=jnp.float32)

    # 3) oracle check
    expect = ref.matmul_ref(x, t)
    err = float(jnp.abs(out - expect).max() / jnp.abs(expect).max())
    print(f"[{variant}] kernel vs oracle rel err {err:.2e}")

    # 4) the paper's integer datapath (Q8_K activations, per-block int dots)
    qx = quantize_q8_k(x)
    out_int = ref.matmul_q8k_ref(qx, t)
    err_int = float(jnp.abs(out_int - expect).max() / jnp.abs(expect).max())
    print(f"[{variant}] integer (Q8_K) datapath vs dequant err {err_int:.2e}")

    # 5) micro-ISA driver + functional accelerator simulator (Table I)
    out_sim, stats = isa.run_matmul(np.asarray(x), t)
    print(f"[{variant}] ISA sim: {stats.schedules} schedules, "
          f"{stats.total_stream_bytes / 2**20:.2f} MiB streamed\n")
