"""Serve a mixed-BFP-quantized model end to end (the paper's Table IV
scenario: 6-token prompts, 10 generated tokens) through the
continuous-batching engine: 6 requests share 2 batch slots, tokens stream
via callbacks, and the decode loop runs on device (one host sync per fused
chunk, not per token).

  PYTHONPATH=src python examples/serve_quantized.py
"""
import jax
import numpy as np

from repro.configs.base import get_arch
from repro.core.policy import get_policy
from repro.core.qlinear import quantize_params, quantized_param_bytes
from repro.models import transformer as T
from repro.serving.engine import Engine, ServeConfig

cfg = get_arch("tinyllama-1.1b", reduced=True)
params = T.init_params(cfg, jax.random.PRNGKey(0))

# per-tensor mixed Q2_K/Q3_K, reproducing the paper's Table III layout
qp, report = quantize_params(params, get_policy("paper_llama_mix"))
counts = {}
for v in report.values():
    if v:
        counts[v] = counts.get(v, 0) + 1
sizes = quantized_param_bytes(qp)
print(f"quantized tensors by variant: {counts}")
print(f"packed {sizes['packed']/2**20:.1f} MiB + fp residual "
      f"{sizes['unpacked']/2**20:.1f} MiB")

engine = Engine(cfg, qp, ServeConfig(max_new_tokens=10, max_slots=2,
                                     decode_chunk=10, cache_len=32))
streamed = {}
rng = np.random.default_rng(0)
prompts = [[int(t) for t in rng.integers(0, cfg.vocab_size, 6)]
           for _ in range(6)]
for p in prompts:
    engine.submit(p, on_token=lambda rid, t: streamed.setdefault(rid,
                                                                 []).append(t))
results = engine.run()
for rid, toks in sorted(results.items()):
    print(f"request {rid}: prompt {prompts[rid]} -> {toks}")
assert streamed == results        # callbacks saw every token, in order
s = engine.stats
print(f"prefill {s['prefill_s']:.3f}s; decode {s['decode_s']:.3f}s; "
      f"{s['tok_per_s']:.1f} tok/s; {s['host_syncs']} host syncs for "
      f"{s['requests']} requests over {s['chunks']} fused chunks "
      f"(2 slots, continuous batching)")
