"""End-to-end training driver: train a ~100M-param llama-family model for a
few hundred steps on the synthetic pipeline with checkpointing + watchdog.

  PYTHONPATH=src python examples/train_e2e.py [--steps 300]

(~100M params: 12L x d=768 x ff=2048, 32k vocab; CPU-sized batch.)
"""
import argparse

from repro.configs.base import ModelConfig
from repro.optim.adamw import AdamWConfig
from repro.training.loop import run_training

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--ckpt-dir", default="/tmp/repro_train_e2e")
args = ap.parse_args()

cfg = ModelConfig(
    name="llama-100m", family="dense",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
    d_ff=2048, vocab_size=32000, rope_theta=1e4,
    attn_impl="naive", remat=False,
)

res = run_training(cfg, steps=args.steps, global_batch=8, seq_len=128,
                   ckpt_dir=args.ckpt_dir, ckpt_every=100,
                   opt=AdamWConfig(lr=6e-4, warmup_steps=30,
                                   total_steps=args.steps))
losses = res["losses"]
t = res["timing"]
print(f"\nloss: {losses[0]:.3f} -> {losses[-1]:.3f} over {len(losses)} steps")
print(f"step time p50 {t['p50']:.3f}s p99 {t['p99']:.3f}s, "
      f"stragglers {t['stragglers']}")
assert losses[-1] < losses[0], "training should reduce loss"
