"""HTTP front-end tests (serving/frontend.py): OpenAI-compatible
completions over a live engine on an ephemeral port -- plain and SSE
streaming roundtrips, request timeouts, saturation 429s with the
machine-readable reason, disconnect-driven cancellation, and the
side-channel GET endpoints. Everything runs against ONE engine/frontend
pair (module fixture): the engine thread owns the device, the tests own
http.client connections, which is exactly the deployment shape."""
import http.client
import json
import socket
import time

import jax
import pytest

from repro.configs.base import get_arch
from repro.models import transformer as T
from repro.serving.engine import Engine, ServeConfig
from repro.serving.frontend import Frontend, FrontendConfig

PROMPT = [3, 1, 4, 1, 5, 9]


@pytest.fixture(scope="module")
def fe():
    cfg = get_arch("tinyllama-1.1b", reduced=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, ServeConfig(
        max_new_tokens=8, cache_len=128, decode_chunk=1, max_slots=1,
        prefill_bucket=16, max_queue=1))
    eng.generate([PROMPT])              # compile before traffic arrives
    fe = Frontend(eng, FrontendConfig(model_name="tiny-test",
                                      request_timeout_s=30.0)).start()
    yield fe
    fe.close()


def _post(fe, payload, timeout=90.0):
    conn = http.client.HTTPConnection("127.0.0.1", fe.port,
                                      timeout=timeout)
    conn.request("POST", "/v1/completions", json.dumps(payload),
                 {"Content-Type": "application/json"})
    resp = conn.getresponse()
    body = json.loads(resp.read())
    conn.close()
    return resp.status, body


def _get(fe, path):
    conn = http.client.HTTPConnection("127.0.0.1", fe.port, timeout=30)
    conn.request("GET", path)
    resp = conn.getresponse()
    body = json.loads(resp.read())
    conn.close()
    return resp.status, body


def test_completion_roundtrip(fe):
    status, body = _post(fe, dict(prompt=PROMPT, max_tokens=5))
    assert status == 200
    choice = body["choices"][0]
    assert len(choice["token_ids"]) == 5
    assert choice["finish_reason"] == "length"
    assert body["model"] == "tiny-test"
    assert body["usage"] == dict(prompt_tokens=len(PROMPT),
                                 completion_tokens=5,
                                 total_tokens=len(PROMPT) + 5)
    assert body["timing"]["ttft_s"] > 0
    assert body["timing"]["queue_wait_s"] >= 0
    # greedy determinism survives the HTTP hop
    assert _post(fe, dict(prompt=PROMPT, max_tokens=5))[1][
        "choices"][0]["token_ids"] == choice["token_ids"]


def test_streaming_sse(fe):
    conn = http.client.HTTPConnection("127.0.0.1", fe.port, timeout=90)
    conn.request("POST", "/v1/completions",
                 json.dumps(dict(prompt=PROMPT, max_tokens=4,
                                 stream=True)),
                 {"Content-Type": "application/json"})
    resp = conn.getresponse()
    assert resp.status == 200
    assert resp.getheader("Content-Type") == "text/event-stream"
    events = []
    for raw in resp.read().split(b"\n\n"):
        if raw.startswith(b"data: ") and raw != b"data: [DONE]":
            events.append(json.loads(raw[len(b"data: "):]))
    conn.close()
    toks = [e["choices"][0]["token_id"] for e in events
            if "token_id" in e["choices"][0]]
    final = events[-1]
    assert len(toks) == 4
    assert final["choices"][0]["finish_reason"] == "length"
    assert final["usage"]["completion_tokens"] == 4
    # stream and plain emit the same greedy tokens
    assert _post(fe, dict(prompt=PROMPT, max_tokens=4))[1][
        "choices"][0]["token_ids"] == toks


def test_request_timeout_keeps_partial_tokens(fe):
    """An overdue request is cancelled through the ordinary cancel()
    machinery: finish_reason "timeout", already-emitted tokens kept."""
    status, body = _post(fe, dict(prompt=PROMPT, max_tokens=64,
                                  timeout_s=0.001))
    assert status == 200
    assert body["choices"][0]["finish_reason"] == "timeout"
    assert len(body["choices"][0]["token_ids"]) < 64


def test_validation_and_routing_errors(fe):
    status, body = _post(fe, dict(prompt="text prompt"))
    assert status == 400 and "token ids" in body["error"]["message"]
    assert _post(fe, dict())[0] == 400
    conn = http.client.HTTPConnection("127.0.0.1", fe.port, timeout=30)
    conn.request("POST", "/v1/completions", b"{not json",
                 {"Content-Type": "application/json"})
    resp = conn.getresponse()
    assert resp.status == 400
    assert json.loads(resp.read())["error"]["type"] == \
        "invalid_request_error"
    conn.close()
    assert _get(fe, "/nope")[0] == 404


def test_get_endpoints(fe):
    status, body = _get(fe, "/health")
    assert status == 200 and body["status"] == "ok"
    assert body["model"] == "tiny-test"
    status, body = _get(fe, "/v1/models")
    assert status == 200 and body["data"][0]["id"] == "tiny-test"
    status, body = _get(fe, "/stats")
    assert status == 200
    assert body["frontend"]["completions"] > 0
    assert "requests" in body["engine"]


def test_saturation_returns_structured_429(fe):
    """max_slots=1 + max_queue=1: with one request in service and one
    queued, a third submit is shed with HTTP 429 and the machine-
    readable EngineSaturated reason in the body. A's prompt lands in a
    length bucket nothing warmed (24 -> bucket 32), so its admission
    compiles for seconds -- B and C both arrive while the single slot is
    provably still busy, with B ahead in the queue."""
    a = http.client.HTTPConnection("127.0.0.1", fe.port, timeout=90)
    a.request("POST", "/v1/completions",
              json.dumps(dict(prompt=PROMPT * 4, max_tokens=100,
                              stream=True)),
              {"Content-Type": "application/json"})
    ra = a.getresponse()                 # headers sent => A is running
    assert ra.status == 200
    b = http.client.HTTPConnection("127.0.0.1", fe.port, timeout=90)
    b.request("POST", "/v1/completions",
              json.dumps(dict(prompt=PROMPT, max_tokens=2)),
              {"Content-Type": "application/json"})
    time.sleep(0.15)                     # B reaches the queue before C
    status, body = _post(fe, dict(prompt=PROMPT, max_tokens=2))
    assert status == 429
    assert body["error"]["type"] == "engine_saturated"
    assert body["error"]["reason"] == "queue_full"
    rb = b.getresponse()                 # the queued request still serves
    assert rb.status == 200
    assert len(json.loads(rb.read())["choices"][0]["token_ids"]) == 2
    b.close()
    assert ra.read().endswith(b"data: [DONE]\n\n")
    a.close()


def test_disconnect_cancels_request(fe):
    """A client that vanishes mid-stream must not leak its slot: the
    next token write fails, the handler cancels through the inbox, and
    the engine serves the next request normally."""
    before = _get(fe, "/stats")[1]["frontend"]["disconnects"]
    s = socket.create_connection(("127.0.0.1", fe.port), timeout=30)
    payload = json.dumps(dict(prompt=PROMPT, max_tokens=120,
                              stream=True)).encode()
    s.sendall(b"POST /v1/completions HTTP/1.1\r\n"
              b"Host: x\r\nContent-Type: application/json\r\n"
              + f"Content-Length: {len(payload)}\r\n\r\n".encode()
              + payload)
    assert s.recv(64)                    # stream started
    s.close()                            # ...and the client vanishes
    deadline = time.time() + 30
    while time.time() < deadline:
        if _get(fe, "/stats")[1]["frontend"]["disconnects"] > before:
            break
        time.sleep(0.1)
    else:
        pytest.fail("disconnect never cancelled the request")
    # the slot is free again: a fresh request completes
    status, body = _post(fe, dict(prompt=PROMPT, max_tokens=3))
    assert status == 200
    assert len(body["choices"][0]["token_ids"]) == 3
