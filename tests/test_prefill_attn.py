"""Fused prefill-attention Pallas kernel (interpret mode) vs the naive
materializing path: the kernel promises f32-rounding-level agreement
with ``layers.naive_attention`` under the chunked-prefill position-mask
semantics (absolute query positions vs per-slot kv positions, -1 = empty
slot), GQA folded, sliding window optional. Only rows with at least one
visible key are compared -- all-masked rows produce garbage by
convention on BOTH paths and callers discard them."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models import layers as L
from repro.kernels.prefill_attn import prefill_attn_fused

TOL = 5e-6     # f32 accumulation-order noise at these shapes


def _mk(seed, B, C, T, H, KH, D, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, C, H, D), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, T, KH, D), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, T, KH, D), jnp.float32).astype(dtype)
    return q, k, v


def _compare(q, k, v, qp, kp, window=None, softcap=None):
    o_ref = L.naive_attention(q, k, v, causal=True, window=window,
                              softcap=softcap, q_positions=qp,
                              kv_positions=kp)
    o_fus = prefill_attn_fused(q, k, v, qp, kp, window=window,
                               softcap=softcap, interpret=True)
    assert o_fus.shape == o_ref.shape and o_fus.dtype == o_ref.dtype
    # visible = query rows with >= 1 unmasked key; others are garbage
    vis = ((kp[:, None, :] >= 0) & (kp[:, None, :] <= qp[:, :, None]))
    if window:
        vis &= kp[:, None, :] > qp[:, :, None] - window
    vis = np.asarray(vis.any(-1))
    a = np.asarray(o_ref, np.float32)[vis]
    b = np.asarray(o_fus, np.float32)[vis]
    np.testing.assert_allclose(b, a, rtol=TOL,
                               atol=TOL * (np.abs(a).max() + 1e-9))


@pytest.mark.parametrize("H,KH", [(4, 4), (8, 2), (6, 1)])
def test_fused_matches_naive_gqa(H, KH):
    """Plain self-attention positions, MHA / GQA / MQA head layouts."""
    B, C, T, D = 2, 16, 16, 32
    q, k, v = _mk(0, B, C, T, H, KH, D)
    pos = jnp.broadcast_to(jnp.arange(C)[None], (B, C))
    _compare(q, k, v, pos, pos)


def test_fused_matches_naive_ring_semantics():
    """The chunked-prefill case: queries attend a decode ring (scattered
    absolute positions, -1 empty slots) plus their own chunk's keys --
    positions are NOT sorted or contiguous along the kv axis."""
    B, C, T, H, KH, D = 2, 8, 24, 4, 2, 16
    q, k, v = _mk(1, B, C, T, H, KH, D)
    rng = np.random.default_rng(2)
    kp = rng.integers(-1, 20, (B, T)).astype(np.int32)
    qp = np.sort(rng.integers(0, 24, (B, C)).astype(np.int32), axis=1)
    _compare(q, k, v, jnp.asarray(qp), jnp.asarray(kp))


def test_fused_sliding_window_and_softcap():
    B, C, T, H, KH, D = 1, 12, 12, 4, 2, 16
    q, k, v = _mk(3, B, C, T, H, KH, D)
    pos = jnp.broadcast_to(jnp.arange(C)[None], (B, C))
    _compare(q, k, v, pos, pos, window=5)
    _compare(q, k, v, pos, pos, softcap=8.0)


def test_fused_through_prefill_attention_entry():
    """impl="fused" on the public layers.prefill_attention entry point:
    same cache + new-chunk concatenation, same outputs as impl="naive"
    on the valid (non-right-padded) rows."""
    B, C, T, H, KH, D = 2, 6, 16, 4, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    q = jax.random.normal(ks[0], (B, C, H, D), jnp.float32)
    kc = jax.random.normal(ks[1], (B, T, KH, D), jnp.float32)
    vc = jax.random.normal(ks[2], (B, T, KH, D), jnp.float32)
    kn = jax.random.normal(ks[3], (B, C, KH, D), jnp.float32)
    vn = jax.random.normal(ks[4], (B, C, KH, D), jnp.float32)
    slot_pos = jnp.where(jnp.arange(T)[None] < 10,
                         jnp.arange(T)[None], -1)
    slot_pos = jnp.broadcast_to(slot_pos, (B, T))
    positions = 10 + jnp.broadcast_to(jnp.arange(C)[None], (B, C))
    valid = jnp.broadcast_to(jnp.arange(C)[None] < 5, (B, C))
    args = (q, kc, vc, slot_pos, kn, vn, positions, valid)
    o_ref = L.prefill_attention(*args)
    o_fus = L.prefill_attention(*args, impl="fused", interpret=True)
    vis = np.asarray(valid)
    a = np.asarray(o_ref)[vis]
    b = np.asarray(o_fus)[vis]
    np.testing.assert_allclose(b, a, rtol=TOL,
                               atol=TOL * (np.abs(a).max() + 1e-9))


@settings(max_examples=10, deadline=None)
@given(B=st.integers(1, 3), C=st.integers(1, 20), T=st.integers(1, 40),
       KH=st.integers(1, 3), G=st.integers(1, 3),
       D=st.sampled_from([8, 16, 32]),
       window=st.sampled_from([None, 4]),
       bf16=st.booleans(), seed=st.integers(0, 2**16))
def test_property_fused_matches_naive(B, C, T, KH, G, D, window, bf16,
                                      seed):
    """Ragged (B, C, T), arbitrary GQA grouping, random ring positions
    with empty slots, both activation dtypes: fused == naive on every
    visible row (f32 tolerance; bf16 inputs round identically on both
    paths since both cast to f32 before the dot)."""
    dtype = jnp.bfloat16 if bf16 else jnp.float32
    q, k, v = _mk(seed, B, C, T, KH * G, KH, D, dtype=dtype)
    rng = np.random.default_rng(seed)
    kp = rng.integers(-1, C + T, (B, T)).astype(np.int32)
    qp = np.sort(rng.integers(0, C + T, (B, C)).astype(np.int32), axis=1)
    _compare(q, k, v, jnp.asarray(qp), jnp.asarray(kp), window=window)
