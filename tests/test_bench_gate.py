"""Unit suite for the benchmark regression gate
(scripts/check_bench_regression.py): the gate runs in CI on every PR, so
its own failure modes -- crashing on null/missing baseline metrics,
comparing TP rows across mismatched queue depths, letting a missing
metric pass silently -- are regressions in their own right.
"""
import importlib.util
import pathlib

import pytest

_SPEC = importlib.util.spec_from_file_location(
    "check_bench_regression",
    pathlib.Path(__file__).resolve().parents[1]
    / "scripts" / "check_bench_regression.py")
gate = importlib.util.module_from_spec(_SPEC)
_SPEC.loader.exec_module(gate)


def _row(params="p", depth=8, **kw):
    r = dict(params=params, queue_depth=depth, tok_per_s=100.0,
             prefill_tok_per_s=500.0, ttft_s=0.01)
    r.update(kw)
    return r


def _compare(new_rows, base_rows, tol=0.2, tol_prefill=0.6, tol_ttft=2.0):
    return gate.compare(dict(runs=new_rows), dict(runs=base_rows),
                        tol, tol_prefill, tol_ttft)


# ---------------------------------------------------------------------------
# null / missing baseline metrics must skip their gate, never crash
# ---------------------------------------------------------------------------

def test_null_baseline_metrics_skip_not_crash(capsys):
    """A hand-edited baseline row carrying explicit JSON nulls for every
    gated metric: floors/ceilings must not be computed from None (the
    historical TypeError), the row passes, and the report line renders."""
    base = [_row(tok_per_s=None, prefill_tok_per_s=None, ttft_s=None,
                 prefix_hit_rate=None)]
    assert _compare([_row(prefix_hit_rate=0.0)], base) == 0
    assert "--" in capsys.readouterr().out          # null rendered, not 8.1f


def test_null_prefill_only(capsys):
    """Nulls are per-metric: a null prefill baseline skips ONLY that
    gate; a genuine decode regression on the same row still fails."""
    base = [_row(prefill_tok_per_s=None)]
    assert _compare([_row(tok_per_s=10.0)], base) == 1
    assert "decode" in capsys.readouterr().out


def test_absent_baseline_metric_skips(capsys):
    """A metric absent from the baseline dict entirely (old baselines
    predate some metrics) skips that gate."""
    b = _row()
    del b["ttft_s"]
    assert _compare([_row(ttft_s=99.0)], [b]) == 0


def test_new_run_missing_metric_fails(capsys):
    """The baseline HAS the metric but the new run dropped it: a
    reporting regression, failed as '<metric>-missing'."""
    r = _row()
    del r["prefill_tok_per_s"]
    assert _compare([r], [_row()]) == 1
    assert "prefill-missing" in capsys.readouterr().out


def test_within_tolerance_passes():
    assert _compare([_row(tok_per_s=90.0, ttft_s=0.02)], [_row()]) == 0


def test_no_common_pairs_is_an_error():
    assert _compare([_row("a")], [_row("b")]) == 2


# ---------------------------------------------------------------------------
# check_tp_sliced: per-queue-depth comparison, missing metrics fail
# ---------------------------------------------------------------------------

def _tp(depth, tp, mm, dec, pre):
    return _row(f"tp{tp}_{mm}", depth, tp=tp, tp_matmul=mm,
                tok_per_s=dec, prefill_tok_per_s=pre)


def test_tp_sliced_compares_same_depth():
    """tp=1 rows at two depths: each sliced row must gate against the
    tp=1 row at ITS depth, not base1[0] arbitrarily. The d8 sliced row
    beats tp=1@d8 but would LOSE to tp=1@d32 -- correct per-depth
    comparison passes both."""
    rows = [_tp(8, 1, "padded", 100, 400), _tp(32, 1, "padded", 900, 900),
            _tp(8, 2, "sliced", 150, 500), _tp(32, 2, "sliced", 950, 950)]
    assert gate.check_tp_sliced(dict(runs=rows)) == 0


def test_tp_sliced_fails_per_depth():
    rows = [_tp(8, 1, "padded", 100, 400), _tp(32, 1, "padded", 900, 900),
            _tp(8, 2, "sliced", 150, 500), _tp(32, 2, "sliced", 850, 950)]
    assert gate.check_tp_sliced(dict(runs=rows)) == 1


def test_tp_sliced_missing_metric_fails_not_crashes(capsys):
    """A sliced row with no prefill_tok_per_s used to KeyError inside
    max(); now it counts as a structural failure with a message."""
    r = _tp(8, 2, "sliced", 150, 500)
    del r["prefill_tok_per_s"]
    rows = [_tp(8, 1, "padded", 100, 400), r]
    assert gate.check_tp_sliced(dict(runs=rows)) == 1
    assert "missing" in capsys.readouterr().out


def test_tp_sliced_null_decode_fails_not_crashes():
    rows = [_tp(8, 1, "padded", 100, 400),
            _tp(8, 2, "sliced", None, 500)]
    assert gate.check_tp_sliced(dict(runs=rows)) >= 1


def test_tp_sliced_unmatched_depth_skipped(capsys):
    """A sliced row at a depth with no tp=1 counterpart has nothing to
    compare against: skipped with a message, not compared cross-depth."""
    rows = [_tp(8, 1, "padded", 100, 400), _tp(32, 2, "sliced", 50, 50)]
    assert gate.check_tp_sliced(dict(runs=rows)) == 0
    assert "SKIP" in capsys.readouterr().out


def test_tp_sliced_no_tp_rows_skips():
    assert gate.check_tp_sliced(dict(runs=[_row()])) == 0


# ---------------------------------------------------------------------------
# check_disagg: the mono-vs-disagg structural gate
# ---------------------------------------------------------------------------

def _mono(depth=8, tokens=80):
    return _row("mono", depth, disagg="mono", tokens=tokens)


def _dis(depth=8, tokens=80, migrated=6, hit=1.0):
    return _row("dis", depth, disagg="1p1d", tokens=tokens,
                migrated_pages=migrated, prefix_hit_rate=hit)


def test_disagg_pair_passes():
    assert gate.check_disagg(dict(runs=[_mono(), _dis()])) == 0


def test_disagg_token_mismatch_fails(capsys):
    """The structural echo of the parity contract: disagg must serve
    exactly the mono token count at the same depth."""
    assert gate.check_disagg(dict(runs=[_mono(), _dis(tokens=79)])) == 1
    assert "tokens 79 != mono 80" in capsys.readouterr().out


def test_disagg_no_migration_fails():
    assert gate.check_disagg(dict(runs=[_mono(), _dis(migrated=0)])) == 1


def test_disagg_cold_decode_tier_fails():
    assert gate.check_disagg(dict(runs=[_mono(), _dis(hit=0.0)])) == 1


def test_disagg_null_fields_fail_not_crash(capsys):
    assert gate.check_disagg(dict(runs=[
        _mono(), _dis(tokens=None, migrated=None, hit=None)])) == 3
    assert "missing" in capsys.readouterr().out


def test_disagg_unmatched_depth_fails():
    assert gate.check_disagg(dict(runs=[_mono(8), _dis(32)])) == 1


def test_disagg_absent_rows_skip():
    assert gate.check_disagg(dict(runs=[_row()])) == 0


# ---------------------------------------------------------------------------
# check_recurrent_prefill: batched fixed-grid prefill must beat the
# same-run exact-length oracle; checkpoint prefix rows must hit
# ---------------------------------------------------------------------------

def _rec(mode="batched", family="ssm", pre=500.0, exact=50.0, hit=1.0):
    r = _row(f"fp32_{family}_{mode}", 8, family=family, prefill_mode=mode,
             prefill_tok_per_s=pre)
    if mode == "batched":
        r["exact_prefill_tok_per_s"] = exact
    else:
        r["prefix_hit_rate"] = hit
    return r


def test_recurrent_batched_beats_exact_passes():
    assert gate.check_recurrent_prefill(dict(runs=[
        _rec(), _rec(family="hybrid"), _rec("prefix_on")])) == 0


def test_recurrent_batched_slower_than_exact_fails(capsys):
    assert gate.check_recurrent_prefill(dict(runs=[
        _rec(pre=40.0, exact=50.0)])) == 1
    assert "exact-length" in capsys.readouterr().out


def test_recurrent_missing_oracle_fails_not_crashes(capsys):
    assert gate.check_recurrent_prefill(dict(runs=[
        _rec(exact=None)])) == 1
    assert "missing" in capsys.readouterr().out


def test_recurrent_cold_checkpoint_cache_fails():
    assert gate.check_recurrent_prefill(dict(runs=[
        _rec("prefix_on", hit=0.0)])) == 1
    assert gate.check_recurrent_prefill(dict(runs=[
        _rec("prefix_on", hit=None)])) == 1


def test_recurrent_absent_rows_skip():
    """KV-family rows (no ``family``/``prefill_mode`` fields) never
    trigger the recurrent gate."""
    assert gate.check_recurrent_prefill(dict(runs=[_row(), _mono()])) == 0


# ---------------------------------------------------------------------------
# check_policy_auto: auto policy must dominate-or-match default_serve_mix
# on quality AND size, and beat the pure anchors when present
# ---------------------------------------------------------------------------

def _pol(policy, arch="tinyllama-1.1b", kl=0.2, by=1000, **kw):
    r = dict(params=f"policy_{policy}_{arch}", queue_depth=4,
             policy=policy, policy_arch=arch, kl=kl, model_bytes=by)
    r.update(kw)
    return r


def test_policy_auto_dominates_passes():
    rows = [_pol("auto", kl=0.2, by=1000),
            _pol("default_serve_mix", kl=0.3, by=1000)]
    assert gate.check_policy_auto(dict(runs=rows)) == 0


def test_policy_auto_worse_quality_fails(capsys):
    rows = [_pol("auto", kl=0.4, by=900),
            _pol("default_serve_mix", kl=0.3, by=1000)]
    assert gate.check_policy_auto(dict(runs=rows)) == 1
    assert "kl" in capsys.readouterr().out


def test_policy_auto_larger_fails():
    rows = [_pol("auto", kl=0.2, by=1100),
            _pol("default_serve_mix", kl=0.3, by=1000)]
    assert gate.check_policy_auto(dict(runs=rows)) == 1


def test_policy_auto_missing_fields_fail_not_crash(capsys):
    rows = [_pol("auto", kl=None, by=None),
            _pol("default_serve_mix", kl=0.3, by=1000)]
    assert gate.check_policy_auto(dict(runs=rows)) == 2
    assert "missing" in capsys.readouterr().out


def test_policy_auto_no_default_row_fails():
    assert gate.check_policy_auto(dict(runs=[_pol("auto")])) == 1


def test_policy_auto_anchors_gated():
    rows = [_pol("auto", kl=0.2, by=1000),
            _pol("default_serve_mix", kl=0.3, by=1000),
            _pol("pure_q2_k", kl=0.45, by=900),
            _pol("pure_q6_k", kl=0.01, by=1600)]
    assert gate.check_policy_auto(dict(runs=rows)) == 0
    rows[2]["kl"] = 0.1                      # auto no longer beats q2_k
    assert gate.check_policy_auto(dict(runs=rows)) == 1
    rows[2]["kl"] = 0.45
    rows[3]["model_bytes"] = 900             # nor smaller than q6_k
    assert gate.check_policy_auto(dict(runs=rows)) == 1


def test_policy_auto_per_arch_pairing():
    """Rows pair within an arch; an arch with only anchors is ignored."""
    rows = [_pol("auto", arch="a", kl=0.2, by=1000),
            _pol("default_serve_mix", arch="a", kl=0.3, by=1000),
            _pol("pure_q2_k", arch="b", kl=0.5, by=900)]
    assert gate.check_policy_auto(dict(runs=rows)) == 0


def test_policy_auto_absent_rows_skip():
    assert gate.check_policy_auto(dict(runs=[_row(), _mono()])) == 0


def test_compare_runs_structural_gates():
    """compare() folds every same-run structural gate into its exit
    code even when every cross-run pair is within tolerance."""
    rows = [_row(), _mono(), _dis(migrated=0)]
    assert _compare(rows, [_row()]) == 1
    rows = [_row(), _rec(pre=40.0, exact=50.0)]
    assert _compare(rows, [_row()]) == 1
    rows = [_row(), _pol("auto", kl=0.4, by=900),
            _pol("default_serve_mix", kl=0.3, by=1000)]
    assert _compare(rows, [_row()]) == 1


def test_compare_gates_tail_ttft():
    """e2e rows now carry ttft_p99_s: it rides the same growth ceiling
    as mean TTFT, skips on baselines that predate it, and fails when the
    new run drops it."""
    base = [_row(ttft_p99_s=0.01)]
    assert _compare([_row(ttft_p99_s=0.02)], base) == 0   # within 3x
    assert _compare([_row(ttft_p99_s=0.05)], base) == 1   # above ceiling
    assert _compare([_row()], base) == 1                  # dropped
    assert _compare([_row(ttft_p99_s=9.9)], [_row()]) == 0  # old baseline


# ---------------------------------------------------------------------------
# check_trace: the trace_serve gate (tail TTFT ceiling, goodput floor,
# arrival-time accounting pinned structurally)
# ---------------------------------------------------------------------------

def _trow(mix="chat", rate=8.0, **kw):
    r = dict(mix=mix, rate_rps=rate, params="p", requests=20,
             completed=20, ttft_p50_s=0.02, ttft_p99_s=0.05,
             ttft_runentry_p50_s=0.04, ttft_runentry_p99_s=0.09,
             itl_p50_s=0.001, itl_p99_s=0.004, goodput_frac=0.95)
    r.update(kw)
    return r


def _trace(rows, mixes=("chat",), summary=None):
    if summary is None:
        summary = {m: dict(saturation_rps=8.0, rates_met=[8.0])
                   for m in mixes}
    return dict(benchmark="trace_serve",
                workload=dict(mixes={m: {} for m in mixes}),
                runs=rows, summary=summary)


def _ctrace(new_rows, base_rows, tol_ttft=2.0, drop=0.25, **kw):
    return gate.check_trace(_trace(new_rows, **kw), _trace(base_rows),
                            tol_ttft, drop)


def test_trace_within_tolerance_passes():
    assert _ctrace([_trow(ttft_p99_s=0.08, goodput_frac=0.8)],
                   [_trow()]) == 0


def test_trace_seeded_ttft_regression_fails(capsys):
    """The gate's reason to exist: a tail-TTFT blowup at matched offered
    load (> the 3x growth ceiling) fails."""
    assert _ctrace([_trow(ttft_p99_s=0.5, ttft_runentry_p99_s=0.6)],
                   [_trow()]) == 1
    assert "ttft_p99" in capsys.readouterr().out


def test_trace_goodput_floor_is_absolute(capsys):
    """goodput_frac is a ratio in [0,1]: the floor is an absolute drop
    (0.25), not fractional -- 0.95 -> 0.65 fails, 0.95 -> 0.75 passes."""
    assert _ctrace([_trow(goodput_frac=0.75)], [_trow()]) == 0
    assert _ctrace([_trow(goodput_frac=0.65)], [_trow()]) == 1
    assert "goodput" in capsys.readouterr().out


def test_trace_arrival_accounting_pinned(capsys):
    """Structural echo of the TTFT bugfix: arrival-stamped percentiles
    exceeding the run-entry-stamped ones recorded alongside them is
    impossible under correct stamping (run() entry precedes every
    mid-cycle arrival), so it fails even with no baseline mismatch."""
    assert _ctrace([_trow(ttft_p99_s=0.10, ttft_runentry_p99_s=0.09)],
                   [_trow()]) == 1
    assert "runentry" in capsys.readouterr().out


def test_trace_missing_fields_fail_not_crash(capsys):
    r = _trow(itl_p99_s=None)
    del r["goodput_frac"]
    assert _ctrace([r], [_trow()]) == 1
    out = capsys.readouterr().out
    assert "itl_p99_s-missing" in out and "goodput_frac-missing" in out
    assert "goodput-dropped" in out       # baseline had it, new run lost it


def test_trace_absent_baseline_metric_skips():
    """Baselines predating a metric skip that gate (same contract as
    compare); the structural checks still run on the new row."""
    b = _trow()
    del b["ttft_p99_s"], b["goodput_frac"]
    assert _ctrace([_trow(ttft_p99_s=9.9, ttft_runentry_p99_s=10.0,
                          goodput_frac=0.0)], [b]) == 0


def test_trace_no_common_rows_is_an_error():
    assert _ctrace([_trow(mix="chat")], [_trow(mix="mixed")]) == 2


def test_trace_missing_saturation_summary_fails(capsys):
    assert _ctrace([_trow()], [_trow()],
                   summary={"chat": dict(rates_met=[])}) == 1
    assert "saturation_rps" in capsys.readouterr().out
