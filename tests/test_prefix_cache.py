"""Paged KV prefix-cache suite.

THE oracle: greedy output with the prefix cache ON must be TOKEN-IDENTICAL
to the same engine with it OFF -- across attention families (causal,
sliding-window ring wrap, int8-KV), through full-prefix re-hits,
partial-page (mid-page divergence / copy-on-write) hits, mixed warm+cold
admission groups, eviction-then-rehit under a tiny page budget, and with
speculative decoding riding on top. The guarantee holds because cached
pages are bit-for-bit copies of the KV rows a cold prefill writes, and the
suffix-only chunked prefill reuses the same masked-chunk program family
whose chunk-placement invariance test_engine_scheduler already pins.

The radix tree itself (matching, partial hits, refcount-by-children, LRU
eviction, capacity budget) is unit-tested host-side without a device.
"""
import jax
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.models import transformer as T
from repro.serving.engine import Engine, ServeConfig
from repro.serving.prefix_cache import PrefixCache


@pytest.fixture(scope="module")
def causal():
    cfg = get_arch("tinyllama-1.1b", reduced=True)
    return cfg, T.init_params(cfg, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def windowed():
    cfg = get_arch("h2o-danube-1.8b", reduced=True)      # window = 64
    return cfg, T.init_params(cfg, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def int8kv():
    cfg = get_arch("llama3.2-1b", reduced=True).replace(
        kv_cache_quant=True, dtype="float32")
    return cfg, T.init_params(cfg, jax.random.PRNGKey(0))


def _mk(model, prefix=False, **kw):
    cfg, params = model
    base = dict(max_new_tokens=5, cache_len=64, decode_chunk=5,
                max_slots=2, prefill_bucket=4, prefill_chunk=16,
                prefix_cache=prefix, prefix_page=8)
    base.update(kw)
    return Engine(cfg, params, ServeConfig(**base))


def _shared_prompts(cfg, n, shared_len=24, uniq=(3, 9), seed=0):
    rng = np.random.default_rng(seed)
    shared = list(rng.integers(0, cfg.vocab_size, shared_len))
    return [shared + list(rng.integers(0, cfg.vocab_size,
                                       int(rng.integers(*uniq))))
            for _ in range(n)]


# ---------------------------------------------------------------------------
# engine parity: prefix cache ON == OFF, token for token
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fixture", ["causal", "windowed", "int8kv"])
def test_greedy_parity_on_vs_off(fixture, request):
    """Shared-prefix queue generated twice: cycle 1 populates the tree
    (cold + mixed groups), cycle 2 is fully warm. Both must match the
    cache-off engine exactly, and the warm cycle must actually reuse."""
    model = request.getfixturevalue(fixture)
    cfg, _ = model
    prompts = _shared_prompts(cfg, 5, seed=1)
    off, on = _mk(model), _mk(model, prefix=True)
    assert off.generate(prompts) == on.generate(prompts)     # cold+mixed
    assert off.generate(prompts) == on.generate(prompts)     # fully warm
    assert on.stats["prefix_hits"] == 5
    assert on.stats["prefix_tokens_reused"] >= 5 * 24


def test_partial_page_cow_hit(causal):
    """A prompt diverging MID-page from a cached branch reuses the shared
    leading rows of that page (copy-on-write: the pool page stays intact,
    the slot ring's divergent tail is recomputed) -- token-identical, and
    the original branch still re-hits unharmed afterwards."""
    cfg, _ = causal
    rng = np.random.default_rng(2)
    A = list(rng.integers(0, cfg.vocab_size, 21))
    B = A[:12] + list(rng.integers(0, cfg.vocab_size, 9))   # diverge at 12
    off, on = _mk(causal), _mk(causal, prefix=True)
    assert off.generate([A]) == on.generate([A])
    assert off.generate([B]) == on.generate([B])
    # page=8: one full page + 4 rows of A's second page
    assert on.stats["prefix_tokens_reused"] == 12
    assert off.generate([A]) == on.generate([A])            # A unharmed
    assert on.stats["prefix_tokens_reused"] == 16           # its 2 pages


def test_mixed_cold_and_warm_group_parity(causal):
    """A cache-hit request fused into the SAME prefill group as a
    brand-new one: the group's chunk grid starts at the cold row's 0, so
    the warm row's cached columns are masked mid-grid (compute runs,
    writes drop, ring supplies the keys) -- the overlap-masking path,
    distinct from whole-chunk skipping. Short and multi-chunk cold
    partners, both token-identical."""
    cfg, _ = causal
    rng = np.random.default_rng(8)
    A = list(rng.integers(0, cfg.vocab_size, 22))
    B = list(rng.integers(0, cfg.vocab_size, 9))      # cold, shorter
    C = list(rng.integers(0, cfg.vocab_size, 30))     # cold, multi-chunk
    off, on = _mk(causal), _mk(causal, prefix=True)
    assert off.generate([A]) == on.generate([A])      # cache A
    assert off.generate([A, B]) == on.generate([A, B])
    assert on.stats["prefix_hits"] == 1
    assert off.generate([A, C]) == on.generate([A, C])


def test_eviction_then_rehit_parity(causal):
    """A pool of 3 pages thrashes under 4 distinct 17-token prompts;
    outputs stay identical to cache-off across repeated cycles and
    eviction counters move."""
    cfg, params = causal
    page_bytes = T.cache_page_bytes(cfg, 8)
    off = _mk(causal, max_new_tokens=4, decode_chunk=4)
    on = _mk(causal, prefix=True, max_new_tokens=4, decode_chunk=4,
             prefix_bytes=3 * page_bytes)
    assert on._prefix.capacity == 3
    rng = np.random.default_rng(3)
    prompts = [list(rng.integers(0, cfg.vocab_size, 17)) for _ in range(4)]
    for _ in range(3):
        assert off.generate(prompts) == on.generate(prompts)
    assert on._prefix.evictions > 0
    assert on._prefix.pages_in_use <= 3


def test_window_arch_long_prompt_skips_insertion(windowed):
    """Sliding-window arch with a prompt longer than the 64-slot ring:
    early pages are overwritten by ring wrap, so insertion skips it, but
    shorter prompts still cache and reuse -- all token-identical."""
    cfg, _ = windowed
    rng = np.random.default_rng(4)
    shared = list(rng.integers(0, cfg.vocab_size, 40))
    prompts = [shared + list(rng.integers(0, cfg.vocab_size, k))
               for k in (5, 9, 40)]                         # last: 80 > 64
    off, on = _mk(windowed), _mk(windowed, prefix=True)
    for _ in range(2):
        assert off.generate(prompts) == on.generate(prompts)
    assert on.stats["prefix_hits"] >= 2


def test_spec_decode_rides_prefix_cache(causal):
    """Speculative decoding over a warm prefix cache: both features
    together still match the plain cache-off engine token for token."""
    cfg, _ = causal
    prompts = _shared_prompts(cfg, 4, seed=5)
    ref = _mk(causal, max_new_tokens=8, decode_chunk=10).generate(prompts)
    eng = _mk(causal, prefix=True, max_new_tokens=8, decode_chunk=10,
              drafter="ngram", draft_k=3)
    assert eng.generate(prompts) == ref                     # cold
    assert eng.generate(prompts) == ref                     # warm
    assert eng.stats["prefix_hits"] > 0


def test_temperature_parity_on_vs_off(causal):
    """Sampling-mode parity: the warm path must consume the identical
    per-request key stream (keys split in queue order), so temperature
    outputs match the cache-off engine too."""
    cfg, _ = causal
    prompts = _shared_prompts(cfg, 4, seed=6)
    off = _mk(causal, temperature=0.8, seed=9)
    on = _mk(causal, prefix=True, temperature=0.8, seed=9)
    for _ in range(2):
        assert off.generate(prompts) == on.generate(prompts)
    assert on.stats["prefix_hits"] == 4


# ---------------------------------------------------------------------------
# recurrent families: checkpoint-mode prefix cache (warm == cold == off)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def mamba2():
    cfg = get_arch("mamba2-2.7b", reduced=True)
    return cfg, T.init_params(cfg, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def zamba2():
    cfg = get_arch("zamba2-1.2b", reduced=True)
    return cfg, T.init_params(cfg, jax.random.PRNGKey(0))


def _mk_rec(model, prefix=False, **kw):
    cfg, params = model
    base = dict(max_new_tokens=4, cache_len=64, decode_chunk=4,
                max_slots=2, prefill_bucket=4, prefill_chunk=16,
                prefix_cache=prefix)
    base.update(kw)
    return Engine(cfg, params, ServeConfig(**base))


def test_recurrent_page_pins_to_prefill_chunk(mamba2):
    """Recurrent families now SUPPORT prefix caching (checkpoint mode);
    the page size is pinned to the prefill chunk so checkpoints are
    exactly the inter-chunk state carries the scheduler materializes
    anyway (regression: this combination used to raise "KV-ring")."""
    eng = _mk_rec(mamba2, prefix=True, prefix_page=8)   # 8 ignored
    assert eng._page == eng._chunk == 16
    assert eng._caps.prefix_mode == "checkpoints"


@pytest.mark.parametrize("fixture", ["mamba2", "zamba2"])
def test_recurrent_greedy_parity_on_vs_off(fixture, request):
    """Shared-prefix queue generated twice on an SSM / hybrid engine:
    cycle 1 checkpoints state at page boundaries (cold + mixed groups),
    cycle 2 restores them. Both must match the cache-off engine token
    for token, and the warm cycle must actually reuse state."""
    model = request.getfixturevalue(fixture)
    cfg, _ = model
    prompts = _shared_prompts(cfg, 3, shared_len=24, uniq=(4, 8), seed=11)
    off, on = _mk_rec(model), _mk_rec(model, prefix=True)
    assert off.generate(prompts) == on.generate(prompts)     # cold+mixed
    assert off.generate(prompts) == on.generate(prompts)     # fully warm
    # every row of the warm cycle restores the 16-token boundary <= 24
    assert on.stats["prefix_hits"] >= 3
    assert on.stats["prefix_tokens_reused"] >= 3 * 16


@pytest.mark.parametrize("fixture", ["mamba2", "zamba2"])
def test_recurrent_temperature_parity_on_vs_off(fixture, request):
    """Sampling-mode parity for checkpoint restores: the warm path must
    consume the identical per-request key stream, so temperature outputs
    match the cache-off engine too."""
    model = request.getfixturevalue(fixture)
    cfg, _ = model
    prompts = _shared_prompts(cfg, 3, shared_len=24, uniq=(4, 8), seed=12)
    off = _mk_rec(model, temperature=0.8, seed=7)
    on = _mk_rec(model, prefix=True, temperature=0.8, seed=7)
    for _ in range(2):
        assert off.generate(prompts) == on.generate(prompts)
    assert on.stats["prefix_hits"] >= 3


def test_recurrent_eviction_then_rehit_parity(mamba2):
    """Checkpoint pool of 3 pages thrashes under 4 distinct prompts;
    outputs stay identical to cache-off and eviction counters move."""
    cfg, _ = mamba2
    page_bytes = T.cache_page_bytes(cfg, 16)
    off = _mk_rec(mamba2)
    on = _mk_rec(mamba2, prefix=True, prefix_bytes=3 * page_bytes)
    assert on._prefix.capacity == 3
    rng = np.random.default_rng(13)
    prompts = [list(rng.integers(0, cfg.vocab_size, 20)) for _ in range(4)]
    for _ in range(3):
        assert off.generate(prompts) == on.generate(prompts)
    assert on._prefix.evictions > 0
    assert on._prefix.pages_in_use <= 3


def test_recurrent_mixed_cold_and_warm_group_parity(zamba2):
    """A checkpoint-hit request fused into the SAME prefill group as a
    brand-new one: checkpoint matching takes the group MINIMUM boundary
    (any cold row forces s0 = 0, a shorter warm row lowers s0 for all),
    so the mixed group must stay token-identical while reusing what the
    group allows."""
    cfg, _ = zamba2
    rng = np.random.default_rng(14)
    A = list(rng.integers(0, cfg.vocab_size, 22))
    B = list(rng.integers(0, cfg.vocab_size, 9))      # cold group-mate
    off, on = _mk_rec(zamba2), _mk_rec(zamba2, prefix=True)
    assert off.generate([A]) == on.generate([A])      # checkpoint A
    assert off.generate([A, B]) == on.generate([A, B])  # cold drags s0 to 0
    assert off.generate([A]) == on.generate([A])      # A still re-hits
    assert on.stats["prefix_hits"] >= 1
    assert on.stats["prefix_tokens_reused"] >= 16


def test_page_clamps_to_ring_divisor(causal):
    """prefix_page must tile the ring: 48 does not divide a 64-slot ring,
    so it clamps down to a divisor (32) instead of letting pages wrap
    internally."""
    eng = _mk(causal, prefix=True, prefix_page=48, cache_len=64)
    assert eng._page == 32


# ---------------------------------------------------------------------------
# host-side radix tree unit tests (no device)
# ---------------------------------------------------------------------------

def test_radix_match_insert_roundtrip():
    pc = PrefixCache(page=4, capacity=8)
    toks = list(range(10))                   # pages [0..4) [4..8), tail 8,9
    assert pc.match(toks) == (0, [])
    new = pc.insert(toks)
    assert [p0 for _, p0 in new] == [0, 4]
    assert pc.pages_in_use == 2
    m, pages = pc.match(toks)
    assert m == 8 and [(p0, take) for _, p0, take in pages] == [(0, 4),
                                                               (4, 4)]
    # matching is capped at len-1: a 5-token prompt reuses only 4 rows
    m, pages = pc.match(toks[:5])
    assert m == 4
    # partial-page: diverge inside page 2
    m, pages = pc.match([0, 1, 2, 3, 4, 5, 9, 9, 9])
    assert m == 6
    assert pages[-1][2] == 2                 # take = 2 rows of page [4..8)
    # no duplicate insertion for an already-cached prefix
    assert pc.insert(toks) == []
    assert pc.pages_in_use == 2


def test_radix_refcount_and_lru_eviction():
    pc = PrefixCache(page=2, capacity=3)
    pc.insert([1, 2, 3, 4])                  # chain: (1,2) -> (3,4)
    pc.insert([1, 2, 5, 6])                  # branch: (1,2) -> (5,6)
    assert pc.pages_in_use == 3
    root_child = pc._root.children[(1, 2)]
    assert root_child.refcount == 2          # two children pin it
    # LRU: (3,4) is the stalest leaf; (1,2) is not evictable (children)
    new = pc.insert([7, 8])
    assert len(new) == 1 and pc.evictions == 1
    assert (3, 4) not in root_child.children
    assert (5, 6) in root_child.children
    # evicted branch re-inserts cleanly (rehit path)
    assert len(pc.insert([1, 2, 3, 4])) == 1


def test_radix_batched_insert_protect_no_index_recycle():
    """Two insertions batched into ONE device copy share a ``protect``
    set: the second must not evict (and recycle the pool index of) a
    page the first just allocated -- duplicate destinations in a single
    batched scatter are undefined in XLA (regression: intra-group
    eviction handed request B the pool row request A's fresh page was
    about to be copied into)."""
    pc = PrefixCache(page=8, capacity=3)
    protect: set = set()
    a = list(range(17))
    b = list(range(100, 117))
    new_a = pc.insert(a, protect)              # fills 2 of 3 pool rows
    new_b = pc.insert(b, protect)              # needs 2, only 1 free
    idx_a = {i for i, _ in new_a}
    idx_b = {i for i, _ in new_b}
    assert len(new_a) == 2 and len(new_b) == 1   # b's tail dropped, not
    assert not (idx_a & idx_b)                   # a's pages recycled
    assert pc.evictions == 0
    assert pc.match(a)[0] == 16                  # a fully intact
    # WITHOUT a shared set the same sequence would evict a's stale leaf:
    pc2 = PrefixCache(page=8, capacity=3)
    pc2.insert(a)
    assert len(pc2.insert(b)) == 2 and pc2.evictions == 1


def test_radix_capacity_exhaustion_drops_tail():
    pc = PrefixCache(page=2, capacity=2)
    new = pc.insert([1, 2, 3, 4, 5, 6])      # 3 pages into a 2-page pool
    assert len(new) == 2                     # tail dropped...
    assert pc.insert_drops == 1              # ...and COUNTED, not silent
    assert pc.match([1, 2, 3, 4, 5, 6])[0] == 4   # ...prefix still usable
    # the insertion path itself is protected from eviction: inserting a
    # longer chain never evicts its own ancestors
    pc2 = PrefixCache(page=2, capacity=2)
    pc2.insert([1, 2, 3, 4, 5, 6, 7, 8])
    assert pc2.match([1, 2, 3, 4])[0] == 3   # chain prefix intact (cap 3)
    assert pc2.insert_drops == 2             # both tail pages
    # re-inserting the resident prefix allocates nothing and drops nothing
    pc2.insert([1, 2, 3, 4])
    assert pc2.insert_drops == 2


def test_engine_surfaces_insert_drops_stat(causal):
    """A pool too small for the workload's page chains silently dropped
    insertion tails (by design -- serving must not fail); the drop count
    must surface as the ``prefix_insert_drops`` engine stat so saturated
    pools are diagnosable, with parity untouched (regression: the stat
    did not exist)."""
    cfg, _ = causal
    rng = np.random.default_rng(21)
    P = list(rng.integers(0, cfg.vocab_size, 28))   # 3 full pages @ page=8
    off = _mk(causal)
    tiny = _mk(causal, prefix=True, prefix_bytes=1)  # floor: 2-page pool
    expect = off.generate([P])
    assert tiny.generate([P]) == expect             # parity regardless
    assert tiny.stats["prefix_insert_drops"] == 1   # 3rd page dropped
    assert tiny.generate([P]) == expect             # resident prefix reused
    assert tiny.stats["prefix_insert_drops"] == 1   # re-dropped tail
    assert tiny.stats["prefix_hits"] == 1
    big = _mk(causal, prefix=True)                  # default 64 MiB budget
    assert big.generate([P]) == expect
    assert big.stats["prefix_insert_drops"] == 0
