"""Mixed-quantization policy tests: Table III reproduction (layer counts +
model sizes) and policy mechanics."""
import pytest

from repro.core import policy as POL
from repro.configs.base import get_arch


def _llama_matmuls(cfg):
    """(path, K, N) for every MatMul layer, llama-family."""
    d, L = cfg.d_model, cfg.n_layers
    H, KH, Dh, f, V = (cfg.n_heads, cfg.n_kv_heads, cfg.d_head, cfg.d_ff,
                       cfg.vocab_size)
    out = []
    for i in range(L):
        out += [
            (f"layers/attn/wq", d, H * Dh), (f"layers/attn/wk", d, KH * Dh),
            (f"layers/attn/wv", d, KH * Dh), (f"layers/attn/wo", H * Dh, d),
            (f"layers/mlp/w_gate", d, f), (f"layers/mlp/w_up", d, f),
            (f"layers/mlp/w_down", f, d),
        ]
    out.append(("lm_head", d, V))
    return out


def _gpt2_matmuls(cfg):
    d, L, f, V = cfg.d_model, cfg.n_layers, cfg.d_ff, cfg.vocab_size
    out = []
    for i in range(L):
        out += [("layers/attn/c_attn", d, 3 * d),
                ("layers/attn/c_proj", d, d),
                ("layers/mlp/c_fc", d, f),
                ("layers/mlp/c_proj", f, d)]
    out.append(("lm_head", d, V))
    return out


# paper Table III ground truth: (arch, q2_layers, q3_layers, size_MB)
TABLE_III = [
    ("gpt2-paper", 25, 24, 77),
    ("tinyllama-1.1b", 45, 110, 460),
    ("mobilellama-1.4b", 49, 120, 560),
]


@pytest.mark.parametrize("arch,q2,q3,size_mb", TABLE_III)
def test_table3_layer_counts(arch, q2, q3, size_mb):
    cfg = get_arch(arch)
    if arch == "gpt2-paper":
        mms = _gpt2_matmuls(cfg)
        pol = POL.get_policy("paper_gpt2_mix")
        extra = [("wte", cfg.vocab_size * cfg.d_model),
                 ("wpe", cfg.max_position * cfg.d_model)]
    else:
        mms = _llama_matmuls(cfg)
        pol = POL.get_policy("paper_llama_mix")
        extra = []
    summ = POL.summarize(pol, mms, extra_f16=extra)
    counts = summ["counts"]
    assert counts.get("q2_k", 0) == q2, counts
    assert counts.get("q3_k", 0) == q3, counts


@pytest.mark.parametrize("arch,q2,q3,size_mb", TABLE_III)
def test_table3_model_sizes(arch, q2, q3, size_mb):
    """Model sizes within 8% of the paper's Table III (gguf bit-density)."""
    cfg = get_arch(arch)
    if arch == "gpt2-paper":
        mms = _gpt2_matmuls(cfg)
        pol = POL.get_policy("paper_gpt2_mix")
        # gguf stores wte quantized (policy maps it) + wpe fp16
        mms = mms + [("wte", cfg.d_model, cfg.vocab_size)]
        extra = [("wpe", cfg.max_position * cfg.d_model)]
    else:
        mms = _llama_matmuls(cfg)
        pol = POL.get_policy("paper_llama_mix")
        mms = mms + [("wte", cfg.d_model, cfg.vocab_size)]
        extra = []
    summ = POL.summarize(pol, mms, extra_f16=extra)
    got_mb = summ["size_bytes_gguf"] / 1e6
    assert abs(got_mb - size_mb) / size_mb < 0.08, (got_mb, size_mb)


def test_paper_param_counts():
    """Table III parameter counts: GPT2 163M (untied head), TinyLlama 1.1B,
    MobileLLaMA 1.4B."""
    import numpy as np
    for arch, expect in [("gpt2-paper", 163e6), ("tinyllama-1.1b", 1.1e9),
                         ("mobilellama-1.4b", 1.4e9)]:
        cfg = get_arch(arch)
        mms = (_gpt2_matmuls(cfg) if arch == "gpt2-paper"
               else _llama_matmuls(cfg))
        n = sum(K * N for _, K, N in mms)
        n += cfg.vocab_size * cfg.d_model          # wte
        if cfg.pos_emb == "learned":
            n += cfg.max_position * cfg.d_model
        assert abs(n - expect) / expect < 0.06, (arch, n)


def test_policy_fallback_k_not_multiple_of_256():
    pol = POL.get_policy("default_serve_mix")
    assert pol.variant_for("layers/mlp/w_down", 29568, 8192) == "q8_0"
    assert pol.variant_for("layers/mlp/w_down", 8192, 2048) == "q3_k"


def test_policy_first_match_wins():
    pol = POL.make_policy("t", [("*attn/wk", "q2_k"), ("*attn/*", "q6_k")])
    assert pol.variant_for("layers/attn/wk", 512, 512) == "q2_k"
    assert pol.variant_for("layers/attn/wq", 512, 512) == "q6_k"
    assert pol.variant_for("layers/mlp/w_up", 512, 512) == "q3_k"  # default


def test_policy_none_and_small():
    pol = POL.make_policy("t", [("*norm*", "none")])
    assert pol.variant_for("layers/norm/w", 512, 512) is None
    assert pol.variant_for("x", 512, 8) is None     # N too small
