"""Mixed-quantization policy tests: Table III reproduction (layer counts +
model sizes) and policy mechanics."""
import pytest

from repro.core import policy as POL
from repro.configs.base import get_arch


def _llama_matmuls(cfg):
    """(path, K, N) for every MatMul layer, llama-family."""
    d, L = cfg.d_model, cfg.n_layers
    H, KH, Dh, f, V = (cfg.n_heads, cfg.n_kv_heads, cfg.d_head, cfg.d_ff,
                       cfg.vocab_size)
    out = []
    for i in range(L):
        out += [
            (f"layers/attn/wq", d, H * Dh), (f"layers/attn/wk", d, KH * Dh),
            (f"layers/attn/wv", d, KH * Dh), (f"layers/attn/wo", H * Dh, d),
            (f"layers/mlp/w_gate", d, f), (f"layers/mlp/w_up", d, f),
            (f"layers/mlp/w_down", f, d),
        ]
    out.append(("lm_head", d, V))
    return out


def _gpt2_matmuls(cfg):
    d, L, f, V = cfg.d_model, cfg.n_layers, cfg.d_ff, cfg.vocab_size
    out = []
    for i in range(L):
        out += [("layers/attn/c_attn", d, 3 * d),
                ("layers/attn/c_proj", d, d),
                ("layers/mlp/c_fc", d, f),
                ("layers/mlp/c_proj", f, d)]
    out.append(("lm_head", d, V))
    return out


# paper Table III ground truth: (arch, q2_layers, q3_layers, size_MB)
TABLE_III = [
    ("gpt2-paper", 25, 24, 77),
    ("tinyllama-1.1b", 45, 110, 460),
    ("mobilellama-1.4b", 49, 120, 560),
]


@pytest.mark.parametrize("arch,q2,q3,size_mb", TABLE_III)
def test_table3_layer_counts(arch, q2, q3, size_mb):
    cfg = get_arch(arch)
    if arch == "gpt2-paper":
        mms = _gpt2_matmuls(cfg)
        pol = POL.get_policy("paper_gpt2_mix")
        extra = [("wte", cfg.vocab_size * cfg.d_model),
                 ("wpe", cfg.max_position * cfg.d_model)]
    else:
        mms = _llama_matmuls(cfg)
        pol = POL.get_policy("paper_llama_mix")
        extra = []
    summ = POL.summarize(pol, mms, extra_f16=extra)
    counts = summ["counts"]
    assert counts.get("q2_k", 0) == q2, counts
    assert counts.get("q3_k", 0) == q3, counts


@pytest.mark.parametrize("arch,q2,q3,size_mb", TABLE_III)
def test_table3_model_sizes(arch, q2, q3, size_mb):
    """Model sizes within 8% of the paper's Table III (gguf bit-density)."""
    cfg = get_arch(arch)
    if arch == "gpt2-paper":
        mms = _gpt2_matmuls(cfg)
        pol = POL.get_policy("paper_gpt2_mix")
        # gguf stores wte quantized (policy maps it) + wpe fp16
        mms = mms + [("wte", cfg.d_model, cfg.vocab_size)]
        extra = [("wpe", cfg.max_position * cfg.d_model)]
    else:
        mms = _llama_matmuls(cfg)
        pol = POL.get_policy("paper_llama_mix")
        mms = mms + [("wte", cfg.d_model, cfg.vocab_size)]
        extra = []
    summ = POL.summarize(pol, mms, extra_f16=extra)
    got_mb = summ["size_bytes_gguf"] / 1e6
    assert abs(got_mb - size_mb) / size_mb < 0.08, (got_mb, size_mb)


def test_paper_param_counts():
    """Table III parameter counts: GPT2 163M (untied head), TinyLlama 1.1B,
    MobileLLaMA 1.4B."""
    import numpy as np
    for arch, expect in [("gpt2-paper", 163e6), ("tinyllama-1.1b", 1.1e9),
                         ("mobilellama-1.4b", 1.4e9)]:
        cfg = get_arch(arch)
        mms = (_gpt2_matmuls(cfg) if arch == "gpt2-paper"
               else _llama_matmuls(cfg))
        n = sum(K * N for _, K, N in mms)
        n += cfg.vocab_size * cfg.d_model          # wte
        if cfg.pos_emb == "learned":
            n += cfg.max_position * cfg.d_model
        assert abs(n - expect) / expect < 0.06, (arch, n)


def test_policy_fallback_k_not_multiple_of_256():
    pol = POL.get_policy("default_serve_mix")
    assert pol.variant_for("layers/mlp/w_down", 29568, 8192) == "q8_0"
    assert pol.variant_for("layers/mlp/w_down", 8192, 2048) == "q3_k"


def test_policy_first_match_wins():
    pol = POL.make_policy("t", [("*attn/wk", "q2_k"), ("*attn/*", "q6_k")])
    assert pol.variant_for("layers/attn/wk", 512, 512) == "q2_k"
    assert pol.variant_for("layers/attn/wq", 512, 512) == "q6_k"
    assert pol.variant_for("layers/mlp/w_up", 512, 512) == "q3_k"  # default


def test_policy_none_and_small():
    pol = POL.make_policy("t", [("*norm*", "none")])
    assert pol.variant_for("layers/norm/w", 512, 512) is None
    assert pol.variant_for("x", 512, 8) is None     # N too small


# --------------------------------------------------------------------------
# variant_for guard regressions
# --------------------------------------------------------------------------

def test_small_k_multiple_of_32_stays_fp():
    """Regression: the guard used to read ``K < MIN_QUANT_K and
    K % 32 != 0``, which let K=64 (a multiple of 32 below the floor)
    quantize, contradicting the module docs ('tensors smaller than this
    along K stay unquantized')."""
    pol = POL.pure("q3_k")
    for K in (32, 64, 128, 224):
        assert pol.variant_for("layers/attn/wq", K, 512) is None, K
    assert pol.variant_for("layers/attn/wq", 256, 512) == "q3_k"


def test_ragged_k_returns_none_not_raise():
    """Regression: K >= 256 with K % 32 != 0 used to reach
    ``pick_fallback`` and raise ValueError, aborting quantize_params for
    the whole model over one odd-shaped tensor."""
    pol = POL.pure("q3_k")
    for K in (257, 300, 1000):
        assert pol.variant_for("layers/attn/wq", K, 512) is None, K


def test_quantize_params_survives_ragged_k_tree():
    import jax
    import jax.numpy as jnp
    from repro.core.qlinear import quantize_params
    from repro.core.quantize import QTensor
    key = jax.random.PRNGKey(0)
    params = {"layers": {"attn": {
        "wq": jax.random.normal(key, (512, 64)),
        "wx": jax.random.normal(key, (300, 64)),    # ragged K
        "wy": jax.random.normal(key, (64, 64)),     # K below floor
    }}}
    qp, report = quantize_params(params, POL.pure("q3_k"))
    assert report["layers/attn/wq"] == "q3_k"
    assert report["layers/attn/wx"] is None
    assert report["layers/attn/wy"] is None
    assert isinstance(qp["layers"]["attn"]["wq"], QTensor)
    assert isinstance(qp["layers"]["attn"]["wx"], jnp.ndarray)


def test_variant_for_grid_always_packs():
    """Property sweep over the K grid: whenever variant_for returns a
    variant, qtensor_spec must succeed for it as-is (the fallback was
    already applied -- no second fallback, no raise); whenever it returns
    None, one of the documented reasons must hold."""
    from repro.core import quantize as Q
    pols = [POL.get_policy("default_serve_mix"), POL.pure("q2_k"),
            POL.pure("q6_k"), POL.pure("q8_0")]
    Ks = [1, 8, 31, 32, 64, 96, 224, 255, 256, 257, 288, 300, 320,
          512, 768, 992, 1000, 1024]
    Ns = [1, 8, 31, 32, 64, 257]
    for pol in pols:
        for K in Ks:
            for N in Ns:
                v = pol.variant_for("layers/attn/wq", K, N)
                if v is None:
                    assert K < POL.MIN_QUANT_K or K % 32 != 0 \
                        or N < POL.MIN_QUANT_N, (pol.name, K, N)
                    continue
                spec = Q.qtensor_spec(v, K, N)
                assert spec.variant == v, (pol.name, K, N, v)


def test_preset_rules_not_shadowed():
    """Every rule in every preset is reachable: a representative path
    built from the pattern must hit that rule first."""
    for pol in POL.POLICIES.values():
        for i, (pat, _) in enumerate(pol.rules):
            path = pat.replace("*", "x")
            hits = [j for j, (p, _) in enumerate(pol.rules)
                    if POL.fnmatch.fnmatch(path, p)]
            assert hits and hits[0] == i, (pol.name, pat, hits)


def test_summarize_matches_brute_force():
    from repro.core import formats as F
    cfg = get_arch("tinyllama-1.1b")
    mms = _llama_matmuls(cfg)
    pol = POL.get_policy("paper_llama_mix")
    summ = POL.summarize(pol, mms)
    counts, size = {}, 0.0
    for path, K, N in mms:
        v = pol.variant_for(path, K, N)
        counts[v or "f16"] = counts.get(v or "f16", 0) + 1
        size += K * N * (2 if v is None
                         else F.get_format(v).bits_per_weight / 8.0)
    assert summ["counts"] == counts
    assert summ["size_bytes"] == int(size)


# --------------------------------------------------------------------------
# searched-policy serialization (--policy auto)
# --------------------------------------------------------------------------

def test_policy_serialization_roundtrip(tmp_path):
    pol = POL.make_policy("auto_test", [("layers/attn/wq", "q4_k"),
                                        ("lm_head", "q3_k_o")],
                          default="none")
    path = tmp_path / "pol.json"
    POL.save_policy(pol, path)
    back = POL.load_policy(path)
    assert back == pol
    # exact paths act as exact-match rules; default "none" keeps the rest fp
    assert back.variant_for("layers/attn/wq", 512, 512) == "q4_k"
    assert back.variant_for("lm_head", 512, 512) == "q3_k_o"
    assert back.variant_for("layers/attn/wk", 512, 512) is None


def test_policy_from_dict_rejects_unknown_variant():
    with pytest.raises(ValueError):
        POL.policy_from_dict({"rules": [["x", "q9_z"]]})
    with pytest.raises(ValueError):
        POL.policy_from_dict({"rules": [], "default": "q9_z"})
