"""Engine fuzz: randomized submit/cancel/EOS schedules with a parity
oracle.

Two engines over the same weights -- one admitting in batched prefill
groups, one strictly one-request-at-a-time -- are driven through identical
randomized schedules (waves of ragged submits, cancels of queued requests,
EOS on or off, greedy or temperature sampling). Every wave must produce
token-for-token identical results, including across batched-admission
boundaries (queues deeper than the slot count force mid-stream admission
into freed slots).

A third check pins the batched engine to ``generate_reference`` (the
host-driven per-token loop), closing the triangle: batched == sequential
== reference.

Runs are seeded and deterministic under both real hypothesis and the
offline ``tests/_hypothesis_stub.py`` fallback.
"""
import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import get_arch
from repro.models import transformer as T
from repro.serving.engine import Engine, ServeConfig

MAX_NEW = 6


@pytest.fixture(scope="module")
def pairs():
    """(batched, sequential) engine pairs, one per sampling/EOS mode.

    Built once: reusing engine instances across fuzz examples keeps every
    example on already-compiled programs, and both members of a pair see
    identical schedules so their PRNG streams stay in lockstep."""
    cfg = get_arch("tinyllama-1.1b", reduced=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))

    def mk(**kw):
        base = dict(max_new_tokens=MAX_NEW, cache_len=64, decode_chunk=4,
                    max_slots=3, prefill_bucket=4, prefill_chunk=8)
        base.update(kw)
        return (Engine(cfg, params, ServeConfig(prefill_batch=3, **base)),
                Engine(cfg, params, ServeConfig(prefill_batch=1, **base)))

    # an EOS id that greedy decode actually emits (probe run), so EOS
    # schedules really cut sequences short mid-stream
    probe, _ = mk()
    eos = probe.generate([[7, 3, 11]])[0][1]
    return dict(cfg=cfg,
                greedy=mk(),
                eos=mk(eos_id=eos),
                temp=mk(temperature=0.9, seed=11))


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**20), mode=st.sampled_from(
    ["greedy", "eos", "temp"]))
def test_fuzz_schedule_parity(pairs, seed, mode):
    cfg = pairs["cfg"]
    batched, seq = pairs[mode]
    rng = np.random.default_rng(seed)
    for _wave in range(int(rng.integers(1, 3))):
        n = int(rng.integers(1, 9))
        ids_b, ids_s = [], []
        for _ in range(n):
            prompt = rng.integers(0, cfg.vocab_size,
                                  int(rng.integers(1, 13))).tolist()
            budget = int(rng.integers(1, MAX_NEW + 1))
            ids_b.append(batched.submit(prompt, max_new_tokens=budget))
            ids_s.append(seq.submit(prompt, max_new_tokens=budget))
        # cancel a random subset while still queued (same ids on both
        # sides: submit order is identical, so id counters are too)
        for i in rng.permutation(n)[:int(rng.integers(0, n))]:
            if rng.integers(0, 2):
                assert batched.cancel(ids_b[i]) == seq.cancel(ids_s[i])
        res_b, res_s = batched.run(), seq.run()
        assert res_b == res_s
        assert set(res_b) == set(ids_b)
        for rid in ids_b:
            assert len(res_b[rid]) <= MAX_NEW


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 2**20))
def test_fuzz_parity_with_reference_loop(pairs, seed):
    """Batched engine vs the host-driven per-token reference on random
    ragged batches (<= max_slots, the reference path has no queue)."""
    cfg = pairs["cfg"]
    batched, _ = pairs["greedy"]
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab_size,
                            int(rng.integers(1, 13))).tolist()
               for _ in range(int(rng.integers(1, 4)))]
    assert batched.generate(prompts) == \
        batched.generate_reference(prompts)
