"""Engine fuzz: randomized submit/cancel/EOS/speculation schedules with a
parity oracle.

Two engines over the same weights -- one admitting in batched prefill
groups, one strictly one-request-at-a-time -- are driven through identical
randomized schedules (waves of ragged submits incl. prompts long enough
to force multi-chunk prefill, cancels of queued requests, per-request
speculation toggles, EOS on or off, greedy or temperature sampling).
Every wave must produce token-for-token identical results, including
across batched-admission boundaries (queues deeper than the slot count
force mid-stream admission into freed slots).

A second fuzz drives IN-FLIGHT cancels: on_token callbacks cancel random
victims at random trigger points, so cancels land while victims are
queued, mid-admission (between a long prompt's prefill chunks and its
slot binding), or running. Greedy only -- greedy tokens are slot-layout
independent, so batched and sequential admission must still agree even
though a mid-admission cancel perturbs the two schedulers' slot
assignments differently.

A third check pins the batched engine to ``generate_reference`` (the
host-driven per-token loop), closing the triangle: batched == sequential
== reference.

A family axis drives the SAME randomized schedules through ssm, hybrid,
and moe engines (mamba2 / zamba2 / olmoe reduced): recurrent families
now ride the batched masked-chunk prefill path, so batched-vs-sequential
parity is a real scheduler property there too, not a vacuous one.

Runs are seeded and deterministic under both real hypothesis and the
offline ``tests/_hypothesis_stub.py`` fallback.
"""
import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import get_arch
from repro.models import transformer as T
from repro.serving.engine import Engine, ServeConfig

MAX_NEW = 6
MAX_PROMPT = 22          # > prefill_chunk: long prompts stream in chunks


@pytest.fixture(scope="module")
def pairs():
    """(batched, sequential) engine pairs, one per sampling/EOS mode.

    Built once: reusing engine instances across fuzz examples keeps every
    example on already-compiled programs, and both members of a pair see
    identical schedules so their PRNG streams stay in lockstep. The
    greedy and EOS pairs carry an ngram drafter so schedules can toggle
    speculation per request."""
    cfg = get_arch("tinyllama-1.1b", reduced=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))

    def mk(**kw):
        base = dict(max_new_tokens=MAX_NEW, cache_len=64, decode_chunk=4,
                    max_slots=3, prefill_bucket=4, prefill_chunk=8)
        base.update(kw)
        return (Engine(cfg, params, ServeConfig(prefill_batch=3, **base)),
                Engine(cfg, params, ServeConfig(prefill_batch=1, **base)))

    spec = dict(drafter="ngram", draft_k=3)
    # an EOS id that greedy decode actually emits (probe run), so EOS
    # schedules really cut sequences short mid-stream
    probe, _ = mk()
    eos = probe.generate([[7, 3, 11]])[0][1]
    return dict(cfg=cfg,
                greedy=mk(**spec),
                eos=mk(eos_id=eos, **spec),
                temp=mk(temperature=0.9, seed=11))


def _drive_waves(cfg, batched, seq, rng):
    """Shared wave driver: identical randomized submit/cancel schedules
    into two engines; every wave must agree token-for-token."""
    has_drafter = batched.scfg.drafter is not None
    for _wave in range(int(rng.integers(1, 3))):
        n = int(rng.integers(1, 9))
        ids_b, ids_s = [], []
        for _ in range(n):
            prompt = rng.integers(0, cfg.vocab_size,
                                  int(rng.integers(1, MAX_PROMPT))).tolist()
            budget = int(rng.integers(1, MAX_NEW + 1))
            spec = bool(rng.integers(0, 2)) if has_drafter else None
            ids_b.append(batched.submit(prompt, max_new_tokens=budget,
                                        speculate=spec))
            ids_s.append(seq.submit(prompt, max_new_tokens=budget,
                                    speculate=spec))
        # cancel a random subset while still queued (same ids on both
        # sides: submit order is identical, so id counters are too)
        for i in rng.permutation(n)[:int(rng.integers(0, n))]:
            if rng.integers(0, 2):
                assert batched.cancel(ids_b[i]) == seq.cancel(ids_s[i])
        res_b, res_s = batched.run(), seq.run()
        assert res_b == res_s
        assert set(res_b) == set(ids_b)
        for rid in ids_b:
            assert len(res_b[rid]) <= MAX_NEW


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**20), mode=st.sampled_from(
    ["greedy", "eos", "temp"]))
def test_fuzz_schedule_parity(pairs, seed, mode):
    cfg = pairs["cfg"]
    batched, seq = pairs[mode]
    _drive_waves(cfg, batched, seq, np.random.default_rng(seed))


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 2**20))
def test_fuzz_inflight_cancels_parity(pairs, seed):
    """Callback-driven cancels at random trigger points: victims may be
    queued, between a long prompt's prefill chunks and slot binding
    (mid-admission), or running with a partial stream. Greedy, so the
    slot-layout perturbation a mid-admission cancel causes cannot change
    any surviving request's tokens -- batched and sequential admission
    must agree request-for-request (cancelled prefixes included)."""
    cfg = pairs["cfg"]
    batched, seq = pairs["greedy"]
    rng = np.random.default_rng(seed)
    n = int(rng.integers(3, 8))
    prompts = [rng.integers(0, cfg.vocab_size,
                            int(rng.integers(1, MAX_PROMPT))).tolist()
               for _ in range(n)]
    spec = [bool(rng.integers(0, 2)) for _ in range(n)]
    # ONE canceller per wave: before any cancel both schedulers are in
    # lockstep, so the cancel lands at an identical logical state; a
    # mid-admission cancel may perturb the two engines' subsequent slot
    # layouts, which greedy tokens don't observe -- but a SECOND cancel's
    # within-chunk ordering could, so waves carry a single cancel
    plans = {int(rng.integers(0, n)):
             (int(rng.integers(0, n)),                  # victim index
              int(rng.integers(1, MAX_NEW + 1)))}       # trigger count

    def run(eng):
        counts = {}
        ids = []

        def mk_cb(idx):
            def cb(rid, tok):
                c = counts[rid] = counts.get(rid, 0) + 1
                victim, trig = plans.get(idx, (None, None))
                if victim is not None and c == trig:
                    eng.cancel(ids[victim])
            return cb
        for i, p in enumerate(prompts):
            ids.append(eng.submit(p, on_token=mk_cb(i),
                                  speculate=spec[i]))
        res = eng.run()
        return ids, res

    ids_b, res_b = run(batched)
    ids_s, res_s = run(seq)
    assert [res_b[i] for i in ids_b] == [res_s[i] for i in ids_s]
    assert set(res_b) == set(ids_b)
    for rid in ids_b:
        assert len(res_b[rid]) <= MAX_NEW
    # both engines drain cleanly afterwards
    assert batched.generate([[1, 2, 3]]) == seq.generate([[1, 2, 3]])


def test_cancel_between_prefill_chunks_of_long_prompt(pairs):
    """Deterministic pin of the mid-admission window: request A's
    first-token callback cancels long-prompt request B. Sequentially B is
    still queued; batched, B's multi-chunk prefill has already run inside
    A's admission group but its slot is not bound yet -- both must report
    cancel()==True, emit nothing for B, and leave everyone else
    untouched."""
    cfg = pairs["cfg"]
    batched, seq = pairs["greedy"]
    rng = np.random.default_rng(123)
    long_prompt = rng.integers(0, cfg.vocab_size, 21).tolist()  # 3 chunks
    short = rng.integers(0, cfg.vocab_size, 3).tolist()

    def run(eng):
        ids = {}
        cancelled = {}
        def cb(rid, tok):
            if not cancelled:
                cancelled[0] = eng.cancel(ids["b"])
        ids["a"] = eng.submit(short, on_token=cb)
        ids["b"] = eng.submit(long_prompt)
        ids["c"] = eng.submit(short)
        res = eng.run()
        return ids, res, cancelled[0]

    ids_b, res_b, ok_b = run(batched)
    ids_s, res_s, ok_s = run(seq)
    assert ok_b and ok_s
    assert res_b[ids_b["b"]] == res_s[ids_s["b"]] == []
    assert res_b[ids_b["a"]] == res_s[ids_s["a"]]
    assert res_b[ids_b["c"]] == res_s[ids_s["c"]]
    assert len(res_b[ids_b["a"]]) == MAX_NEW


# -- family axis: the same randomized schedules through the non-dense
# families. No drafter (speculation needs a KV ring; moe could carry one
# but the axis targets admission/cancel scheduling, not speculation) --
# waves toggle nothing per-request, so parity isolates the scheduler.

@pytest.fixture(scope="module")
def family_pairs():
    """(cfg, batched, sequential) per family. ssm exercises the fixed
    recurrent chunk grid greedy; hybrid runs under temperature so the
    warm key-stream discipline is fuzzed too; moe runs with an emitted
    EOS id so schedules cut sequences short mid-stream."""
    def mk(arch, probe_eos=False, **kw):
        cfg = get_arch(arch, reduced=True)
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        base = dict(max_new_tokens=MAX_NEW, cache_len=64, decode_chunk=4,
                    max_slots=3, prefill_bucket=4, prefill_chunk=8)
        base.update(kw)
        if probe_eos:
            probe = Engine(cfg, params, ServeConfig(**base))
            base["eos_id"] = probe.generate([[7, 3, 11]])[0][1]
        return (cfg,
                Engine(cfg, params, ServeConfig(prefill_batch=3, **base)),
                Engine(cfg, params, ServeConfig(prefill_batch=1, **base)))
    return {"ssm": mk("mamba2-2.7b"),
            "hybrid": mk("zamba2-1.2b", temperature=0.8, seed=5),
            "moe": mk("olmoe-1b-7b", probe_eos=True)}


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 2**20),
       family=st.sampled_from(["ssm", "hybrid", "moe"]))
def test_fuzz_schedule_parity_across_families(family_pairs, seed, family):
    cfg, batched, seq = family_pairs[family]
    _drive_waves(cfg, batched, seq, np.random.default_rng(seed))


# -- tensor-parallel axis: the same randomized schedules, but the
# batched engine runs under a shard_map TP mesh and is compared against
# the single-device one-request-at-a-time oracle. Needs forced host
# devices (XLA_FLAGS=--xla_force_host_platform_device_count=N before
# jax initializes); skips under the plain 1-device tier-1 run, runs in
# the forced-4-device CI job and test_tp_serving's acceptance command.

@pytest.fixture(scope="module")
def tp_pairs():
    """(tp=2 batched, tp=1 sequential) engines per mesh size: the TP
    padded datapath is bit-identical to single-device, so every schedule
    must agree token-for-token -- speculation toggles included."""
    if len(jax.devices()) < 2:
        pytest.skip("needs >= 2 devices (XLA_FLAGS="
                    "--xla_force_host_platform_device_count)")
    cfg = get_arch("tinyllama-1.1b", reduced=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    base = dict(max_new_tokens=MAX_NEW, cache_len=64, decode_chunk=4,
                max_slots=3, prefill_bucket=4, prefill_chunk=8,
                drafter="ngram", draft_k=3)
    sizes = [tp for tp in (2, 4) if tp <= len(jax.devices())]
    return dict(cfg=cfg, sizes=sizes, engines={
        tp: (Engine(cfg, params, ServeConfig(prefill_batch=3, tp=tp,
                                             **base)),
             Engine(cfg, params, ServeConfig(prefill_batch=1, **base)))
        for tp in sizes})


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 2**20), size_idx=st.integers(0, 1))
def test_fuzz_schedule_parity_under_tp(tp_pairs, seed, size_idx):
    cfg = tp_pairs["cfg"]
    sizes = tp_pairs["sizes"]
    tp = sizes[min(size_idx, len(sizes) - 1)]
    batched, seq = tp_pairs["engines"][tp]
    _drive_waves(cfg, batched, seq, np.random.default_rng(seed))


@pytest.fixture(scope="module", params=["sliced", "sliced_row"])
def tp_sliced_pairs(request):
    """(tp=2 sliced batched, tp=2 sliced sequential) engine pairs.

    The sliced datapaths only promise ulp-level logit agreement with
    tp=1 (shape-dependent gemm rounding / K-reduction reorder), so the
    oracle here runs the SAME datapath sequentially: batched-admission
    parity is a property of the scheduler, independent of which gemm
    datapath runs underneath, and within one datapath it is exact --
    batched and sequential runs differ only in the gemm M (row)
    dimension, which XLA computes row-independently. Every schedule
    must agree token-for-token, speculation toggles included (scan
    verify replays the same sliced decode program)."""
    if len(jax.devices()) < 2:
        pytest.skip("needs >= 2 devices (XLA_FLAGS="
                    "--xla_force_host_platform_device_count)")
    cfg = get_arch("tinyllama-1.1b", reduced=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    base = dict(max_new_tokens=MAX_NEW, cache_len=64, decode_chunk=4,
                max_slots=3, prefill_bucket=4, prefill_chunk=8,
                drafter="ngram", draft_k=3, tp=2,
                tp_matmul=request.param)
    return dict(cfg=cfg, engines=(
        Engine(cfg, params, ServeConfig(prefill_batch=3, **base)),
        Engine(cfg, params, ServeConfig(prefill_batch=1, **base))))


@settings(max_examples=3, deadline=None)
@given(seed=st.integers(0, 2**20))
def test_fuzz_schedule_parity_tp2_sliced(tp_sliced_pairs, seed):
    cfg = tp_sliced_pairs["cfg"]
    batched, seq = tp_sliced_pairs["engines"]
    _drive_waves(cfg, batched, seq, np.random.default_rng(seed))


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 2**20))
def test_fuzz_parity_with_reference_loop(pairs, seed):
    """Batched engine vs the host-driven per-token reference on random
    ragged batches (<= max_slots, the reference path has no queue).
    Speculation off for the wave: generate_reference is the PLAIN decode
    oracle (greedy spec parity vs plain decode lives in
    test_spec_decode.py)."""
    cfg = pairs["cfg"]
    batched, _ = pairs["greedy"]
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab_size,
                            int(rng.integers(1, 13))).tolist()
               for _ in range(int(rng.integers(1, 4)))]
    ids = [batched.submit(list(p), speculate=False) for p in prompts]
    res = batched.run()
    assert [res[i] for i in ids] == batched.generate_reference(prompts)
