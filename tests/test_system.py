"""End-to-end behaviour tests for the paper's system."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (ARCH_IDS, SHAPES, get_arch, input_specs,
                                shape_applicable)


def test_all_archs_registered_with_exact_assigned_configs():
    """Every assigned architecture resolves with the exact spec from the
    assignment brief."""
    expect = {
        # arch: (L, d_model, H, KH, d_ff, vocab)
        "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152064),
        "llama3.2-1b": (16, 2048, 32, 8, 8192, 128256),
        "qwen3-1.7b": (28, 2048, 16, 8, 6144, 151936),
        "phi3-mini-3.8b": (32, 3072, 32, 32, 8192, 32064),
        "h2o-danube-1.8b": (24, 2560, 32, 8, 6912, 32000),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "mamba2-2.7b": (64, 2560, 0, 0, 0, 50280),
    }
    for arch, (L, d, H, KH, f, V) in expect.items():
        cfg = get_arch(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab_size) == (L, d, H, KH, f, V), arch


def test_moe_and_ssm_specs():
    g = get_arch("granite-moe-3b-a800m")
    assert (g.n_experts, g.n_experts_active) == (40, 8)
    o = get_arch("olmoe-1b-7b")
    assert (o.n_experts, o.n_experts_active) == (64, 8)
    z = get_arch("zamba2-1.2b")
    assert z.ssm_state == 64
    m = get_arch("mamba2-2.7b")
    assert m.ssm_state == 128


def test_shape_grid_is_40_cells():
    cells = [(a, s) for a in ARCH_IDS[:10] for s in SHAPES]
    assert len(cells) == 40
    runnable = skipped = 0
    for a, s in cells:
        ok, why = shape_applicable(get_arch(a), SHAPES[s])
        if ok:
            runnable += 1
        else:
            skipped += 1
            assert s == "long_500k" and "sub-quadratic" in why
    # long_500k runs only for ssm / hybrid / SWA archs (3 of 10)
    assert skipped == 7 and runnable == 33


def test_input_specs_cover_every_cell():
    for a in ARCH_IDS[:10]:
        cfg = get_arch(a)
        for s, shape in SHAPES.items():
            if not shape_applicable(cfg, shape)[0]:
                continue
            specs = input_specs(cfg, shape)
            assert specs, (a, s)
            for v in specs.values():
                assert isinstance(v, jax.ShapeDtypeStruct)
            if shape.kind == "train":
                assert "labels" in specs
            if shape.kind == "decode":
                assert "position" in specs
            # stub frontends provide embeddings, not tokens
            if not cfg.embed_input:
                assert "tokens" not in specs


def test_every_reduced_arch_has_same_family():
    for a in ARCH_IDS:
        full, red = get_arch(a), get_arch(a, reduced=True)
        assert full.family == red.family
        assert red.n_layers <= 4 and red.d_model <= 256


def test_public_api_imports():
    import repro.core.formats
    import repro.core.quantize
    import repro.core.policy
    import repro.core.qlinear
    import repro.core.isa
    import repro.kernels.ops
    import repro.kernels.ref
    import repro.kernels.bfp_matmul
    import repro.kernels.q8k_quant
    import repro.models.transformer
    import repro.models.mamba2
    import repro.models.moe
    import repro.serving.engine
    import repro.training.loop
    import repro.checkpoint.ckpt
    import repro.data.pipeline
    import repro.distributed.sharding
    import repro.distributed.compress
    import repro.launch.mesh
    import repro.launch.analysis
    import repro.launch.flops
