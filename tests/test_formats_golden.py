"""Golden packing tests: bit-exact round-trips and slab-layout invariants
against hand-computed super-blocks.

The weights here are CONSTRUCTED so that the one-shot quantizer's fit is
exact: every block's extreme values pin the intended block scale/min, the
intended super-scales are fp16-exact powers of two, and every value sits
on its reconstruction grid. That turns quantize() into a pure
pack-and-store whose every payload byte we can predict by hand -- any
layout drift (slab order, nibble packing, scale bias) fails loudly
instead of hiding inside a tolerance.

Covers the paper's native variants (Q2_K, Q3_K), the headline 4-bit
variants (Q4_0, Q4_K), a beyond-paper one (Q6_K), plus Q8_0 and an
independent re-implementation of the slab rule.
"""
import jax.numpy as jnp
import numpy as np

from repro.core import formats as F
from repro.core import quantize as Q


def _slab_pack_ref(q: np.ndarray, bits: int, sb: int) -> np.ndarray:
    """Independent reimplementation of the slab layout contract: within
    each super-block of ``sb`` rows, bit-field j (shift j*bits) of packed
    row p holds original row j * (sb // F) + p."""
    Fpb = 8 // bits
    K, N = q.shape
    slab = sb // Fpb
    out = np.zeros((K // Fpb, N), np.uint8)
    for s in range(K // sb):                    # super-block
        for p in range(slab):                   # packed row within SB
            byte = np.zeros(N, np.uint8)
            for j in range(Fpb):                # bit-field
                byte |= (q[s * sb + j * slab + p] & ((1 << bits) - 1)) \
                    << (bits * j)
            out[s * slab + p] = byte
    return out


def test_slab_layout_invariant_vs_independent_packer():
    rng = np.random.default_rng(0)
    for bits, sb in [(1, 256), (2, 256), (4, 256), (2, 64)]:
        q = rng.integers(0, 1 << bits, size=(512, 3)).astype(np.uint8)
        np.testing.assert_array_equal(
            np.asarray(F.slab_pack(jnp.asarray(q), bits, sb)),
            _slab_pack_ref(q, bits, sb))
        np.testing.assert_array_equal(
            np.asarray(F.slab_unpack(jnp.asarray(
                _slab_pack_ref(q, bits, sb)), bits, sb)), q)


def _col_dup(a: np.ndarray, n: int = 2) -> np.ndarray:
    """(K,) -> (K, n) with column c scaled by 2**c (exercises per-lane
    independence of every scale field)."""
    return a[:, None] * (2.0 ** np.arange(n))[None, :]


def test_golden_q2_k_superblock():
    # block b: scale code b, min code 15-b, super-scales d=0.5, dmin=0.25;
    # in-block pattern [0,1,2,3]*4 pins bmax/bmin to the exact grid ends
    d, dmin = 0.5, 0.25
    sc_q = np.arange(16)                        # 0..15 (15 pins d)
    m_q = 15 - np.arange(16)                    # 15..0 (15 pins dmin)
    qpat = np.tile(np.arange(4), 4)             # (16,) values 0..3
    q = np.where(sc_q[:, None] > 0, qpat[None, :], 0)       # (16 blk, 16)
    w1 = (d * sc_q)[:, None] * q - (dmin * m_q)[:, None]    # (16, 16)
    w = _col_dup(w1.reshape(256))
    t = Q.quantize("q2_k", jnp.asarray(w, jnp.float32))
    assert t.variant == "q2_k" and t.shape == (256, 2)
    np.testing.assert_array_equal(
        np.asarray(t.data["scales"]),
        np.repeat((sc_q | (m_q << 4)).astype(np.uint8)[:, None], 2, axis=1))
    np.testing.assert_array_equal(np.asarray(t.data["d"], np.float32),
                                  [[d, 2 * d]])
    np.testing.assert_array_equal(np.asarray(t.data["dmin"], np.float32),
                                  [[dmin, 2 * dmin]])
    qkn = np.repeat(q.reshape(256)[:, None], 2, axis=1).astype(np.uint8)
    np.testing.assert_array_equal(np.asarray(t.data["qs"]),
                                  _slab_pack_ref(qkn, 2, 256))
    np.testing.assert_array_equal(np.asarray(Q.dequantize(t)), w)  # exact


def test_golden_q3_k_superblock():
    # block b: 6-bit scale code 2b+1 (31 pins d=0.25); q in [-4,3] with -4
    # present so amax/4 recovers the block scale exactly
    d = 0.25
    sc_q = 2 * np.arange(16) + 1                # 1..31 odd
    qpat = np.tile(np.arange(-4, 4), 2)         # (16,) includes -4
    w1 = (d * sc_q)[:, None] * qpat[None, :]    # (16, 16)
    w = _col_dup(w1.reshape(256))
    t = Q.quantize("q3_k", jnp.asarray(w, jnp.float32))
    np.testing.assert_array_equal(
        np.asarray(t.data["scales"]),
        np.repeat((sc_q + 32).astype(np.uint8)[:, None], 2, axis=1))
    np.testing.assert_array_equal(np.asarray(t.data["d"], np.float32),
                                  [[d, 2 * d]])
    stored = np.repeat((qpat + 4).astype(np.uint8)[None, :]
                       .repeat(16, 0).reshape(256)[:, None], 2, axis=1)
    np.testing.assert_array_equal(np.asarray(t.data["qs"]),
                                  _slab_pack_ref(stored & 3, 2, 256))
    np.testing.assert_array_equal(np.asarray(t.data["hmask"]),
                                  _slab_pack_ref(stored >> 2, 1, 256))
    np.testing.assert_array_equal(np.asarray(Q.dequantize(t)), w)


def test_golden_q6_k_superblock_beyond_paper():
    # block b: int8 scale code 127-8b (127 pins d=0.125); q in [-32,31]
    # with -32 present so amax/32 recovers the block scale exactly
    d = 0.125
    sc_q = 127 - 8 * np.arange(16)              # 127..7, all > 0
    qpat = np.array([-32, -16, -8, -4, -2, -1, 0, 1,
                     2, 4, 8, 16, 24, 30, 31, -31])
    w1 = (d * sc_q)[:, None] * qpat[None, :]
    w = _col_dup(w1.reshape(256))
    t = Q.quantize("q6_k", jnp.asarray(w, jnp.float32))
    np.testing.assert_array_equal(
        np.asarray(t.data["scales"]),
        np.repeat(sc_q.astype(np.int8)[:, None], 2, axis=1))
    np.testing.assert_array_equal(np.asarray(t.data["d"], np.float32),
                                  [[d, 2 * d]])
    stored = np.repeat((qpat + 32).astype(np.uint8)[None, :]
                       .repeat(16, 0).reshape(256)[:, None], 2, axis=1)
    np.testing.assert_array_equal(np.asarray(t.data["ql"]),
                                  _slab_pack_ref(stored & 15, 4, 256))
    np.testing.assert_array_equal(np.asarray(t.data["qh"]),
                                  _slab_pack_ref(stored >> 4, 2, 256))
    np.testing.assert_array_equal(np.asarray(Q.dequantize(t)), w)


def test_golden_q3_k_o_superblock_with_outlier_sidecar():
    # q3_k golden pattern (block b: scale code 2b+1, q in [-4,3]) plus 8
    # injected outlier rows per super-block with distinct huge magnitudes
    # at in-block offset 5 -- never the -4 row that pins the block scale,
    # so zeroing them for the base fit leaves every base value on its
    # exact grid. Descending magnitudes make the top_k order (and hence
    # the sidecar payload bytes) fully deterministic.
    d = 0.25
    sc_q = 2 * np.arange(16) + 1
    qpat = np.tile(np.arange(-4, 4), 2)
    base1 = ((d * sc_q)[:, None] * qpat[None, :]).reshape(256)
    orows = 16 * np.arange(8) + 5
    ovals1 = 100.0 * (8 - np.arange(8))         # 800..100, all fp16-exact
    wfull1 = base1.copy()
    wfull1[orows] = ovals1
    base1[orows] = 0.0
    w = _col_dup(wfull1)
    t = Q.quantize("q3_k_o", jnp.asarray(w, jnp.float32))
    assert t.variant == "q3_k_o" and t.shape == (256, 2)
    # sidecar: top-8 |w| rows per (SB, column), descending-score order
    np.testing.assert_array_equal(
        np.asarray(t.data["oidx"]),
        np.repeat(orows.astype(np.uint8)[:, None], 2, axis=1))
    np.testing.assert_array_equal(np.asarray(t.data["ovals"], np.float32),
                                  _col_dup(ovals1))
    # base payloads: the q3_k fit of the outlier-zeroed weights, every
    # byte predicted by hand (outlier rows store code 0+4 = 4)
    np.testing.assert_array_equal(
        np.asarray(t.data["scales"]),
        np.repeat((sc_q + 32).astype(np.uint8)[:, None], 2, axis=1))
    np.testing.assert_array_equal(np.asarray(t.data["d"], np.float32),
                                  [[d, 2 * d]])
    stored1 = (np.tile(qpat, 16) + 4).astype(np.uint8)
    stored1[orows] = 4
    stored = np.repeat(stored1[:, None], 2, axis=1)
    np.testing.assert_array_equal(np.asarray(t.data["qs"]),
                                  _slab_pack_ref(stored & 3, 2, 256))
    np.testing.assert_array_equal(np.asarray(t.data["hmask"]),
                                  _slab_pack_ref(stored >> 2, 1, 256))
    np.testing.assert_array_equal(np.asarray(Q.dequantize(t)), w)  # exact


def test_golden_q4_0_blocks():
    # block b: d pinned by the signed abs-max element mapping to code 0
    # (llama.cpp convention d = mval / -8): block 0 has a negative
    # extreme (d = +0.5), block 1 a positive extreme (d = -0.25 -- the
    # sign convention is part of the contract); in-block pattern covers
    # every 4-bit code
    qpat = np.tile(np.arange(16), 2)            # (32,) codes 0..15
    d_blocks = np.array([0.5, -0.25])
    w1 = (d_blocks[:, None] * (qpat[None, :] - 8.0)).reshape(64)
    w = _col_dup(w1)
    t = Q.quantize("q4_0", jnp.asarray(w, jnp.float32))
    assert t.variant == "q4_0" and t.shape == (64, 2)
    np.testing.assert_array_equal(
        np.asarray(t.data["d"], np.float32),
        np.stack([d_blocks, 2 * d_blocks], axis=1))
    qkn = np.repeat(qpat[None].repeat(2, 0).reshape(64)[:, None].astype(
        np.uint8), 2, axis=1)
    np.testing.assert_array_equal(np.asarray(t.data["qs"]),
                                  _slab_pack_ref(qkn, 4, 32))
    np.testing.assert_array_equal(np.asarray(Q.dequantize(t)), w)  # exact


def test_golden_q4_k_superblock():
    # 8 blocks of 32: 6-bit scale code 63-8b (63 pins d = 0.25), 6-bit
    # min code 8b+7 (63 pins dmin = 0.125); in-block pattern [0..15]*2
    # pins bmax/bmin to the exact affine grid ends
    d, dmin = 0.25, 0.125
    sc_q = 63 - 8 * np.arange(8)                # 63..7, all > 0
    m_q = 8 * np.arange(8) + 7                  # 7..63
    qpat = np.tile(np.arange(16), 2)            # (32,) values 0..15
    w1 = ((d * sc_q)[:, None] * qpat[None, :]
          - (dmin * m_q)[:, None])              # (8, 32)
    w = _col_dup(w1.reshape(256))
    t = Q.quantize("q4_k", jnp.asarray(w, jnp.float32))
    assert t.variant == "q4_k" and t.shape == (256, 2)
    np.testing.assert_array_equal(
        np.asarray(t.data["scales"]),
        np.repeat(sc_q.astype(np.uint8)[:, None], 2, axis=1))
    np.testing.assert_array_equal(
        np.asarray(t.data["mins"]),
        np.repeat(m_q.astype(np.uint8)[:, None], 2, axis=1))
    np.testing.assert_array_equal(np.asarray(t.data["d"], np.float32),
                                  [[d, 2 * d]])
    np.testing.assert_array_equal(np.asarray(t.data["dmin"], np.float32),
                                  [[dmin, 2 * dmin]])
    stored = np.repeat(qpat.astype(np.uint8)[None, :]
                       .repeat(8, 0).reshape(256)[:, None], 2, axis=1)
    np.testing.assert_array_equal(np.asarray(t.data["qs"]),
                                  _slab_pack_ref(stored, 4, 256))
    np.testing.assert_array_equal(np.asarray(Q.dequantize(t)), w)


def test_golden_q8_0_block():
    # one 32-block: d = 0.5 pinned by |q|=127; payload stores q verbatim
    qpat = np.concatenate([[127, -127, 0, 1, -1], np.arange(-13, 14)])
    assert qpat.shape == (32,)
    w = _col_dup(0.5 * qpat)
    t = Q.quantize("q8_0", jnp.asarray(w, jnp.float32))
    assert t.variant == "q8_0"
    np.testing.assert_array_equal(
        np.asarray(t.data["qs"]),
        np.repeat(qpat.astype(np.int8)[:, None], 2, axis=1))
    np.testing.assert_array_equal(np.asarray(t.data["d"], np.float32),
                                  [[0.5, 1.0]])
    np.testing.assert_array_equal(np.asarray(Q.dequantize(t)), w)
