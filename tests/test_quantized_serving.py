"""Quantized-serving integration: mixed BFP policies end-to-end through
forward/decode + the serving engine (the paper's deployment scenario)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.core.policy import get_policy
from repro.core.qlinear import quantize_params, quantized_param_bytes
from repro.models import transformer as T
from repro.serving.engine import Engine, ServeConfig


@pytest.mark.parametrize("arch", ["llama3.2-1b", "olmoe-1b-7b",
                                  "mamba2-2.7b", "zamba2-1.2b",
                                  "gpt2-paper"])
def test_quantized_forward_close_to_fp(arch):
    cfg = get_arch(arch, reduced=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    qp, report = quantize_params(params, get_policy("default_serve_mix"))
    variants = {v for v in report.values() if v}
    assert "q2_k" in variants and "q3_k" in variants  # genuinely mixed
    B, S = 2, 16
    kwargs = (dict(tokens=jax.random.randint(jax.random.PRNGKey(1), (B, S),
                                             0, cfg.vocab_size))
              if cfg.embed_input else
              dict(embeds=jax.random.normal(jax.random.PRNGKey(1),
                                            (B, S, cfg.d_model))))
    lg_f, _, _ = T.forward_seq(params, cfg, **kwargs)
    lg_q, _, _ = T.forward_seq(qp, cfg, **kwargs)
    assert bool(jnp.all(jnp.isfinite(lg_q)))
    # 2-3 bit quantization of RANDOM weights: logits correlated but not
    # equal. Recurrent families (ssm/hybrid) compound quantization error
    # through the state recurrence, so their bound is looser.
    floor = 0.45 if cfg.family in ("ssm", "hybrid", "moe") else 0.7
    a = np.asarray(lg_f).reshape(-1, cfg.vocab_size)
    b = np.asarray(lg_q).reshape(-1, cfg.vocab_size)
    cos = (a * b).sum(-1) / (np.linalg.norm(a, axis=-1)
                             * np.linalg.norm(b, axis=-1) + 1e-9)
    assert cos.mean() > floor, (cos.mean(), floor)


def test_quantized_decode_matches_quantized_full():
    """Cache path and full path must agree bit-for-bit *with the same
    quantized params* (quantization is deterministic)."""
    cfg = get_arch("tinyllama-1.1b", reduced=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    qp, _ = quantize_params(params, get_policy("paper_llama_mix"))
    B, S_pre, n_new = 2, 8, 4
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S_pre + n_new), 0,
                              cfg.vocab_size)
    lg_full, _, _ = T.forward_seq(qp, cfg, tokens=toks)
    _, _, caches = T.forward_seq(qp, cfg, want_cache=True,
                                 tokens=toks[:, :S_pre])
    cache = T.cache_from_prefill(cfg, caches, S_pre,
                                 cache_len=S_pre + n_new,
                                 dtype=jnp.float32)
    errs = []
    for t in range(n_new):
        pos = jnp.full((B,), S_pre + t, jnp.int32)
        lg, cache = T.decode_step(qp, cfg, cache, position=pos,
                                  tokens=toks[:, S_pre + t])
        errs.append(float(jnp.abs(lg - lg_full[:, S_pre + t]).max()))
    assert max(errs) / (float(jnp.abs(lg_full).max()) + 1e-9) < 2e-4


def test_memory_footprint_reduction():
    """The point of BFP quantization: packed weights are ~5x smaller."""
    cfg = get_arch("tinyllama-1.1b", reduced=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    qp, _ = quantize_params(params, get_policy("paper_llama_mix"))
    import jax as _j
    fp_bytes = sum(x.size * 4 for x in _j.tree.leaves(params))
    sizes = quantized_param_bytes(qp)
    # packed portion must be < 30% of its fp32 original overall
    assert sizes["total"] < 0.55 * fp_bytes
    assert sizes["packed"] > 0


def test_serving_engine_generates():
    cfg = get_arch("tinyllama-1.1b", reduced=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    qp, _ = quantize_params(params, get_policy("paper_llama_mix"))
    eng = Engine(cfg, qp, ServeConfig(max_new_tokens=8))
    outs = eng.generate([[1, 2, 3], [4, 5, 6, 7]])
    assert len(outs) == 2
    assert all(len(o) == 8 for o in outs)
    assert all(0 <= t < cfg.vocab_size for o in outs for t in o)
    # greedy decoding is deterministic
    outs2 = eng.generate([[1, 2, 3], [4, 5, 6, 7]])
    assert outs == outs2


def test_int8_kv_cache_decode():
    """Beyond-paper: int8 KV cache (per-token-head scales) halves decode
    cache traffic; logits stay within quantization noise of the bf16-cache
    path."""
    cfg = get_arch("llama3.2-1b", reduced=True).replace(
        kv_cache_quant=True, dtype="float32")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    B, S_pre, n_new = 2, 12, 6
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S_pre + n_new), 0,
                              cfg.vocab_size)
    lg_full, _, _ = T.forward_seq(params, cfg, tokens=toks)
    _, _, caches = T.forward_seq(params, cfg, want_cache=True,
                                 tokens=toks[:, :S_pre])
    cache = T.cache_from_prefill(cfg, caches, S_pre,
                                 cache_len=S_pre + n_new)
    assert cache["k"].dtype == jnp.int8
    errs = []
    for t in range(n_new):
        pos = jnp.full((B,), S_pre + t, jnp.int32)
        lg, cache = T.decode_step(params, cfg, cache, position=pos,
                                  tokens=toks[:, S_pre + t])
        errs.append(float(jnp.abs(lg - lg_full[:, S_pre + t]).max()))
    assert max(errs) / float(jnp.abs(lg_full).max()) < 0.06


def test_extended_variants_policy():
    """Paper future work (Q4_K-Q8_K) usable end-to-end (untied arch so the
    q6_k lm_head rule actually fires)."""
    cfg = get_arch("phi3-mini-3.8b", reduced=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    qp, report = quantize_params(params, get_policy("extended_mix"))
    variants = {v for v in report.values() if v}
    assert "q4_k" in variants and "q6_k" in variants
    lg, _, _ = T.forward_seq(
        qp, cfg, tokens=jnp.zeros((1, 8), jnp.int32))
    assert bool(jnp.all(jnp.isfinite(lg)))
