"""Micro-ISA driver + simulator tests (paper Table I / §III-C)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import isa
from repro.core.quantize import quantize, quantize_q8_k
from repro.kernels import ref


def _setup(variant="q2_k", M=24, K=512, N=192, key=0):
    kx, kw = jax.random.split(jax.random.PRNGKey(key))
    x = np.asarray(jax.random.normal(kx, (M, K)), np.float32)
    w = quantize(variant, jax.random.normal(kw, (K, N)) * 0.2)
    return x, w


def test_stream_structure_follows_paper():
    """CONFIG first; whole-input load when it fits; output-stationary
    LOAD_W/SCHEDULE sweeps; STORE per output tile."""
    x, w = _setup()
    plan = isa.plan_tiling(24, 512, 192, "q2_k", input_buf_bytes=1 << 30,
                           tile_n=64)
    stream = isa.generate_stream(24, 512, 192, "q2_k", plan)
    assert stream[0].op == isa.Op.CONFIG
    assert stream[0].weight_type == "q2_k"
    assert stream[1].op == isa.Op.LOAD_I        # input fits -> sent once
    kinds = [i.op for i in stream]
    assert kinds.count(isa.Op.STORE) == 3       # N/64 x M/128 output tiles
    assert isa.Op.SCHEDULE in kinds


def test_sim_matches_integer_reference():
    x, w = _setup("q2_k")
    out, stats = isa.run_matmul(x, w)
    qx = quantize_q8_k(jnp.asarray(x))
    expect = np.asarray(ref.matmul_q8k_ref(qx, w))
    np.testing.assert_allclose(out, expect, rtol=1e-5,
                               atol=1e-5 * np.abs(expect).max())
    assert stats.schedules >= 1


@pytest.mark.parametrize("variant", ["q2_k", "q3_k"])
def test_sim_tiled_equals_untiled(variant):
    """Output-stationary tiling must not change results (paper §III-C)."""
    x, w = _setup(variant, M=40, K=768, N=160)
    plan_small = isa.plan_tiling(40, 768, 160, variant,
                                 input_buf_bytes=100,   # forces tiling
                                 weight_buf_bytes=60000,
                                 tile_m=16, tile_n=64)
    assert not plan_small.whole_input
    out_t, stats_t = isa.run_matmul(x, w, plan_small)
    out_u, _ = isa.run_matmul(x, w)
    np.testing.assert_allclose(out_t, out_u, rtol=1e-5,
                               atol=1e-5 * np.abs(out_u).max())
    assert stats_t.schedules > 1


def test_sim_rejects_wrong_weight_type():
    x, w = _setup("q2_k")
    stream = isa.generate_stream(24, 512, 192, "q3_k")
    sim = isa.FBFQSimulator(x, w)
    with pytest.raises(AssertionError):
        sim.run(stream)


def test_stream_byte_accounting():
    """Weight stream bytes == packed tensor bytes when each tile is sent
    once (the accelerator's bandwidth model)."""
    x, w = _setup("q3_k", M=16, K=512, N=128)
    plan = isa.plan_tiling(16, 512, 128, "q3_k", tile_m=16, tile_n=128)
    out, stats = isa.run_matmul(x, w, plan)
    assert stats.weight_bytes == w.nbytes
    assert stats.output_bytes == 16 * 128 * 4


def test_qtensor_tile_slicing():
    _, w = _setup("q3_k", K=768, N=96)
    t = isa.qtensor_tile(w, 256, 768, 32, 64)
    assert t.shape == (512, 32)
    from repro.core.quantize import dequantize
    full = np.asarray(dequantize(w))
    part = np.asarray(dequantize(t))
    np.testing.assert_allclose(part, full[256:768, 32:64], rtol=1e-6)
