"""Disaggregated prefill/decode serving suite.

THE oracle: routed output through DisaggEngine (prefill workers + KV
page migration + decode workers behind the radix router) must be
TOKEN-IDENTICAL to one monolithic Engine with the same ServeConfig --
greedy across causal / sliding-window / int8-KV, against monolithic
references with the prefix cache off AND on, with speculation riding on
the decode tier, and under temperature sampling (1P+1D). The guarantee
composes from already-pinned pieces: exported pages are bit-for-bit pool
copies (tested in isolation below, int8 scales included), imports land
in the decode worker's ordinary prefix cache, and warm-prefix admission
is parity-pinned in test_prefix_cache -- so the only NEW thing to trust
is the hand-off, which is why export/import gets its own bit-identity
tests before the router ever composes them.

Router behavior (overlap-first placement, spreading, direct-to-decode
for sub-page prompts) and API validation ride along.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.models import transformer as T
from repro.serving.disagg import DisaggEngine
from repro.serving.engine import Engine, ServeConfig
from repro.serving.router import KVRouter


@pytest.fixture(scope="module")
def causal():
    cfg = get_arch("tinyllama-1.1b", reduced=True)
    return cfg, T.init_params(cfg, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def windowed():
    cfg = get_arch("h2o-danube-1.8b", reduced=True)      # window = 64
    return cfg, T.init_params(cfg, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def int8kv():
    cfg = get_arch("llama3.2-1b", reduced=True).replace(
        kv_cache_quant=True, dtype="float32")
    return cfg, T.init_params(cfg, jax.random.PRNGKey(0))


_BASE = dict(max_new_tokens=5, cache_len=64, decode_chunk=5, max_slots=2,
             prefill_bucket=4, prefill_chunk=16, prefix_page=8)


def _scfg(**kw):
    base = dict(_BASE)
    base.update(kw)
    return ServeConfig(**base)


def _prompts(cfg, n, shared_len=24, uniq=(3, 9), seed=0):
    """Shared-system-prompt queue plus one sub-page prompt (exercises the
    router's direct-to-decode path in every parity run)."""
    rng = np.random.default_rng(seed)
    shared = list(rng.integers(0, cfg.vocab_size, shared_len))
    ps = [shared + list(rng.integers(0, cfg.vocab_size,
                                     int(rng.integers(*uniq))))
          for _ in range(n)]
    ps.append(list(rng.integers(0, cfg.vocab_size, 4)))  # < one page
    return ps


# ---------------------------------------------------------------------------
# the parity matrix: arch family x mono-prefix on/off x spec on/off
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fixture", ["causal", "windowed", "int8kv"])
@pytest.mark.parametrize("spec", [False, True])
def test_greedy_parity_matrix(fixture, spec, request):
    """1P+1D routed output == monolithic engine, token for token, against
    BOTH monolithic references (prefix cache off and on), with pages
    actually migrating and the decode tier actually reusing them. With
    ``spec`` the drafter rides on the decode workers (prefill workers
    never decode, so speculation there is moot)."""
    model = request.getfixturevalue(fixture)
    cfg, params = model
    kw = dict(drafter="ngram", draft_k=4) if spec else {}
    prompts = _prompts(cfg, 4, seed=1)
    mono_off = Engine(cfg, params, _scfg(**kw))
    mono_on = Engine(cfg, params, _scfg(prefix_cache=True, **kw))
    dis = DisaggEngine(cfg, params, _scfg(**kw),
                       prefill_workers=1, decode_workers=1)
    expect = mono_off.generate(prompts)
    assert mono_on.generate(prompts) == expect
    assert dis.generate(prompts) == expect
    assert dis.stats["migrated_pages"] > 0
    assert dis.stats["prefix_hits"] > 0          # decode tier reused them
    assert dis.stats["router"]["direct_decode"] == 1   # the sub-page prompt
    # repeat runs stay warm AND identical (radix state survives generate)
    assert dis.generate(prompts) == expect
    assert dis.stats["router"]["migrated_pages_total"] > 0


def test_temperature_parity_1p1d(causal):
    """Same ServeConfig seed + same submission order => the decode worker
    replicates the monolithic engine's per-request key-split discipline
    exactly, so even SAMPLED output is token-identical through the
    disaggregated path (1 decode worker; multi-worker temperature runs
    split into per-worker streams by design)."""
    cfg, params = causal
    prompts = _prompts(cfg, 4, seed=2)
    mono = Engine(cfg, params, _scfg(temperature=0.7, seed=3))
    dis = DisaggEngine(cfg, params, _scfg(temperature=0.7, seed=3),
                       prefill_workers=1, decode_workers=1)
    expect = mono.generate(prompts)
    assert dis.generate(prompts) == expect
    assert dis.stats["migrated_pages"] > 0


def test_multiworker_greedy_parity(causal):
    """2P+2D: greedy sampling is schedule-independent and per-slot
    admission is isolation-pinned, so output stays token-identical to one
    monolithic engine even with requests spread over two decode
    workers -- and the router must actually spread them."""
    cfg, params = causal
    prompts = _prompts(cfg, 5, seed=4)
    mono = Engine(cfg, params, _scfg())
    dis = DisaggEngine(cfg, params, _scfg(),
                       prefill_workers=2, decode_workers=2)
    assert dis.generate(prompts) == mono.generate(prompts)
    rt = dis.stats["router"]
    assert all(n > 0 for n in rt["decode_requests"])     # both workers used
    assert rt["migrated_pages_total"] > 0


# ---------------------------------------------------------------------------
# router placement
# ---------------------------------------------------------------------------

def test_router_prefers_prefix_overlap(causal):
    """Two prefill workers: after worker 0 caches family-A pages, a
    second wave routes the A-prefixed request to worker 0 (overlap) and
    the unrelated request to worker 1 (tie on score 0 -> shallowest
    queue), concentrating prefix reuse where the KV lives."""
    cfg, params = causal
    rng = np.random.default_rng(5)
    A = list(rng.integers(0, cfg.vocab_size, 24))
    dis = DisaggEngine(cfg, params, _scfg(),
                       prefill_workers=2, decode_workers=1)
    dis.generate([A + list(rng.integers(0, cfg.vocab_size, 5))])
    rt = dis.stats["router"]
    assert rt["prefill_requests"] == [1, 0]      # cold tie -> worker 0
    A2 = A + list(rng.integers(0, cfg.vocab_size, 6))
    B = list(rng.integers(0, cfg.vocab_size, 30))
    dis.generate([A2, B])
    rt = dis.stats["router"]
    assert rt["prefill_requests"] == [2, 1]      # A2 -> 0 (overlap), B -> 1
    assert rt["prefill_overlap_hits"][0] == 1
    assert rt["prefill_overlap_tokens"][0] >= 24 - _BASE["prefix_page"]
    assert rt["prefill_hit_rate"][0] == 0.5


def test_router_scoring_no_lru_distortion():
    """prefix_match_len (the router probe) must not touch LRU stamps:
    scoring a request against every worker's tree cannot reorder
    eviction on the workers that lose the vote. Checked host-side on the
    raw radix tree."""
    from repro.serving.prefix_cache import PrefixCache
    pc = PrefixCache(page=2, capacity=2)
    pc.insert([1, 2, 3, 4])                      # two pages
    stamps = {id(c): c.stamp for c in pc._root.children.values()}
    assert pc.match_len([1, 2, 3, 4, 9]) == 4
    assert {id(c): c.stamp
            for c in pc._root.children.values()} == stamps
    m, _ = pc.match([1, 2, 3, 4, 9])             # match() DOES touch
    assert m == 4
    assert {id(c): c.stamp
            for c in pc._root.children.values()} != stamps


# ---------------------------------------------------------------------------
# export/import in isolation: the hand-off must be bit-identical before
# the router ever composes it
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fixture", ["causal", "int8kv"])
def test_export_import_bit_identical(fixture, request):
    """Pages exported from one engine and imported into another come back
    out bit-for-bit -- int8-KV payloads AND their f32 scales -- and the
    importer's radix tree then matches the prompt as if it had prefilled
    it itself. Re-import dedupes to zero."""
    model = request.getfixturevalue(fixture)
    cfg, params = model
    rng = np.random.default_rng(6)
    P = list(rng.integers(0, cfg.vocab_size, 21))        # 2 full pages + 5
    src = Engine(cfg, params, _scfg(prefix_cache=True))
    dst = Engine(cfg, params, _scfg(prefix_cache=True))
    src.generate([P])
    kv = src.export_kv_pages(P)
    assert kv.n_pages == 2 and kv.tokens == P[:16]
    if cfg.kv_cache_quant:
        assert kv.payload["k"].dtype == np.int8
        assert set(kv.payload) == {"k", "v", "k_scale", "v_scale"}
        assert kv.payload["k_scale"].dtype == np.float32
    assert dst.import_kv_pages(kv) == 2
    assert dst.prefix_match_len(P) == 16
    back = dst.export_kv_pages(P)
    assert back.tokens == kv.tokens
    for k in kv.payload:
        np.testing.assert_array_equal(np.asarray(back.payload[k]),
                                      np.asarray(kv.payload[k]))
    assert dst.import_kv_pages(kv) == 0                  # dedup
    # and the imported pages SERVE: dst decodes P identically to src
    assert dst.generate([P]) == src.generate([P])
    assert dst.stats["prefix_hits"] == 1


def test_page_roundtrip_ring_wrap(windowed):
    """The page primitives themselves through a sliding-window ring wrap:
    gather pages whose positions straddle the wrap boundary out of one
    ring, scatter them into a second engine's fresh ring, and the
    destination rows/positions must equal the source bit-for-bit (cols
    are position % T on both sides)."""
    cfg, _ = windowed
    Tr = T.attn_cache_len(cfg, 64)
    assert Tr == 64
    page = 8
    key = jax.random.PRNGKey(7)
    ring = T.init_cache(cfg, 2, 64)
    ring = {k: (jax.random.normal(key, v.shape).astype(v.dtype)
                if v.dtype != jnp.int32 else v)
            for k, v in ring.items()}
    # positions 60..75 on slot 1: pages [60..67], [68..75] wrap the ring
    positions = np.arange(60, 76)
    cols = (positions % Tr).reshape(2, page)
    rows = np.array([1, 1])
    pages = T.cache_gather_pages(ring, jnp.asarray(rows),
                                 jnp.asarray(cols))
    ring2 = T.init_cache(cfg, 2, 64)
    ring2 = T.cache_scatter_pages(
        ring2, pages, jnp.asarray(np.array([0, 0])), jnp.asarray(cols),
        jnp.asarray(positions.reshape(2, page)))
    for k, pg in pages.items():
        got = np.asarray(ring2[k][:, 0])[:, cols.ravel()]
        want = np.asarray(ring[k][:, 1])[:, cols.ravel()]
        np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(
        np.asarray(ring2["pos"][0])[cols.ravel()], positions)


def test_import_rejects_mismatched_page(causal):
    cfg, params = causal
    rng = np.random.default_rng(8)
    P = list(rng.integers(0, cfg.vocab_size, 17))
    src = Engine(cfg, params, _scfg(prefix_cache=True))
    dst = Engine(cfg, params, _scfg(prefix_cache=True, prefix_page=4))
    src.generate([P])
    with pytest.raises(ValueError, match="page geometry"):
        dst.import_kv_pages(src.export_kv_pages(P))


def test_export_requires_prefix_cache(causal):
    cfg, params = causal
    eng = Engine(cfg, params, _scfg())
    with pytest.raises(RuntimeError, match="prefix_cache"):
        eng.export_kv_pages([1, 2, 3])
    with pytest.raises(RuntimeError, match="prefix_cache"):
        eng.import_kv_pages(None)
    assert eng.prefix_page is None
    assert eng.prefix_match_len([1, 2, 3]) == 0


def test_export_unknown_prompt_is_empty(causal):
    cfg, params = causal
    eng = Engine(cfg, params, _scfg(prefix_cache=True))
    kv = eng.export_kv_pages([5, 6, 7, 8, 9, 10, 11, 12, 13])
    assert kv.n_pages == 0 and kv.payload == {}


# ---------------------------------------------------------------------------
# API validation
# ---------------------------------------------------------------------------

def test_disagg_validation(causal):
    cfg, params = causal
    with pytest.raises(ValueError, match="worker"):
        DisaggEngine(cfg, params, _scfg(), prefill_workers=0)
    ssm = cfg.replace(family="ssm")
    with pytest.raises(ValueError, match="KV-ring"):
        DisaggEngine(ssm, params, _scfg())
    dis = DisaggEngine(cfg, params, _scfg())
    with pytest.raises(ValueError, match="empty"):
        dis.submit([])
    with pytest.raises(ValueError, match="max_new_tokens"):
        dis.submit([1, 2], max_new_tokens=0)
    with pytest.raises(ValueError, match="drafter"):
        dis.submit([1, 2], speculate=True)
    with pytest.raises(ValueError, match="cache_len"):
        dis.submit(list(range(64)))
    dis.submit([1, 2, 3])
    with pytest.raises(RuntimeError, match="pending"):
        dis.generate([[1, 2]])


def test_router_validation():
    with pytest.raises(ValueError, match="router needs"):
        KVRouter([], [object()])


def test_router_depth_clamps_at_zero():
    """A double-done (or a done with no matching pick) must not drive a
    queue depth negative: a negative depth makes that worker look
    permanently shallower than every honest worker, so least-loaded
    placement routes to it forever after. Depths clamp at 0 and the
    stray calls are counted in snapshot()["depth_underflows"]."""
    class _W:                            # router probes prefix_match_len
        def prefix_match_len(self, prompt):
            return 0
    r = KVRouter([_W(), _W()], [object(), object()])
    w = r.pick_prefill([1, 2, 3])
    r.note_prefill_done(w)
    r.note_prefill_done(w)               # double-done: clamped, counted
    r.note_decode_done(1)                # done without pick: ditto
    snap = r.snapshot()
    assert snap["prefill_queue_depth"] == [0, 0]
    assert snap["decode_queue_depth"] == [0, 0]
    assert snap["depth_underflows"] == 2
    # placement is still unbiased: the clamped worker does not win every
    # least-loaded tie-break with a phantom negative depth
    d = r.pick_decode()
    assert d == 0                        # lowest index, not the clamped 1
    r.note_decode_done(d)
    assert r.snapshot()["depth_underflows"] == 2


def test_disagg_cancel_queued(causal):
    cfg, params = causal
    dis = DisaggEngine(cfg, params, _scfg())
    rid = dis.submit([1, 2, 3])
    keep = dis.submit([4, 5, 6, 7])
    assert dis.cancel(rid)
    assert not dis.cancel(999)
    res = dis.run()
    assert res[rid] == []
    assert len(res[keep]) == _BASE["max_new_tokens"]
