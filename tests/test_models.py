"""Per-arch smoke tests (reduced configs): forward + train step on CPU,
shape/NaN assertions -- one per assigned architecture + paper models."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_arch
from repro.models import transformer as T
from repro.optim.adamw import AdamWConfig
from repro.training import steps as S

ALL_ARCHS = list(ARCH_IDS)


def _batch(cfg, key, B=2, S=16):
    ks = jax.random.split(key, 3)
    batch = {"labels": jax.random.randint(ks[0], (B, S), 0,
                                          cfg.vocab_size)}
    if cfg.embed_input:
        batch["tokens"] = jax.random.randint(ks[1], (B, S), 0,
                                             cfg.vocab_size)
    else:
        batch["embeds"] = jax.random.normal(ks[1], (B, S, cfg.d_model),
                                            jnp.float32)
    if cfg.pos_emb == "mrope":
        batch["positions"] = jnp.broadcast_to(jnp.arange(S)[None, None],
                                              (3, B, S)).astype(jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_no_nans(arch):
    cfg = get_arch(arch, reduced=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    logits, aux, _ = T.forward_seq(
        params, cfg, tokens=batch.get("tokens"),
        embeds=batch.get("embeds"), positions=batch.get("positions"))
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step(arch):
    cfg = get_arch(arch, reduced=True)
    opt = AdamWConfig(warmup_steps=1, total_steps=10)
    state = S.init_train_state(cfg, opt, jax.random.PRNGKey(0))
    step = jax.jit(S.make_train_step(cfg, opt))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    state2, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(state2["step"]) == 1
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda p, q: float(jnp.abs(p - q).max()),
                     state["params"], state2["params"]))
    assert delta > 0


@pytest.mark.parametrize("arch", ["llama3.2-1b", "zamba2-1.2b",
                                  "mamba2-2.7b", "olmoe-1b-7b",
                                  "gpt2-paper", "h2o-danube-1.8b"])
def test_decode_matches_full_forward(arch):
    # fp32 compute: the decode and full-sequence paths reduce in different
    # orders, which is bit-visible at bf16 but not a semantic difference
    cfg = get_arch(arch, reduced=True).replace(dtype="float32")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    B, S_pre, n_new = 2, 16, 4
    S_tot = S_pre + n_new
    key = jax.random.PRNGKey(1)
    if cfg.embed_input:
        toks = jax.random.randint(key, (B, S_tot), 0, cfg.vocab_size)
        fkw = dict(tokens=toks)
        pkw = dict(tokens=toks[:, :S_pre])
    else:
        em = jax.random.normal(key, (B, S_tot, cfg.d_model), jnp.float32)
        fkw = dict(embeds=em)
        pkw = dict(embeds=em[:, :S_pre])
    logits_full, _, _ = T.forward_seq(params, cfg, **fkw)
    logits_pre, _, caches = T.forward_seq(params, cfg, want_cache=True,
                                          **pkw)
    cache = T.cache_from_prefill(cfg, caches, S_pre,
                                 cache_len=T.attn_cache_len(cfg, S_tot),
                                 dtype=jnp.float32)
    errs = [float(jnp.abs(logits_pre[:, -1]
                          - logits_full[:, S_pre - 1]).max())]
    for t in range(n_new):
        pos = jnp.full((B,), S_pre + t, jnp.int32)
        skw = (dict(tokens=toks[:, S_pre + t]) if cfg.embed_input
               else dict(embeds=em[:, S_pre + t]))
        lg, cache = T.decode_step(params, cfg, cache, position=pos, **skw)
        errs.append(float(jnp.abs(lg - logits_full[:, S_pre + t]).max()))
    scale = float(jnp.abs(logits_full).max()) + 1e-9
    # MoE: router logits differ by ~1 ulp between the two paths, which can
    # flip near-tied top-k choices -- an inherent (documented) property of
    # capacity routing, not a cache bug
    tol = 5e-3 if cfg.family == "moe" else 2e-4
    assert max(errs) / scale < tol, errs


def test_swa_ring_buffer_decode():
    """Sliding-window arch: decode far past the window with a ring cache
    must equal the full forward."""
    # fp32 compute: ring-buffer slot order permutes the softmax summation
    # order at wrap, which is bit-visible at bf16 but not a correctness bug
    cfg = get_arch("h2o-danube-1.8b", reduced=True).replace(
        sliding_window=8, dtype="float32")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    B, S_tot = 1, 32
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S_tot), 0,
                              cfg.vocab_size)
    logits_full, _, _ = T.forward_seq(params, cfg, tokens=toks)
    # prefill 4, then decode 28 steps through a ring cache of size 8
    S_pre = 4
    _, _, caches = T.forward_seq(params, cfg, want_cache=True,
                                 tokens=toks[:, :S_pre])
    cache = T.cache_from_prefill(cfg, caches, S_pre, cache_len=8,
                                 dtype=jnp.float32)
    errs = []
    for t in range(S_pre, S_tot):
        pos = jnp.full((B,), t, jnp.int32)
        lg, cache = T.decode_step(params, cfg, cache, position=pos,
                                  tokens=toks[:, t])
        errs.append(float(jnp.abs(lg - logits_full[:, t]).max()))
    scale = float(jnp.abs(logits_full).max())
    assert max(errs) / scale < 2e-4, max(errs)


def test_blockwise_attention_equals_naive():
    from repro.models import layers as L
    key = jax.random.PRNGKey(0)
    B, S, H, KH, D = 2, 64, 4, 2, 16
    q = jax.random.normal(key, (B, S, H, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, KH, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, KH, D))
    for window in (None, 24):
        o1 = L.naive_attention(q, k, v, causal=True, window=window)
        o2 = L.blockwise_attention(q, k, v, causal=True, window=window,
                                   q_chunk=16, kv_chunk=16)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   rtol=2e-5, atol=2e-5)


def test_ssd_chunked_equals_recurrence():
    from repro.models.mamba2 import _ssd_chunk_scan, naive_recurrence
    key = jax.random.PRNGKey(3)
    B, S, H, P, N = 2, 48, 4, 8, 16
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))
    s0 = jax.random.normal(key, (B, H, P, N)) * 0.1
    y1, st1 = _ssd_chunk_scan(x, dt, A, Bm, Cm, s0, 16)   # S % 16 == 0
    y2, st2 = naive_recurrence(x, dt, A, Bm, Cm, s0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(st1), np.asarray(st2), atol=1e-4)
    # non-divisible S -> padded path
    y3, st3 = _ssd_chunk_scan(x[:, :40], dt[:, :40], A, Bm[:, :40],
                              Cm[:, :40], s0, 16)
    y4, st4 = naive_recurrence(x[:, :40], dt[:, :40], A, Bm[:, :40],
                               Cm[:, :40], s0)
    np.testing.assert_allclose(np.asarray(y3), np.asarray(y4), atol=1e-4)
    np.testing.assert_allclose(np.asarray(st3), np.asarray(st4), atol=1e-4)


def test_moe_routing_properties():
    from repro.models.moe import moe_block
    cfg = get_arch("olmoe-1b-7b", reduced=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    lp = jax.tree.map(lambda a: a[0], params["layers"]["moe"])
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y, aux = moe_block(x, lp, cfg)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))
    assert float(aux) > 0.5          # Switch aux loss ~1 for random routing
    # zero input -> zero output (experts are linear+silu with no bias)
    y0, _ = moe_block(jnp.zeros_like(x), lp, cfg)
    assert float(jnp.abs(y0).max()) < 1e-5


def test_mrope_sections():
    from repro.models.layers import rope_cos_sin, apply_rope
    B, S, D = 2, 8, 32
    pos3 = jnp.stack([jnp.arange(S)[None].repeat(B, 0)] * 3)
    cos3, sin3 = rope_cos_sin(pos3, D, 1e4, mrope_sections=(4, 6, 6))
    cos1, sin1 = rope_cos_sin(pos3[0], D, 1e4)
    # equal position streams -> M-RoPE == standard RoPE
    np.testing.assert_allclose(np.asarray(cos3), np.asarray(cos1),
                               rtol=1e-6)
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, 2, D))
    np.testing.assert_allclose(np.asarray(apply_rope(q, cos3, sin3)),
                               np.asarray(apply_rope(q, cos1, sin1)),
                               rtol=1e-5, atol=1e-5)
