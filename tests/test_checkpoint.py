"""Checkpointing + fault tolerance: atomicity, resume, preemption."""
import os
import signal
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import Checkpointer
from repro.configs.base import get_arch
from repro.optim.adamw import AdamWConfig
from repro.training.loop import run_training


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": {"w": jax.random.normal(k, (8, 8)),
                  "b": jnp.arange(3)},
            "step": jnp.asarray(7)}


def test_save_restore_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    t = _tree()
    ck.save(5, t)
    step, r = ck.restore_latest()
    assert step == 5
    np.testing.assert_array_equal(np.asarray(t["a"]["w"]), r["a"]["w"])
    np.testing.assert_array_equal(np.asarray(t["a"]["b"]), r["a"]["b"])


def test_async_save_and_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save_async(s, _tree(s))
    ck.wait()
    assert ck.all_steps() == [3, 4]


def test_torn_checkpoint_ignored(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _tree())
    # simulate a torn write: step dir without MANIFEST
    os.makedirs(tmp_path / "step_9")
    np.savez(tmp_path / "step_9" / "process_0.npz", x=np.zeros(3))
    step, _ = ck.restore_latest()
    assert step == 1


def test_resume_continues_bit_identical(tmp_path):
    """Train 6 steps straight vs 3 + restart + 3: identical final loss
    (checkpoint/restart fault tolerance + deterministic data)."""
    cfg = get_arch("llama3.2-1b", reduced=True)
    opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=6)
    kw = dict(global_batch=4, seq_len=32, opt=opt, seed=3,
              log_fn=lambda *_: None)
    res_a = run_training(cfg, steps=6, **kw)

    d1 = tmp_path / "resume"
    res_b1 = run_training(cfg, steps=3, ckpt_dir=str(d1), ckpt_every=3,
                          **kw)
    res_b2 = run_training(cfg, steps=6, ckpt_dir=str(d1), ckpt_every=3,
                          **kw)
    assert len(res_b2["losses"]) == 3          # resumed from step 3
    assert abs(res_a["losses"][-1] - res_b2["losses"][-1]) < 1e-4


PREEMPT_SCRIPT = r"""
import sys, os
sys.path.insert(0, "src")
from repro.configs.base import get_arch
from repro.optim.adamw import AdamWConfig
from repro.training.loop import run_training
cfg = get_arch("llama3.2-1b", reduced=True)
opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=40)
res = run_training(cfg, steps=40, global_batch=4, seq_len=32, opt=opt,
                   ckpt_dir=sys.argv[1], ckpt_every=5, seed=3,
                   log_fn=lambda m: print(m, flush=True))
print("PREEMPTED" if res["preempted"] else "FINISHED", flush=True)
"""


@pytest.mark.slow
def test_sigterm_preemption_saves_and_resumes(tmp_path):
    ckdir = str(tmp_path / "pre")
    proc = subprocess.Popen(
        [sys.executable, "-c", PREEMPT_SCRIPT, ckdir],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True)
    # wait for a few steps then preempt
    t0 = time.time()
    saw_step = False
    while time.time() - t0 < 120:
        line = proc.stdout.readline()
        if "step " in line:
            saw_step = True
        if "step    10" in line or "step 10" in line.replace("  ", " "):
            break
    assert saw_step
    proc.send_signal(signal.SIGTERM)
    out, _ = proc.communicate(timeout=120)
    assert "PREEMPTED" in out
    ck = Checkpointer(ckdir)
    steps = ck.all_steps()
    assert steps, "preemption must leave a checkpoint"
    # restart completes from the saved step
    cfg = get_arch("llama3.2-1b", reduced=True)
    opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=40)
    res = run_training(cfg, steps=40, global_batch=4, seq_len=32, opt=opt,
                       ckpt_dir=ckdir, ckpt_every=50, seed=3,
                       log_fn=lambda *_: None)
    assert len(res["losses"]) == 40 - steps[-1]
