"""Distribution tests: sharding rules, compressed gradient all-reduce,
and a subprocess tiny-mesh dry-run (the multi-pod config, miniaturized)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import get_arch
from repro.distributed import sharding as SH
from repro.models import transformer as T

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


class FakeMesh:
    """Axis-name/shape stand-in for spec tests (no devices needed)."""
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)
        self.size = int(np.prod(list(shape.values())))


def test_param_specs_rules():
    mesh = FakeMesh({"data": 16, "model": 16})
    cfg = get_arch("llama3.2-1b")
    params = jax.eval_shape(
        lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
    specs = SH.param_specs(params, mesh)
    lay = specs["layers"]
    assert lay["attn"]["wq"] == P(None, ("data",), "model")     # column
    assert lay["attn"]["wo"] == P(None, "model", ("data",))     # row
    assert lay["mlp"]["w_down"] == P(None, "model", ("data",))  # row
    assert lay["ln1"]["w"] == P()
    assert specs["wte"] == P("model", ("data",))


def test_param_specs_moe_ep_vs_tp():
    mesh = FakeMesh({"data": 16, "model": 16})
    # olmoe: 64 experts % 16 == 0 -> EP over model
    specs = SH.param_specs(jax.eval_shape(
        lambda: T.init_params(get_arch("olmoe-1b-7b"),
                              jax.random.PRNGKey(0))), mesh)
    assert specs["layers"]["moe"]["w_gate"][1] == "model"
    # granite: 40 % 16 != 0 -> per-expert FFN TP
    specs2 = SH.param_specs(jax.eval_shape(
        lambda: T.init_params(get_arch("granite-moe-3b-a800m"),
                              jax.random.PRNGKey(0))), mesh)
    g = specs2["layers"]["moe"]["w_gate"]
    assert g[1] is None and g[3] == "model"


def test_qtensor_specs_row_vs_column():
    from repro.core.policy import get_policy
    from repro.core.qlinear import spec_like_quantized
    mesh = FakeMesh({"data": 16, "model": 16})
    cfg = get_arch("llama3.2-1b")
    sds = jax.eval_shape(lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
    qsds = spec_like_quantized(sds, get_policy("default_serve_mix"))
    specs = SH.param_specs(qsds, mesh, fsdp=False)
    # column-parallel wq: lanes over model
    assert specs["layers"]["attn"]["wq"].data["qs"] == P(None, None, "model")
    # row-parallel w_down (K=8192 SB-aligned for 16): rows over model
    assert specs["layers"]["mlp"]["w_down"].data["qs"] == P(None, "model",
                                                            None)


def test_cache_specs_adaptive():
    mesh = FakeMesh({"data": 16, "model": 16})
    # kv=8 not divisible by 16 -> flash-decoding sequence sharding
    # (head_dim mode is never auto-chosen: GSPMD re-gathers the cache,
    # see EXPERIMENTS.md §Perf H1)
    cfg = get_arch("qwen2-vl-72b")
    cache = jax.eval_shape(lambda: T.init_cache(cfg, 128, 1024))
    specs = SH.cache_specs(cache, mesh)
    assert specs["k"] == P(None, ("data",), "model", None, None)  # seq
    cfg2 = get_arch("phi3-mini-3.8b")  # kv=32 divisible
    cache2 = jax.eval_shape(lambda: T.init_cache(cfg2, 128, 1024))
    specs2 = SH.cache_specs(cache2, mesh)
    assert specs2["k"] == P(None, ("data",), None, "model", None)  # heads
    # B=1 long-context: sequence shards over dp
    cache3 = jax.eval_shape(lambda: T.init_cache(cfg2, 1, 2048))
    specs3 = SH.cache_specs(cache3, mesh)
    # PartitionSpec may normalize 1-tuples to the bare axis name
    assert specs3["k"][1] is None
    assert specs3["k"][2] in ("data", ("data",))


def test_nested_and_reentrant_role_contexts():
    """tp_off / activation_axes are stacked contexts: nesting tp_off
    inside an active activation_axes, re-entering the SAME context
    object, and sequential reuse must all restore state exactly
    (regression: the old per-instance ``_saved`` slot was clobbered on
    re-entry, leaving the module-level role dicts corrupted)."""
    mesh = FakeMesh({"data": 2, "model": 2})
    assert SH.model_axis(mesh) == "model"

    # nested tp_off inside activation_axes: the inner context must not
    # disturb the outer's activation frame on exit
    with SH.activation_axes(mesh):
        assert SH._ACT_STACK[-1]["enabled"]
        outer_frame = dict(SH._ACT_STACK[-1])
        with SH.tp_off():
            assert SH.model_axis(mesh) is None
            assert SH.dp_axes(mesh) == ("data", "model")
            assert SH._ACT_STACK[-1] == outer_frame       # untouched
        assert SH.model_axis(mesh) == "model"             # tp restored
        assert SH._ACT_STACK[-1] == outer_frame
    assert not SH._ACT_STACK[-1]["enabled"]
    assert len(SH._ACT_STACK) == 1 and len(SH._TP_STACK) == 1

    # re-entering the SAME context object (the old code restored the
    # inner snapshot and left tp permanently off)
    ctx = SH.tp_off()
    with ctx:
        with ctx:
            assert SH.model_axis(mesh) is None
        assert SH.model_axis(mesh) is None                # outer active
    assert SH.model_axis(mesh) == "model"
    assert len(SH._TP_STACK) == 1

    # sequential reuse of one activation_axes object stays balanced
    act = SH.activation_axes(mesh)
    for _ in range(2):
        with act:
            assert SH._ACT_STACK[-1]["enabled"]
        assert not SH._ACT_STACK[-1]["enabled"]
    assert len(SH._ACT_STACK) == 1

    # interleaved (out-of-order) exits still converge to a clean base
    a, b = SH.tp_off(), SH.tp_off()
    a.__enter__()
    b.__enter__()
    a.__exit__(None, None, None)                          # out of order
    b.__exit__(None, None, None)
    assert len(SH._TP_STACK) == 1 and SH.model_axis(mesh) == "model"

    with pytest.raises(RuntimeError, match="without matching"):
        SH.tp_off().__exit__(None, None, None)


def test_constrain_noop_outside_context():
    x = jnp.ones((4, 4))
    assert SH.constrain(x, "dp", None) is x


def test_compressed_psum_error_feedback():
    """bf16-wire all-reduce with error feedback on a real 1-device mesh."""
    from repro.distributed.compress import compressed_psum
    mesh = jax.make_mesh((1,), ("data",))
    g = {"w": jnp.asarray([[1.0004883, -2.0], [0.5, 3.141592]])}
    red, err = compressed_psum(g, mesh)
    # single device: reduced == bf16(g); error = g - bf16(g)
    np.testing.assert_allclose(
        np.asarray(red["w"]),
        np.asarray(g["w"].astype(jnp.bfloat16).astype(jnp.float32)))
    total = np.asarray(red["w"]) + np.asarray(err["w"])
    np.testing.assert_allclose(total, np.asarray(g["w"]), rtol=1e-6)
    # second step: residual is carried
    red2, err2 = compressed_psum(g, mesh, error=err)
    total2 = np.asarray(red2["w"]) + np.asarray(err2["w"])
    np.testing.assert_allclose(total2, np.asarray(g["w"]) * 1
                               + np.asarray(err["w"]), rtol=1e-5)


DRYRUN_SNIPPET = r"""
import os
os.environ["REPRO_DRYRUN_DEVICES"] = "16"
import sys
sys.path.insert(0, "src")
from repro.launch import dryrun as D
import jax
# miniature production mesh pair: (4,4) and multi-pod (2,2,4)
for axes, shape in ((("data","model"), (4,4)),
                    (("pod","data","model"), (2,2,4))):
    mesh = jax.make_mesh(shape, axes)
    rec = D.dryrun_cell("llama3.2-1b", "decode_32k", mesh=mesh)
    assert rec["status"] == "ok", rec
    assert rec["memory"]["total_hbm_bytes"] > 0
    assert rec["roofline"]["dominant"] in ("compute", "memory",
                                           "collective")
print("SUBPROCESS_DRYRUN_OK")
"""


@pytest.mark.slow
def test_subprocess_tiny_mesh_dryrun():
    out = subprocess.run([sys.executable, "-c", DRYRUN_SNIPPET], cwd=REPO,
                         capture_output=True, text=True, timeout=900)
    assert "SUBPROCESS_DRYRUN_OK" in out.stdout, out.stdout + out.stderr


def test_collective_parser():
    from repro.launch.analysis import collective_bytes, shape_bytes
    hlo = """
  %cvt = f32[8,16]{1,0} convert(%x)
  %dot.1 = f32[8,16]{1,0} dot(%cvt, %convert_bitcast_fusion.2)
  %all-reduce.1 = f32[8,16]{1,0} all-reduce(%dot.1), replica_groups={}
  %ag = bf16[4,8]{1,0} all-gather(%y), replica_groups={}
  %rs-start = f32[16]{0} reduce-scatter-start(%z)
"""
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 8 * 16 * 4
    assert out["all-gather"] == 4 * 8 * 2
    assert out["reduce-scatter"] == 16 * 4
    # the f32 all-reduce fed by a promoted bf16 dot counts at bf16 width
    assert out["total_corrected"] == 8 * 16 * 2 + 4 * 8 * 2 + 16 * 4
    assert shape_bytes("(f32[2,3], bf16[4])") == 24 + 8
