"""Expert-parallel MoE serving: sharded experts == replicated, bitwise.

THE oracle: greedy serving output with experts sharded over the model
axis (``ServeTPPlan.moe_ep``) is TOKEN-IDENTICAL to the single-device
engine AND to the tp>1 engine with EP disabled (``tp_ep=False``). The
guarantee is by construction: routing/dispatch/combine run replicated on
the full expert set (the router is replicated), each shard computes only
its own E/size experts' gemms on the SAME per-expert problem shapes the
replicated path batches over the expert dim, and one tiled all-gather --
pure data movement -- reassembles the global (B, E, C, d) output buffer.
No gemm changes shape, so CPU shape-dependent rounding cannot bite.

Multi-device tests need forced host devices BEFORE jax initializes:

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python -m pytest -x -q tests/test_moe_ep.py

Under the plain tier-1 run (1 device) the parity tests skip; the plan
unit tests still run.
"""
import jax
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.distributed import sharding as SH
from repro.models import transformer as T
from repro.serving.engine import Engine, ServeConfig

NDEV = len(jax.devices())
needs2 = pytest.mark.skipif(
    NDEV < 2, reason="needs >= 2 devices (force host devices via "
                     "XLA_FLAGS before jax initializes)")
needs4 = pytest.mark.skipif(
    NDEV < 4, reason="needs XLA_FLAGS=--xla_force_host_platform_"
                     "device_count=4 (set before jax initializes)")

BASE = dict(max_new_tokens=6, cache_len=64, decode_chunk=4, max_slots=2,
            prefill_bucket=4, prefill_chunk=8)


@pytest.fixture(scope="module")
def olmoe():
    # 4 experts so EP divides mesh sizes 2 and 4
    cfg = get_arch("olmoe-1b-7b", reduced=True).replace(
        n_experts=4, n_experts_active=2, capacity_factor=4.0)
    return cfg, T.init_params(cfg, jax.random.PRNGKey(0))


def _prompts(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    return [list(rng.integers(0, cfg.vocab_size, int(rng.integers(2, 24))))
            for _ in range(n)]


# ---------------------------------------------------------------------------
# plan unit tests (no extra devices needed)
# ---------------------------------------------------------------------------

def test_plan_moe_ep_requires_divisible_experts(olmoe):
    cfg, _ = olmoe                                  # E=4
    assert SH.make_serve_tp_plan(cfg, 1).moe_ep is False
    if NDEV >= 2:
        assert SH.make_serve_tp_plan(cfg, 2).moe_ep is True
        assert SH.make_serve_tp_plan(cfg, 2, ep=False).moe_ep is False
        e3 = cfg.replace(n_experts=3, n_experts_active=2)
        assert SH.make_serve_tp_plan(e3, 2).moe_ep is False


def test_plan_moe_ep_only_for_moe_family():
    cfg = get_arch("tinyllama-1.1b", reduced=True)
    if NDEV >= 2:
        assert SH.make_serve_tp_plan(cfg, 2).moe_ep is False


def test_param_specs_shard_expert_stacks(olmoe):
    cfg, params = olmoe
    if NDEV < 2:
        pytest.skip("plan needs 2 devices")
    plan = SH.make_serve_tp_plan(cfg, 2)
    assert plan.moe_ep
    specs = SH.serve_param_specs(params, plan)
    for key in ("w_gate", "w_up", "w_down"):
        spec = specs["layers"]["moe"][key]         # (Lc, E, d, f) stacks
        assert spec[-3] == plan.axis               # expert dim sharded
    assert specs["layers"]["moe"]["router"] == SH.P()  # replicated


# ---------------------------------------------------------------------------
# serving parity: EP on == EP off == single device, token for token
# ---------------------------------------------------------------------------

def _gen(model, tp, tp_ep=True, seed=3):
    cfg, params = model
    eng = Engine(cfg, params, ServeConfig(tp=tp, tp_ep=tp_ep, **BASE))
    if tp > 1:
        assert eng._plan.moe_ep == (tp_ep and cfg.n_experts % tp == 0)
    return eng.generate(_prompts(cfg, 4, seed=seed))


@needs2
def test_moe_ep_tp2_matches_single_device(olmoe):
    assert _gen(olmoe, tp=2) == _gen(olmoe, tp=1)


@needs2
def test_moe_ep_matches_replicated_experts(olmoe):
    """EP sliced expert gemms vs the same mesh running every expert
    replicated: bit-identical outputs (per-expert problems unchanged)."""
    assert _gen(olmoe, tp=2, tp_ep=True) == _gen(olmoe, tp=2, tp_ep=False)


@needs4
def test_moe_ep_tp4_matches_single_device(olmoe):
    assert _gen(olmoe, tp=4) == _gen(olmoe, tp=1)
