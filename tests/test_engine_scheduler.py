"""Serving-engine scheduler tests: on-device decode loop parity, continuous
batching (slot admission/eviction/reuse), ragged prompts, sampling
determinism, O(1)-host-syncs-per-sequence accounting, and the SLO
admission surface (arrival-time TTFT, deadlines, priorities, preemption,
backpressure)."""
import time

import jax
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.models import transformer as T
from repro.serving.engine import Engine, EngineSaturated, ServeConfig


@pytest.fixture(scope="module")
def model():
    cfg = get_arch("tinyllama-1.1b", reduced=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _engine(model, **kw):
    cfg, params = model
    base = dict(max_new_tokens=6, cache_len=64, decode_chunk=6, max_slots=2)
    base.update(kw)
    return Engine(cfg, params, ServeConfig(**base))


def _prompts(cfg, n, lo=2, hi=9, seed=0):
    rng = np.random.default_rng(seed)
    return [list(rng.integers(0, cfg.vocab_size, int(k)))
            for k in rng.integers(lo, hi, n)]


def test_parity_with_host_loop_reference(model):
    """The on-device while_loop must emit exactly what the pre-rewrite
    host-driven per-token loop emits (same prefill, same sampling math)."""
    cfg, _ = model
    eng = _engine(model, max_new_tokens=8, decode_chunk=3)  # multi-chunk
    prompts = _prompts(cfg, 2)
    fused = eng.generate(prompts)
    fused_syncs = eng.stats["host_syncs"]
    ref = eng.generate_reference(prompts)
    assert fused == ref
    # the whole point: per-chunk syncs, not per-token syncs
    assert fused_syncs < eng.stats["host_syncs"]


def test_continuous_batching_queue_deeper_than_slots(model):
    """5 requests share 2 slots; every sequence completes and matches its
    single-request run exactly (admission isolation + ragged prefill)."""
    cfg, _ = model
    prompts = _prompts(cfg, 5)
    eng = _engine(model)
    outs = eng.generate(prompts)
    assert all(len(o) == 6 for o in outs)
    assert eng.stats["admissions"] == 5
    singles = [_engine(model).generate([p])[0] for p in prompts]
    assert outs == singles


def test_slot_reuse_after_eos(model):
    """A sequence hitting EOS frees its slot mid-stream; queued requests
    are admitted into it and still complete."""
    cfg, _ = model
    prompts = _prompts(cfg, 5)
    free_run = _engine(model, max_new_tokens=16,
                       decode_chunk=16).generate(prompts)
    eos = free_run[0][2]            # a token greedy decode will emit early
    eng = _engine(model, max_new_tokens=16, decode_chunk=16, eos_id=eos)
    outs = eng.generate(prompts)
    assert len(outs) == 5 and all(1 <= len(o) <= 16 for o in outs)
    assert any(len(o) < 16 for o in outs)         # EOS actually fired
    for o in outs:                                 # EOS ends its sequence
        if eos in o:
            assert o.index(eos) == len(o) - 1
    # slots were reused: 5 admissions into 2 slots, in few fused chunks
    assert eng.stats["admissions"] == 5
    assert eng.stats["chunks"] <= 5


def test_sampling_determinism_and_modes(model):
    """Greedy is deterministic call-to-call; temperature sampling is
    deterministic under a fixed seed and varies across seeds."""
    cfg, _ = model
    prompts = _prompts(cfg, 2)
    g = _engine(model, max_new_tokens=8)
    assert g.generate(prompts) == g.generate(prompts)

    t7 = _engine(model, max_new_tokens=8, temperature=0.8, seed=7)
    a, b = t7.generate(prompts), t7.generate(prompts)
    assert a == b                                   # seed-fixed
    t8 = _engine(model, max_new_tokens=8, temperature=0.8, seed=8)
    assert a != t8.generate(prompts)                # seed-sensitive
    # temperature parity with the host-loop reference too
    assert a == t7.generate_reference(prompts)


def test_host_syncs_o1_per_sequence(model):
    """Decode must cost O(1) host syncs per *sequence*: one at admission
    plus one per fused chunk -- independent of tokens generated."""
    cfg, _ = model
    eng = _engine(model, max_new_tokens=24, decode_chunk=32, max_slots=1)
    (out,) = eng.generate(_prompts(cfg, 1))
    assert len(out) == 24
    assert eng.stats["host_syncs"] == 2             # 1 admission + 1 chunk
    eng = _engine(model, max_new_tokens=24, decode_chunk=8, max_slots=1)
    eng.generate(_prompts(cfg, 1))
    assert eng.stats["host_syncs"] == 1 + 3         # ceil(23 steps / 8)


def test_streaming_callbacks_and_budget_override(model):
    """on_token streams every token in order; per-request max_new_tokens
    overrides ride along without recompilation."""
    cfg, _ = model
    eng = _engine(model, max_new_tokens=6)
    seen = {}
    cb = lambda rid, tok: seen.setdefault(rid, []).append(tok)
    prompts = _prompts(cfg, 3)
    ids = [eng.submit(p, on_token=cb,
                      max_new_tokens=3 if i == 1 else None)
           for i, p in enumerate(prompts)]
    res = eng.run()
    assert seen == res
    assert len(res[ids[1]]) == 3
    assert len(res[ids[0]]) == len(res[ids[2]]) == 6


def test_sliding_window_arch_ring_clamp():
    """Windowed archs clamp the KV ring to the window; admission must
    scatter a matching-length slot cache (regression: cache_len=256 vs a
    64-slot ring crashed the first admit)."""
    cfg = get_arch("h2o-danube-1.8b", reduced=True)      # window = 64
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, ServeConfig(max_new_tokens=4, cache_len=256,
                                          decode_chunk=4, max_slots=2))
    outs = eng.generate(_prompts(cfg, 3))
    assert all(len(o) == 4 for o in outs)


def test_sliding_window_long_prompt(model):
    """Windowed archs accept prompts longer than the ring: prefill keeps
    the last window (ring-rolled) and decode continues seamlessly."""
    cfg = get_arch("h2o-danube-1.8b", reduced=True)      # window = 64
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    scfg = ServeConfig(max_new_tokens=4, cache_len=64, decode_chunk=4,
                       max_slots=2)
    eng = Engine(cfg, params, scfg)
    long_prompt = _prompts(cfg, 1, lo=100, hi=101)[0]    # 100 > 64
    outs = eng.generate([long_prompt])
    assert len(outs[0]) == 4
    assert outs == eng.generate_reference([long_prompt])


def test_generate_refuses_to_drop_pending_submits(model):
    """generate() resets engine state, so it must refuse while submitted
    requests are still queued instead of silently discarding them."""
    eng = _engine(model, max_new_tokens=4, decode_chunk=4)
    eng.submit([1, 2, 3])
    with pytest.raises(RuntimeError, match="pending"):
        eng.generate([[4, 5]])
    eng.run()                                  # drain; now generate works
    assert len(eng.generate([[4, 5]])[0]) == 4
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.submit([1, 2], max_new_tokens=0)
    with pytest.raises(ValueError, match="max_slots"):   # would hang run()
        _engine(model, max_slots=0)


def test_full_attention_rejects_ring_wrap(model):
    """Non-windowed archs must refuse work that would wrap the KV ring
    (silent context truncation); windowed archs wrap by design."""
    eng = _engine(model, max_new_tokens=62, cache_len=64)
    with pytest.raises(ValueError, match="cache_len"):
        eng.submit([1, 2, 3])


def test_submit_run_cycles_are_fresh(model):
    """A second submit()+run() cycle on a live engine returns only its own
    requests with per-cycle stats (regression: stale _results leak)."""
    cfg, _ = model
    eng = _engine(model, max_new_tokens=4, decode_chunk=4)
    p1, p2 = _prompts(cfg, 2)
    i1 = eng.submit(p1)
    r1 = eng.run()
    assert set(r1) == {i1}
    i2 = eng.submit(p2)
    r2 = eng.run()
    assert set(r2) == {i2}
    assert eng.stats["requests"] == 1 and eng.stats["tokens"] == 4


def test_batched_admission_token_identical_to_sequential(model):
    """THE acceptance oracle: a queue admitted in batched prefill groups
    must emit token-for-token what one-at-a-time admission emits -- greedy
    AND temperature sampling (per-request keys are split in queue order in
    both schedules)."""
    cfg, _ = model
    prompts = _prompts(cfg, 9, lo=1, hi=14, seed=3)
    for extra in (dict(), dict(temperature=0.7, seed=5)):
        batched = _engine(model, max_slots=4, prefill_batch=4, **extra)
        seq = _engine(model, max_slots=4, prefill_batch=1, **extra)
        outs_b = batched.generate(prompts)
        outs_s = seq.generate(prompts)
        assert outs_b == outs_s
        # batching is real: one prefill sync per GROUP, not per request
        assert batched.stats["admissions"] == seq.stats["admissions"] == 9
        assert (batched.stats["prefill_groups"]
                < seq.stats["prefill_groups"] == 9)
        assert batched.stats["host_syncs"] < seq.stats["host_syncs"]


def test_chunked_prefill_long_prompt_parity(model):
    """Prompts longer than prefill_chunk stream through the fixed-shape
    chunk program; results must match sequential admission and the
    host-loop reference (full-attention arch)."""
    cfg, _ = model
    prompts = _prompts(cfg, 3, lo=18, hi=30, seed=4)
    kw = dict(max_new_tokens=5, cache_len=64, decode_chunk=5,
              max_slots=2, prefill_chunk=8, prefill_bucket=4)
    outs = _engine(model, **kw).generate(prompts)
    seq = _engine(model, prefill_batch=1, **kw).generate(prompts)
    assert outs == seq
    two = _engine(model, **kw)
    assert two.generate(prompts[:2]) == two.generate_reference(prompts[:2])


def test_chunked_prefill_windowed_ring_wrap():
    """A prompt longer than the KV ring, fed chunk-by-chunk, must leave
    exactly the last-window state behind: parity with the host-loop
    reference on a sliding-window arch."""
    cfg = get_arch("h2o-danube-1.8b", reduced=True)      # window = 64
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    scfg = ServeConfig(max_new_tokens=4, cache_len=64, decode_chunk=4,
                       max_slots=2, prefill_chunk=16, prefill_bucket=8)
    eng = Engine(cfg, params, scfg)
    prompts = _prompts(cfg, 2, lo=90, hi=120, seed=5)    # 90+ > ring 64
    assert eng.generate(prompts) == eng.generate_reference(prompts)


def test_prefill_compilations_are_bucketed(model):
    """Ragged prompt lengths inside one bucket share one padded shape:
    a deep ragged queue admits in prefill_groups, each a single fused
    prefill (prefill tokens accounted per true lengths, not pads)."""
    cfg, _ = model
    prompts = _prompts(cfg, 8, lo=1, hi=16, seed=6)
    eng = _engine(model, max_slots=4, prefill_batch=4, prefill_bucket=16)
    eng.generate(prompts)
    assert eng.stats["prefill_groups"] == 2              # 8 reqs / groups of 4
    assert eng.stats["prefill_tokens"] == sum(len(p) for p in prompts)
    assert eng.stats["ttft_s"] > 0


def test_cancel_queued_and_running(model):
    """cancel(): a queued request never runs; a running request keeps its
    streamed prefix and frees its slot; unknown ids return False."""
    cfg, _ = model
    eng = _engine(model, max_new_tokens=12, decode_chunk=3)
    a = eng.submit(_prompts(cfg, 1, seed=7)[0])
    b = eng.submit(_prompts(cfg, 1, seed=8)[0])
    assert eng.cancel(b)
    assert not eng.cancel(b) and not eng.cancel(999)
    # cancel `c` mid-stream from its own token callback
    seen = []

    def cb(rid, tok):
        seen.append(tok)
        if len(seen) == 4:
            eng.cancel(rid)
    c = eng.submit(_prompts(cfg, 1, seed=9)[0], on_token=cb)
    res = eng.run()
    assert set(res) == {a, b, c}
    assert res[b] == []                                  # never admitted
    assert len(res[a]) == 12                             # untouched
    assert 1 <= len(res[c]) < 12                         # partial kept
    # engine drains cleanly afterwards
    assert len(eng.generate([_prompts(cfg, 1, seed=10)[0]])[0]) == 12
    # regression: cancelling from the FIRST token's callback must stick
    # (the slot is bound before the admission-time emit, so cancel() can
    # find and free it)
    eng2 = _engine(model, max_new_tokens=12, decode_chunk=3)
    d = eng2.submit(_prompts(cfg, 1, seed=13)[0],
                    on_token=lambda rid, tok: eng2.cancel(rid))
    res2 = eng2.run()
    assert res2[d] == res2[d][:1] and len(res2[d]) == 1


def test_stats_all_requests_cancelled_at_first_token(model):
    """Every request cancels itself from its first on_token callback, so
    decode never runs: rate stats must come back 0 (not the absurd
    ntok/1e-9 the old max() guard produced, and no ZeroDivisionError),
    with accept_rate 0 when spec_rounds == 0."""
    cfg, _ = model
    eng = _engine(model, max_new_tokens=8, decode_chunk=4)
    cb = lambda rid, tok: eng.cancel(rid)
    ids = [eng.submit(p, on_token=cb) for p in _prompts(cfg, 3)]
    res = eng.run()
    assert all(len(res[i]) == 1 for i in ids)       # first token kept
    s = eng.stats
    assert s["decode_s"] == 0.0 and s["tokens"] == 3
    assert s["tok_per_s"] == 0.0                    # guarded, not ~3e9
    assert s["accept_rate"] == 0.0 and s["spec_rounds"] == 0
    assert np.isfinite([s["tok_per_s"], s["prefill_tok_per_s"],
                        s["ttft_s"], s["accept_rate"]]).all()
    # a run() with nothing submitted finalizes all-zero rates too
    assert eng.run() == {}
    assert eng.stats["tok_per_s"] == eng.stats["prefill_tok_per_s"] == 0.0


def test_prefill_chunk_boundary_invariance(model):
    """Where chunk boundaries fall must not change a single token: the
    chunk's own keys are attended at ring dtype (the value decode would
    later read back), so chunk=1 (decode-like), chunk=4 and one-shot
    prefill agree exactly."""
    cfg, _ = model
    prompts = _prompts(cfg, 2, lo=9, hi=14, seed=12)
    outs = [
        _engine(model, max_new_tokens=5, decode_chunk=5,
                prefill_chunk=chunk, prefill_bucket=1).generate(prompts)
        for chunk in (1, 4, 32)
    ]
    assert outs[0] == outs[1] == outs[2]


def test_int8_kv_cache_chunked_prefill():
    """kv_cache_quant engine path: chunked prefill quantizes each chunk's
    K/V at the same per-token-head granularity decode uses, so chunk
    placement is invisible and batched == sequential admission holds."""
    cfg = get_arch("llama3.2-1b", reduced=True).replace(
        kv_cache_quant=True, dtype="float32")
    params = T.init_params(cfg, jax.random.PRNGKey(0))

    def mk(**kw):
        base = dict(max_new_tokens=4, cache_len=64, decode_chunk=4,
                    max_slots=2, prefill_bucket=4)
        base.update(kw)
        return Engine(cfg, params, ServeConfig(**base))

    prompts = _prompts(cfg, 3, lo=10, hi=20, seed=11)
    outs = mk(prefill_chunk=8).generate(prompts)         # multi-chunk
    assert outs == mk(prefill_chunk=8, prefill_batch=1).generate(prompts)
    assert outs == mk(prefill_chunk=32).generate(prompts)  # single chunk
    ref_eng = mk(prefill_chunk=8)
    assert ref_eng.generate(prompts[:2]) == \
        ref_eng.generate_reference(prompts[:2])


def test_scheduler_recurrent_family():
    """SSM family rides the batched masked-chunk prefill path (trailing
    pads are dt-masked so they never pollute the recurrent state, and the
    chunk grid is fixed so chunk boundaries land identically for every
    batch shape); batched continuous run matches single-request runs."""
    cfg = get_arch("mamba2-2.7b", reduced=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    scfg = dict(max_new_tokens=4, cache_len=64, decode_chunk=4, max_slots=2)
    prompts = _prompts(cfg, 3, seed=1)
    outs = Engine(cfg, params, ServeConfig(**scfg)).generate(prompts)
    singles = [Engine(cfg, params, ServeConfig(**scfg)).generate([p])[0]
               for p in prompts]
    assert outs == singles


def test_requests_stat_counts_callback_submissions(model):
    """A request submitted from an on_token callback mid-cycle is served
    in the same run() -- and must be COUNTED: stats["requests"] used to
    be stamped from len(queue) at entry, so follow-ups were served but
    invisible (regression). Now it counts admissions over the cycle."""
    cfg, _ = model
    eng = _engine(model, max_new_tokens=4, decode_chunk=4)
    follow = _prompts(cfg, 1, seed=13)[0]
    fired = []

    def cb(rid, tok):
        if not fired:
            fired.append(eng.submit(follow))
    ids = [eng.submit(p, on_token=cb) for p in _prompts(cfg, 2, seed=12)]
    res = eng.run()
    assert set(res) == {*ids, fired[0]}             # follow-up served
    assert len(res[fired[0]]) == 4
    assert eng.stats["requests"] == 3               # ...and counted
    assert eng.stats["admissions"] == 3


# ---------------------------------------------------------------------------
# arrival-time TTFT accounting + the SLO admission surface
# ---------------------------------------------------------------------------

def test_ttft_stamped_from_arrival_not_run_entry(model):
    """THE accounting bugfix: a request submitted mid-cycle (from another
    request's on_token callback) measures TTFT from ITS OWN submit(), not
    from run() entry. The old run()-entry stamp charged the follow-up for
    everything that happened before it arrived -- here an explicit 0.5s
    sleep -- so its TTFT came out ~ the full cycle wall time."""
    cfg, _ = model
    eng = _engine(model, max_new_tokens=8, decode_chunk=2)
    done, fired = {}, []
    follow = _prompts(cfg, 1, seed=21)[0]

    def cb(rid, tok):
        if not fired:
            time.sleep(0.5)         # run-entry inflation, made visible
            fired.append(eng.submit(
                follow, on_done=lambda r: done.setdefault("f", r)))
    eng.submit(_prompts(cfg, 1, seed=20)[0], on_token=cb)
    t0 = time.perf_counter()
    eng.run()
    wall = time.perf_counter() - t0
    req = done["f"]
    assert req.ttft_s is not None and req.queue_wait_s is not None
    # arrival stamping cannot include the pre-arrival sleep; run-entry
    # stamping always did (ttft would be > 0.5 ~ wall)
    assert req.ttft_s < wall - 0.4
    assert 0.0 <= req.queue_wait_s <= req.ttft_s + 1e-9


def test_ttft_percentile_stats(model):
    """_finalize_stats reports tail TTFT (p50/p99 over the cycle's
    requests) and mean queue wait alongside the historical mean."""
    cfg, _ = model
    eng = _engine(model, max_slots=2)
    eng.generate(_prompts(cfg, 5))
    s = eng.stats
    assert 0 < s["ttft_p50_s"] <= s["ttft_p99_s"]
    assert s["ttft_s"] > 0 and s["queue_wait_s"] >= 0.0
    assert s["deadline_misses"] == 0 and s["preemptions"] == 0


def test_single_priority_parity_with_slo_features_enabled(model):
    """Uniform priority / no deadlines drains exactly FIFO: token output
    is identical to the plain engine even with preemption armed and the
    queue bounded (the SLO machinery must be invisible until used)."""
    cfg, _ = model
    prompts = _prompts(cfg, 5, seed=14)
    plain = _engine(model).generate(prompts)
    slo = _engine(model, preempt=True, max_queue=50)
    ids = [slo.submit(p, priority=0) for p in prompts]
    res = slo.run()
    assert [res[i] for i in ids] == plain


def test_deadline_ordered_admission_beats_fifo(model):
    """One slot, two queued requests: FIFO admits in submit order, so the
    late tight-deadline request waits out the whole first decode. The
    deadline-ordered drain admits it first."""
    cfg, _ = model
    a_p, b_p = _prompts(cfg, 2, seed=15)
    order_fifo, order_slo = [], []

    def first(order):
        return lambda rid, tok: (order.append(rid)
                                 if rid not in order else None)
    fifo = _engine(model, max_slots=1, max_new_tokens=6)
    fa = fifo.submit(a_p, on_token=first(order_fifo))
    fb = fifo.submit(b_p, on_token=first(order_fifo))
    fifo.run()
    assert order_fifo == [fa, fb]                   # the baseline miss
    slo = _engine(model, max_slots=1, max_new_tokens=6)
    done = {}
    sa = slo.submit(a_p, on_token=first(order_slo))
    sb = slo.submit(b_p, on_token=first(order_slo), deadline_s=30.0,
                    on_done=lambda r: done.setdefault("b", r))
    slo.run()
    assert order_slo == [sb, sa]                    # deadline jumps queue
    assert not done["b"].deadline_missed


def test_deadline_miss_accounting(model):
    """deadline_s=0 can never be met -> deadline_missed + stats counter;
    a generous deadline is met and does not count."""
    cfg, _ = model
    eng = _engine(model)
    got = {}
    eng.submit(_prompts(cfg, 1, seed=22)[0], deadline_s=0.0,
               on_done=lambda r: got.setdefault("miss", r))
    eng.submit(_prompts(cfg, 1, seed=23)[0], deadline_s=1e9,
               on_done=lambda r: got.setdefault("ok", r))
    eng.run()
    assert got["miss"].deadline_missed and not got["ok"].deadline_missed
    assert eng.stats["deadline_misses"] == 1


def test_backpressure_structured_rejection(model):
    """With max_queue set, submit() sheds load with a machine-readable
    EngineSaturated instead of growing the queue without bound -- and
    accepts again once the queue drains."""
    cfg, _ = model
    eng = _engine(model, max_queue=2)
    p = _prompts(cfg, 3)
    eng.submit(p[0])
    eng.submit(p[1])
    with pytest.raises(EngineSaturated) as ei:
        eng.submit(p[2])
    assert ei.value.reason == "queue_full"
    assert "max_queue=2" in ei.value.detail
    eng.run()
    rid = eng.submit(p[2])                          # queue drained: accepted
    assert len(eng.run()[rid]) == 6


def test_backpressure_page_pool_saturation(model):
    """prefix_bytes=1 floors the page pool at 2 pages: a 3-page prompt is
    rejected with reason "page_pool_saturated" (admitting it could only
    thrash the pool), while a 1-page prompt still serves."""
    cfg, _ = model
    eng = _engine(model, max_queue=8, max_new_tokens=4, prefix_cache=True,
                  prefix_page=8, prefix_bytes=1)
    long_p = _prompts(cfg, 1, lo=20, hi=21)[0]      # ceil(20/8)=3 > cap 2
    with pytest.raises(EngineSaturated) as ei:
        eng.submit(long_p)
    assert ei.value.reason == "page_pool_saturated"
    short = _prompts(cfg, 1, lo=4, hi=6)[0]         # 1 page: admitted
    assert len(eng.generate([short])[0]) == 4


def test_preemption_keeps_streamed_tokens(model):
    """ServeConfig.preempt: a strictly-higher-priority arrival evicts the
    lowest-priority running request at a chunk boundary. The victim keeps
    every token it streamed (the ordinary cancel contract) and is marked
    preempted; the winner runs to completion."""
    cfg, _ = model
    eng = _engine(model, max_slots=1, max_new_tokens=12, decode_chunk=2,
                  preempt=True)
    done, low_toks, hi = {}, [], []

    def low_cb(rid, tok):
        low_toks.append(tok)
        if len(low_toks) == 2:
            hi.append(eng.submit(
                _prompts(cfg, 1, seed=17)[0], priority=1,
                on_done=lambda r: done.setdefault("hi", r)))
    low = eng.submit(_prompts(cfg, 1, seed=16)[0], on_token=low_cb,
                     on_done=lambda r: done.setdefault("low", r))
    res = eng.run()
    assert done["low"].preempted and done["low"].cancelled
    assert 2 <= len(res[low]) < 12                  # streamed prefix kept
    assert res[low] == low_toks
    assert len(res[hi[0]]) == 12                    # winner unharmed
    assert eng.stats["preemptions"] == 1
    assert not done["hi"].preempted
