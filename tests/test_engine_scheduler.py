"""Serving-engine scheduler tests: on-device decode loop parity, continuous
batching (slot admission/eviction/reuse), ragged prompts, sampling
determinism, and O(1)-host-syncs-per-sequence accounting."""
import jax
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.models import transformer as T
from repro.serving.engine import Engine, ServeConfig


@pytest.fixture(scope="module")
def model():
    cfg = get_arch("tinyllama-1.1b", reduced=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _engine(model, **kw):
    cfg, params = model
    base = dict(max_new_tokens=6, cache_len=64, decode_chunk=6, max_slots=2)
    base.update(kw)
    return Engine(cfg, params, ServeConfig(**base))


def _prompts(cfg, n, lo=2, hi=9, seed=0):
    rng = np.random.default_rng(seed)
    return [list(rng.integers(0, cfg.vocab_size, int(k)))
            for k in rng.integers(lo, hi, n)]


def test_parity_with_host_loop_reference(model):
    """The on-device while_loop must emit exactly what the pre-rewrite
    host-driven per-token loop emits (same prefill, same sampling math)."""
    cfg, _ = model
    eng = _engine(model, max_new_tokens=8, decode_chunk=3)  # multi-chunk
    prompts = _prompts(cfg, 2)
    fused = eng.generate(prompts)
    fused_syncs = eng.stats["host_syncs"]
    ref = eng.generate_reference(prompts)
    assert fused == ref
    # the whole point: per-chunk syncs, not per-token syncs
    assert fused_syncs < eng.stats["host_syncs"]


def test_continuous_batching_queue_deeper_than_slots(model):
    """5 requests share 2 slots; every sequence completes and matches its
    single-request run exactly (admission isolation + ragged prefill)."""
    cfg, _ = model
    prompts = _prompts(cfg, 5)
    eng = _engine(model)
    outs = eng.generate(prompts)
    assert all(len(o) == 6 for o in outs)
    assert eng.stats["admissions"] == 5
    singles = [_engine(model).generate([p])[0] for p in prompts]
    assert outs == singles


def test_slot_reuse_after_eos(model):
    """A sequence hitting EOS frees its slot mid-stream; queued requests
    are admitted into it and still complete."""
    cfg, _ = model
    prompts = _prompts(cfg, 5)
    free_run = _engine(model, max_new_tokens=16,
                       decode_chunk=16).generate(prompts)
    eos = free_run[0][2]            # a token greedy decode will emit early
    eng = _engine(model, max_new_tokens=16, decode_chunk=16, eos_id=eos)
    outs = eng.generate(prompts)
    assert len(outs) == 5 and all(1 <= len(o) <= 16 for o in outs)
    assert any(len(o) < 16 for o in outs)         # EOS actually fired
    for o in outs:                                 # EOS ends its sequence
        if eos in o:
            assert o.index(eos) == len(o) - 1
    # slots were reused: 5 admissions into 2 slots, in few fused chunks
    assert eng.stats["admissions"] == 5
    assert eng.stats["chunks"] <= 5


def test_sampling_determinism_and_modes(model):
    """Greedy is deterministic call-to-call; temperature sampling is
    deterministic under a fixed seed and varies across seeds."""
    cfg, _ = model
    prompts = _prompts(cfg, 2)
    g = _engine(model, max_new_tokens=8)
    assert g.generate(prompts) == g.generate(prompts)

    t7 = _engine(model, max_new_tokens=8, temperature=0.8, seed=7)
    a, b = t7.generate(prompts), t7.generate(prompts)
    assert a == b                                   # seed-fixed
    t8 = _engine(model, max_new_tokens=8, temperature=0.8, seed=8)
    assert a != t8.generate(prompts)                # seed-sensitive
    # temperature parity with the host-loop reference too
    assert a == t7.generate_reference(prompts)


def test_host_syncs_o1_per_sequence(model):
    """Decode must cost O(1) host syncs per *sequence*: one at admission
    plus one per fused chunk -- independent of tokens generated."""
    cfg, _ = model
    eng = _engine(model, max_new_tokens=24, decode_chunk=32, max_slots=1)
    (out,) = eng.generate(_prompts(cfg, 1))
    assert len(out) == 24
    assert eng.stats["host_syncs"] == 2             # 1 admission + 1 chunk
    eng = _engine(model, max_new_tokens=24, decode_chunk=8, max_slots=1)
    eng.generate(_prompts(cfg, 1))
    assert eng.stats["host_syncs"] == 1 + 3         # ceil(23 steps / 8)


def test_streaming_callbacks_and_budget_override(model):
    """on_token streams every token in order; per-request max_new_tokens
    overrides ride along without recompilation."""
    cfg, _ = model
    eng = _engine(model, max_new_tokens=6)
    seen = {}
    cb = lambda rid, tok: seen.setdefault(rid, []).append(tok)
    prompts = _prompts(cfg, 3)
    ids = [eng.submit(p, on_token=cb,
                      max_new_tokens=3 if i == 1 else None)
           for i, p in enumerate(prompts)]
    res = eng.run()
    assert seen == res
    assert len(res[ids[1]]) == 3
    assert len(res[ids[0]]) == len(res[ids[2]]) == 6


def test_sliding_window_arch_ring_clamp():
    """Windowed archs clamp the KV ring to the window; admission must
    scatter a matching-length slot cache (regression: cache_len=256 vs a
    64-slot ring crashed the first admit)."""
    cfg = get_arch("h2o-danube-1.8b", reduced=True)      # window = 64
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, ServeConfig(max_new_tokens=4, cache_len=256,
                                          decode_chunk=4, max_slots=2))
    outs = eng.generate(_prompts(cfg, 3))
    assert all(len(o) == 4 for o in outs)


def test_sliding_window_long_prompt(model):
    """Windowed archs accept prompts longer than the ring: prefill keeps
    the last window (ring-rolled) and decode continues seamlessly."""
    cfg = get_arch("h2o-danube-1.8b", reduced=True)      # window = 64
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    scfg = ServeConfig(max_new_tokens=4, cache_len=64, decode_chunk=4,
                       max_slots=2)
    eng = Engine(cfg, params, scfg)
    long_prompt = _prompts(cfg, 1, lo=100, hi=101)[0]    # 100 > 64
    outs = eng.generate([long_prompt])
    assert len(outs[0]) == 4
    assert outs == eng.generate_reference([long_prompt])


def test_generate_refuses_to_drop_pending_submits(model):
    """generate() resets engine state, so it must refuse while submitted
    requests are still queued instead of silently discarding them."""
    eng = _engine(model, max_new_tokens=4, decode_chunk=4)
    eng.submit([1, 2, 3])
    with pytest.raises(RuntimeError, match="pending"):
        eng.generate([[4, 5]])
    eng.run()                                  # drain; now generate works
    assert len(eng.generate([[4, 5]])[0]) == 4
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.submit([1, 2], max_new_tokens=0)
    with pytest.raises(ValueError, match="max_slots"):   # would hang run()
        _engine(model, max_slots=0)


def test_full_attention_rejects_ring_wrap(model):
    """Non-windowed archs must refuse work that would wrap the KV ring
    (silent context truncation); windowed archs wrap by design."""
    eng = _engine(model, max_new_tokens=62, cache_len=64)
    with pytest.raises(ValueError, match="cache_len"):
        eng.submit([1, 2, 3])


def test_submit_run_cycles_are_fresh(model):
    """A second submit()+run() cycle on a live engine returns only its own
    requests with per-cycle stats (regression: stale _results leak)."""
    cfg, _ = model
    eng = _engine(model, max_new_tokens=4, decode_chunk=4)
    p1, p2 = _prompts(cfg, 2)
    i1 = eng.submit(p1)
    r1 = eng.run()
    assert set(r1) == {i1}
    i2 = eng.submit(p2)
    r2 = eng.run()
    assert set(r2) == {i2}
    assert eng.stats["requests"] == 1 and eng.stats["tokens"] == 4


def test_scheduler_recurrent_family():
    """SSM family: exact-length prefill (no pad pollution of the recurrent
    state); batched continuous run matches single-request runs."""
    cfg = get_arch("mamba2-2.7b", reduced=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    scfg = dict(max_new_tokens=4, cache_len=64, decode_chunk=4, max_slots=2)
    prompts = _prompts(cfg, 3, seed=1)
    outs = Engine(cfg, params, ServeConfig(**scfg)).generate(prompts)
    singles = [Engine(cfg, params, ServeConfig(**scfg)).generate([p])[0]
               for p in prompts]
    assert outs == singles
