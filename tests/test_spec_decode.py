"""Speculative decoding parity suite.

THE oracle: greedy speculative decode must be TOKEN-IDENTICAL to plain
(non-speculative) decode -- for every drafter, across attention families
(causal, sliding-window/ring-wrap, int8-KV), through EOS landing inside
an accepted draft block, mid-stream cancel(), ragged budgets, and mixed
speculative/plain batches. Temperature mode has no plain-decode oracle
(the key stream differs by construction), so it is validated against
``generate_spec_reference`` -- a host-driven loop that re-implements the
rejection-sampling/acceptance bookkeeping in numpy over the same raw
logits and keys.

The guarantee is backed by ``draft_verify="scan"`` (the default), which
replays the exact decode_step program per draft column; the "batched"
masked-forward datapath is checked for determinism and well-formedness
(its logits are equal only to within float rounding, so a greedy argmax
may flip on a near-tie -- documented, not promised)."""
import jax
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.models import transformer as T
from repro.serving.engine import Engine, ServeConfig

DRAFTERS = ("ngram", "self")


def _prompts(cfg, n, lo=2, hi=12, seed=0, repetitive_first=True):
    rng = np.random.default_rng(seed)
    ps = [list(rng.integers(0, cfg.vocab_size, int(m)))
          for m in rng.integers(lo, hi, n)]
    if repetitive_first:
        ps[0] = [7, 11] * 4          # prompt-lookup's home turf
    return ps


@pytest.fixture(scope="module")
def causal():
    cfg = get_arch("tinyllama-1.1b", reduced=True)
    return cfg, T.init_params(cfg, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def windowed():
    cfg = get_arch("h2o-danube-1.8b", reduced=True)      # window = 64
    return cfg, T.init_params(cfg, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def int8kv():
    cfg = get_arch("llama3.2-1b", reduced=True).replace(
        kv_cache_quant=True, dtype="float32")
    return cfg, T.init_params(cfg, jax.random.PRNGKey(0))


def _mk(model, drafter=None, **kw):
    cfg, params = model
    base = dict(max_new_tokens=8, cache_len=64, decode_chunk=10,
                max_slots=3, prefill_bucket=4, prefill_chunk=8,
                drafter=drafter, draft_k=3)
    base.update(kw)
    return Engine(cfg, params, ServeConfig(**base))


# ---------------------------------------------------------------------------
# greedy parity: spec == plain, token for token
# ---------------------------------------------------------------------------

def test_greedy_parity_causal(causal):
    prompts = _prompts(causal[0], 5)
    ref = _mk(causal).generate(prompts)
    for drafter in DRAFTERS:
        eng = _mk(causal, drafter=drafter)
        assert eng.generate(prompts) == ref, drafter
        assert eng.stats["spec_rounds"] > 0
        assert eng.stats["draft_tokens"] > 0


def test_greedy_parity_sliding_window_ring_wrap(windowed):
    """Drafts written (and rolled back) across the ring-wrap boundary:
    prompts longer than the 64-slot ring force mid-block wrap, and the
    rewind must restore the overwritten still-in-window entries."""
    cfg, _ = windowed
    rng = np.random.default_rng(3)
    prompts = [list(rng.integers(0, cfg.vocab_size, 90)),   # 90 > ring 64
               [3, 5] * 10]
    ref = _mk(windowed, max_slots=2, prefill_chunk=16).generate(prompts)
    for drafter in DRAFTERS:
        eng = _mk(windowed, drafter=drafter, max_slots=2, prefill_chunk=16)
        assert eng.generate(prompts) == ref, drafter


def test_greedy_parity_int8_kv(int8kv):
    prompts = _prompts(int8kv[0], 4, seed=2)
    ref = _mk(int8kv, max_new_tokens=6).generate(prompts)
    for drafter in DRAFTERS:
        eng = _mk(int8kv, drafter=drafter, max_new_tokens=6,
                  draft_layers=1)
        assert eng.generate(prompts) == ref, drafter


def test_greedy_parity_full_attention_ring_end(causal):
    """Full-attention slots within draft_k of the ring end must fall back
    to plain steps (draft positions may never wrap a full-attention
    ring); output stays identical to plain decode right up to a
    completely full ring (prompt + budget == cache_len)."""
    cfg, _ = causal
    rng = np.random.default_rng(4)
    prompts = [list(rng.integers(0, cfg.vocab_size, 8))]   # 8 + 8 == 16
    ref = _mk(causal, cache_len=16, max_slots=1).generate(prompts)
    for drafter in DRAFTERS:
        eng = _mk(causal, drafter=drafter, cache_len=16, max_slots=1)
        assert eng.generate(prompts) == ref, drafter


def test_ring_end_flush_boundary_sweep(causal):
    """Exhaustive full-attention ring-end boundary: for every prompt
    length p with p + budget == cache_len EXACTLY (a completely full ring
    at the last token), both drafters, the spec engine must match plain
    decode token for token. This sweeps the clamp's edge cases: the
    draft_k fallback window engaging at different points mid-sequence
    (pos + draft_k == T-1 vs == T), budget truncation landing inside an
    accepted block right at the ring end, and prompts so close to the end
    that speculation never activates (cache_len - p <= draft_k). A
    verify round writes pos..pos+draft_k before rewinding, so the clamp
    ``pos + draft_k < T`` is exactly the largest safe region -- this test
    is the regression net for anyone re-deriving it."""
    cfg, _ = causal
    Tring = 16
    rng = np.random.default_rng(7)
    for p in (3, 8, 11, 13, 14):
        prompts = [list(rng.integers(0, cfg.vocab_size, p))]
        budget = Tring - p                       # flush: p + budget == T
        ref_eng = _mk(causal, cache_len=Tring, max_slots=1,
                      max_new_tokens=budget)
        ref = ref_eng.generate(prompts)
        assert ref == ref_eng.generate_reference(prompts)
        for drafter in DRAFTERS:
            eng = _mk(causal, drafter=drafter, cache_len=Tring,
                      max_slots=1, max_new_tokens=budget)
            assert eng.generate(prompts) == ref, (drafter, p)
    # multi-slot: ragged prompts flushing against the ring at different
    # steps, so some slots speculate while others are already clamped
    prompts = [list(rng.integers(0, cfg.vocab_size, p))
               for p in (3, 9, 13)]
    ref = _mk(causal, cache_len=Tring, max_slots=3,
              max_new_tokens=3).generate(prompts)
    for drafter in DRAFTERS:
        eng = _mk(causal, drafter=drafter, cache_len=Tring, max_slots=3,
                  max_new_tokens=3)
        assert eng.generate(prompts) == ref, drafter


def test_greedy_parity_mixed_spec_and_plain_slots(causal):
    """A continuous batch mixing speculate=True/False requests matches
    plain decode for every request -- and toggling is per-request, not
    per-engine."""
    prompts = _prompts(causal[0], 6, seed=5)
    plain = _mk(causal)
    ref_ids = [plain.submit(p) for p in prompts]
    ref = plain.run()
    eng = _mk(causal, drafter="ngram")
    ids = [eng.submit(p, speculate=(i % 2 == 0))
           for i, p in enumerate(prompts)]
    res = eng.run()
    assert [res[i] for i in ids] == [ref[i] for i in ref_ids]


def test_greedy_host_oracle_agrees(causal):
    """The host-driven spec reference loop (numpy acceptance over the
    same logits/keys) emits exactly what the fused device loop emits."""
    prompts = _prompts(causal[0], 3, seed=6)
    for drafter in DRAFTERS:
        a = _mk(causal, drafter=drafter)
        b = _mk(causal, drafter=drafter)
        assert a.generate(prompts) == b.generate_spec_reference(prompts)


# ---------------------------------------------------------------------------
# EOS / budget / cancel inside draft blocks
# ---------------------------------------------------------------------------

def test_eos_inside_accepted_draft_block(causal):
    """Pick an EOS id greedy decode emits mid-stream and use the
    full-depth self-drafter (acceptance == 1.0), so the EOS token arrives
    INSIDE an accepted block: emission must stop exactly at the EOS, the
    slot must free, and everything must equal plain decode with the same
    EOS."""
    cfg, _ = causal
    prompts = _prompts(cfg, 4, seed=7)
    free = _mk(causal, max_new_tokens=12, decode_chunk=13).generate(prompts)
    eos = free[0][2]                       # emitted early by greedy decode
    ref_eng = _mk(causal, max_new_tokens=12, decode_chunk=13, eos_id=eos)
    ref = ref_eng.generate(prompts)
    assert any(len(o) < 12 for o in ref)               # EOS really fired
    eng = _mk(causal, drafter="self", draft_layers=cfg.n_layers,
              max_new_tokens=12, decode_chunk=13, eos_id=eos)
    outs = eng.generate(prompts)
    assert outs == ref
    assert eng.stats["accept_rate"] > 0.9              # blocks were accepted
    for o in outs:
        if eos in o:
            assert o.index(eos) == len(o) - 1          # EOS ends its seq


def test_ragged_budgets_and_instant_finish(causal):
    """Per-request budgets not aligned to draft_k truncate accepted
    blocks exactly; budget-1 requests finish at admission and never
    speculate."""
    cfg, _ = causal
    prompts = _prompts(cfg, 5, seed=8)
    budgets = [1, 2, 5, 7, 8]
    plain = _mk(causal)
    rids = [plain.submit(p, max_new_tokens=b)
            for p, b in zip(prompts, budgets)]
    ref = plain.run()
    eng = _mk(causal, drafter="ngram")
    ids = [eng.submit(p, max_new_tokens=b)
           for p, b in zip(prompts, budgets)]
    res = eng.run()
    assert [res[i] for i in ids] == [ref[i] for i in rids]
    assert all(len(res[i]) == b for i, b in zip(ids, budgets))


def test_midstream_cancel_during_speculation(causal):
    """cancel() from an on_token callback mid-speculation keeps the
    streamed prefix, frees the slot, and leaves the other sequences
    bit-identical to plain decode."""
    cfg, _ = causal
    prompts = _prompts(cfg, 3, seed=9)

    def run(drafter):
        eng = _mk(causal, drafter=drafter, max_new_tokens=10,
                  decode_chunk=11)
        seen = []

        def cb(rid, tok):
            seen.append(tok)
            if len(seen) == 3:
                eng.cancel(rid)
        a = eng.submit(prompts[0], on_token=cb)
        b = eng.submit(prompts[1])
        c = eng.submit(prompts[2])
        res = eng.run()
        return res[a], res[b], res[c]

    ref = run(None)
    for drafter in DRAFTERS:
        got = run(drafter)
        # the cancelled stream stops within one chunk of the callback;
        # its kept prefix and both survivors must match plain decode
        assert got[0] == ref[0][:len(got[0])] and len(got[0]) >= 3
        assert got[1:] == ref[1:]
        # drain cleanly afterwards
    # cancel() of a still-queued request under speculation never runs
    eng = _mk(causal, drafter="ngram", max_slots=1)
    x = eng.submit(prompts[0])
    y = eng.submit(prompts[1])
    assert eng.cancel(y)
    res = eng.run()
    assert res[y] == [] and len(res[x]) == 8


# ---------------------------------------------------------------------------
# temperature: rejection sampling vs the host oracle
# ---------------------------------------------------------------------------

def test_temperature_matches_host_rejection_oracle(causal):
    prompts = _prompts(causal[0], 3, seed=10)
    for drafter in DRAFTERS:
        a = _mk(causal, drafter=drafter, temperature=0.8, seed=11)
        b = _mk(causal, drafter=drafter, temperature=0.8, seed=11)
        oa = a.generate(prompts)
        ob = b.generate_spec_reference(prompts)
        assert oa == ob, drafter
        # seed-fixed determinism of the speculative temperature path
        assert oa == a.generate(prompts)


def test_temperature_seed_sensitivity(causal):
    prompts = _prompts(causal[0], 2, seed=12)
    a = _mk(causal, drafter="ngram", temperature=0.9, seed=1)
    b = _mk(causal, drafter="ngram", temperature=0.9, seed=2)
    assert a.generate(prompts) != b.generate(prompts)


# ---------------------------------------------------------------------------
# acceptance accounting, batched verify mode, validation
# ---------------------------------------------------------------------------

def test_full_depth_self_drafter_accepts_everything(causal):
    """draft_layers == n_layers makes the draft model THE target model:
    greedy acceptance must be exactly 1.0 (the strongest internal
    consistency check on the verify/accept path)."""
    cfg, _ = causal
    eng = _mk(causal, drafter="self", draft_layers=cfg.n_layers)
    eng.generate(_prompts(cfg, 3, seed=13))
    assert eng.stats["accept_rate"] == 1.0
    assert eng.stats["draft_accepted"] == eng.stats["draft_tokens"] > 0
    # each round serves every slot: k+1 tokens/slot/round at full
    # acceptance, i.e. FAR fewer verify rounds than tokens
    assert eng.stats["tokens"] <= (eng.stats["spec_rounds"]
                                   * (eng.scfg.draft_k + 1)
                                   * eng.scfg.max_slots)
    assert eng.stats["spec_rounds"] < eng.stats["tokens"]


def test_batched_verify_mode_deterministic(causal):
    """The one-masked-forward verify datapath: deterministic run-to-run,
    budget-exact, and its host-visible accounting is sane. (Bit-parity
    with plain decode is only promised by draft_verify='scan'.)"""
    prompts = _prompts(causal[0], 4, seed=14)
    eng = _mk(causal, drafter="ngram", draft_verify="batched")
    o1 = eng.generate(prompts)
    o2 = eng.generate(prompts)
    assert o1 == o2
    assert all(len(o) == 8 for o in o1)
    assert eng.stats["draft_tokens"] > 0


def test_spec_config_validation(causal):
    cfg, params = causal
    with pytest.raises(ValueError, match="decode_chunk"):
        Engine(cfg, params, ServeConfig(drafter="ngram", draft_k=8,
                                        decode_chunk=8))
    with pytest.raises(ValueError, match="draft_verify"):
        Engine(cfg, params, ServeConfig(drafter="ngram",
                                        draft_verify="nope"))
    with pytest.raises(ValueError, match="unknown drafter"):
        Engine(cfg, params, ServeConfig(drafter="oracle"))
    with pytest.raises(ValueError, match="draft_layers"):
        Engine(cfg, params, ServeConfig(drafter="self", draft_layers=99))
    with pytest.raises(ValueError, match="draft_hist"):
        Engine(cfg, params, ServeConfig(drafter="ngram", draft_ngram=9,
                                        draft_hist=8))
    ssm = get_arch("mamba2-2.7b", reduced=True)
    with pytest.raises(ValueError, match="recurrent"):
        Engine(ssm, T.init_params(ssm, jax.random.PRNGKey(0)),
               ServeConfig(drafter="ngram"))
    eng = _mk(causal)                       # no drafter configured
    with pytest.raises(ValueError, match="drafter"):
        eng.submit([1, 2], speculate=True)


def test_quantized_params_spec_parity(causal):
    """The whole point of the paper: the SAME packed BFP weights serve
    both the draft prefix and the verify pass. Greedy parity must hold
    on a quantized model too."""
    from repro.core.policy import get_policy
    from repro.core.qlinear import quantize_params
    cfg, params = causal
    qp, _ = quantize_params(params, get_policy("paper_llama_mix"))
    prompts = _prompts(cfg, 3, seed=15)
    ref = _mk((cfg, qp), max_new_tokens=6).generate(prompts)
    for drafter in DRAFTERS:
        eng = _mk((cfg, qp), drafter=drafter, max_new_tokens=6,
                  draft_layers=1)
        assert eng.generate(prompts) == ref, drafter
