"""Tensor-parallel serving suite: shard_map TP pinned by multi-device
parity plus host-side sharding-rule unit tests.

THE oracle: greedy serving output is TOKEN-IDENTICAL across mesh shapes
{1, 2, 4} -- across causal / sliding-window / int8-KV attention, with
speculative decoding and the paged prefix cache riding on top, in fp32
AND with a packed quantized policy. The guarantee is by construction,
not luck: weights lane-shard (K rows whole per shard, so packed
super-blocks never straddle devices), the KV cache shards over kv_heads
(slicing a BATCH dim keeps each head's attention sub-problem the same
shape), and the default "padded" matmul datapath zero-embeds each
shard's lanes so every gemm keeps the single-device program shape --
CPU gemms round shape-dependently (pinned below), so same-shape is the
only road to bitwise parity. The "sliced" datapath (true lane-sliced
gemm, 1/size FLOPs per shard) is equal to within an f32 ulp only and is
tested at a documented logit tolerance, same caveat class as
test_spec_decode's batched verify.

Multi-device tests need forced host devices BEFORE jax initializes:

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python -m pytest -x -q tests/test_tp_serving.py

Under the plain tier-1 run (1 device) those tests skip, and a subprocess
test still proves the acceptance core (fp32 parity {1,2,4} with spec +
prefix cache enabled) by forcing 4 devices in a fresh interpreter.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import get_arch
from repro.core.policy import get_policy
from repro.core.qlinear import quantize_params
from repro.core import quantize as Q
from repro.distributed import sharding as SH
from repro.models import transformer as T
from repro.serving.engine import Engine, ServeConfig

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
NDEV = len(jax.devices())
needs4 = pytest.mark.skipif(
    NDEV < 4, reason="needs XLA_FLAGS=--xla_force_host_platform_"
                     "device_count=4 (set before jax initializes)")
needs2 = pytest.mark.skipif(
    NDEV < 2, reason="needs >= 2 devices (force host devices via "
                     "XLA_FLAGS)")

BASE = dict(max_new_tokens=6, cache_len=64, decode_chunk=8, max_slots=3,
            prefill_bucket=4, prefill_chunk=8, prefill_batch=3)


def _prompts(cfg, n, seed=0, lo=2, hi=30, shared=0):
    """Ragged prompts (multi-chunk lengths included); ``shared`` prepends
    a common system prefix (the prefix-cache workload)."""
    rng = np.random.default_rng(seed)
    pre = list(rng.integers(0, cfg.vocab_size, shared))
    return [pre + list(rng.integers(0, cfg.vocab_size,
                                    int(rng.integers(lo, hi))))
            for _ in range(n)]


# ---------------------------------------------------------------------------
# host-side unit tests (no extra devices needed)
# ---------------------------------------------------------------------------

def test_serve_tp_plan_divisibility_fallbacks():
    cfg = get_arch("tinyllama-1.1b", reduced=True)       # H=4 KH=2 ff=512
    p1 = SH.make_serve_tp_plan(cfg, 1)
    assert p1.size == 1 and not p1.attn and not p1.mlp
    p2 = SH.make_serve_tp_plan(cfg, 2)
    assert p2.attn and p2.mlp
    # KH=2 not divisible by 4 -> attention falls back to replication,
    # the mlp (ff=512, d=256) still shards
    p4 = SH.make_serve_tp_plan(cfg, 4)
    assert not p4.attn and p4.mlp
    # fused-qkv layouts interleave q/k/v lanes -> attention never shards
    g = get_arch("gpt2-paper", reduced=True)
    assert not SH.make_serve_tp_plan(g, 2).attn
    # moe expert stacks stay replicated at serve time
    m = get_arch("olmoe-1b-7b", reduced=True)
    assert not SH.make_serve_tp_plan(m, 2).mlp
    with pytest.raises(ValueError, match="padded.*sliced"):
        SH.make_serve_tp_plan(cfg, 2, matmul="megatron")


def test_serve_param_specs_lane_only():
    """Serve weights shard lanes ONLY -- in particular the row-parallel
    (in the training rules) w_down keeps its K rows whole per shard."""
    cfg = get_arch("tinyllama-1.1b", reduced=True)
    params = jax.eval_shape(lambda: T.init_params(cfg,
                                                  jax.random.PRNGKey(0)))
    plan = SH.make_serve_tp_plan(cfg, 2)
    specs = SH.serve_param_specs(params, plan)
    lay = specs["layers"]
    assert lay["attn"]["wq"] == P(None, None, "model")
    assert lay["attn"]["wo"] == P(None, None, "model")    # lanes, NOT K
    assert lay["mlp"]["w_down"] == P(None, None, "model")
    assert lay["ln1"]["w"] == P()
    assert specs["wte"] == P()                            # replicated head
    # quantized: payload arrays shard their lane (last) axis
    qp, _ = quantize_params(
        jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), params),
        get_policy("paper_llama_mix"))
    qspecs = SH.serve_param_specs(qp, plan)
    qt = qspecs["layers"]["mlp"]["w_down"]
    assert isinstance(qt, Q.QTensor)
    assert all(len(sp) and sp[-1] == "model" for sp in qt.data.values())
    # attention fallback (tp=4, KH=2): attn replicated, mlp sharded
    specs4 = SH.serve_param_specs(params, SH.make_serve_tp_plan(cfg, 4))
    assert specs4["layers"]["attn"]["wq"] == P()
    assert specs4["layers"]["mlp"]["w_up"] == P(None, None, "model")


def test_serve_cache_specs_kv_heads():
    cfg = get_arch("llama3.2-1b", reduced=True).replace(
        kv_cache_quant=True, dtype="float32")
    cache = jax.eval_shape(lambda: T.init_cache(cfg, 4, 64))
    plan = SH.make_serve_tp_plan(cfg, 2)
    specs = SH.serve_cache_specs(cache, plan)
    assert specs["k"] == P(None, None, None, "model", None)
    assert specs["k_scale"] == P(None, None, None, "model")  # co-sharded
    assert specs["pos"] == P()
    # page pools co-shard on the same axis
    pool = jax.eval_shape(lambda: T.cache_page_pool(cfg, 8, 8))
    pspecs = SH.serve_cache_specs(pool, plan)
    assert pspecs["v"] == P(None, None, None, "model", None)
    # attention fallback -> fully replicated cache
    nodiv = SH.serve_cache_specs(cache, SH.make_serve_tp_plan(cfg, 8))
    assert nodiv["k"] == P()


def test_lane_shard_and_localize_qtensor():
    w = jax.random.normal(jax.random.PRNGKey(0), (256, 128)) * 0.2
    t = Q.quantize("q2_k", w)
    s0 = SH.lane_shard_qtensor(t, 0, 2)
    assert s0.shape == (256, 64)
    assert all(v.shape[-1] * 2 == t.data[k].shape[-1]
               for k, v in s0.data.items())
    with pytest.raises(ValueError, match="divisible"):
        SH.lane_shard_qtensor(t, 0, 3)
    # localize rewrites only lane-sharded QTensor aux shapes
    params = {"a": t, "b": jnp.ones((4, 4))}
    plan = SH.ServeTPPlan(size=2, attn=True, mlp=True)
    specs = {"a": Q.QTensor(t.variant, t.shape,
                            {k: P(None, "model") for k in t.data}),
             "b": P()}
    loc = SH.localize_serve_params(params, specs, 2)
    assert loc["a"].shape == (256, 64)
    rep = {"a": Q.QTensor(t.variant, t.shape, {k: P() for k in t.data}),
           "b": P()}
    assert SH.localize_serve_params(params, rep, 2)["a"].shape == (256, 128)


def test_tp_validation_errors():
    ssm = get_arch("mamba2-2.7b", reduced=True)
    sp = T.init_params(ssm, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="KV-ring family"):
        Engine(ssm, sp, ServeConfig(tp=2, **BASE))
    cfg = get_arch("tinyllama-1.1b", reduced=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="device"):
        Engine(cfg, params, ServeConfig(tp=NDEV + 1, **BASE))
    with pytest.raises(ValueError, match="tp"):
        Engine(cfg, params, ServeConfig(tp=0, **BASE))


def test_padded_gemm_column_independence():
    """THE property the padded TP datapath rests on: zeroing the
    off-shard columns of a weight (same gemm shape) never perturbs the
    on-shard columns' bits -- gemm rounding is per-output-column, so a
    shard computing dot(x, zero_embed(w_lanes)) reproduces the
    single-device dot's columns exactly, at every tp degree. Asserted
    bitwise over the engine's own projection shapes, including the
    (24, 256, 256) case where the lane-SLICED dot demonstrably rounds
    differently on CPU XLA (which is why sliced mode only promises
    ulp-level agreement; see test_sliced_datapath_logit_tolerance)."""
    for seed, (M, K, N) in enumerate([(24, 256, 256), (24, 256, 512),
                                      (24, 512, 256), (3, 256, 512)]):
        kx, kw = jax.random.split(jax.random.PRNGKey(seed))
        x = jax.random.normal(kx, (M, K), jnp.float32)
        w = jax.random.normal(kw, (K, N), jnp.float32)
        full = np.asarray(jax.jit(jnp.dot)(x, w))
        for S in (2, 4):
            n = N // S
            for i in range(S):
                wz = np.zeros((K, N), np.float32)
                wz[:, i * n:(i + 1) * n] = np.asarray(w[:, i * n:(i + 1) * n])
                emb = np.asarray(jax.jit(jnp.dot)(x, jnp.asarray(wz)))
                np.testing.assert_array_equal(
                    emb[:, i * n:(i + 1) * n], full[:, i * n:(i + 1) * n])
                np.testing.assert_array_equal(
                    emb[:, :i * n], 0.0)
                np.testing.assert_array_equal(emb[:, (i + 1) * n:], 0.0)


# ---------------------------------------------------------------------------
# multi-device parity (forced host devices)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def causal():
    # n_kv_heads=4 so tp=4 shards attention too (stock reduced KH=2
    # exercises the fallback instead, covered by test_greedy_parity_fallback)
    cfg = get_arch("tinyllama-1.1b", reduced=True).replace(n_kv_heads=4)
    return cfg, T.init_params(cfg, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def windowed():
    cfg = get_arch("h2o-danube-1.8b", reduced=True)      # window = 64
    return cfg, T.init_params(cfg, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def int8kv():
    cfg = get_arch("llama3.2-1b", reduced=True).replace(
        kv_cache_quant=True, dtype="float32")
    return cfg, T.init_params(cfg, jax.random.PRNGKey(0))


def _parity(model, meshes, prompts=None, runs=1, **kw):
    """Generate with identical queues at every tp degree; all outputs
    (and a second warm run, for prefix workloads) must be token-identical
    to the tp=1 engine's."""
    cfg, params = model
    prompts = prompts or _prompts(cfg, 5, seed=1)
    outs, engines = {}, {}
    for tp in meshes:
        eng = Engine(cfg, params, ServeConfig(tp=tp, **BASE, **kw))
        outs[tp] = [eng.generate(prompts) for _ in range(runs)]
        engines[tp] = eng
    for tp in meshes[1:]:
        assert outs[tp] == outs[meshes[0]], f"tp={tp} diverged"
    return engines


@needs4
@pytest.mark.parametrize("spec,prefix", [(False, False), (False, True),
                                         (True, False), (True, True)])
def test_greedy_parity_causal_meshes_1_2_4(causal, spec, prefix):
    """fp32 greedy, mesh {1,2,4}: bitwise token parity across the full
    spec x prefix matrix -- cold AND warm (radix re-hit) cycles."""
    kw = {}
    if spec:
        kw.update(drafter="ngram", draft_k=3)
    if prefix:
        kw.update(prefix_cache=True, prefix_page=8)
    prompts = _prompts(causal[0], 5, seed=2, shared=24 if prefix else 0,
                       lo=2, hi=8 if prefix else 30)
    engines = _parity(causal, (1, 2, 4), prompts=prompts, runs=2, **kw)
    assert engines[2]._plan.attn and engines[4]._plan.attn
    if prefix:     # warm cycle really hit, identically at every degree
        hits = {tp: e.stats["prefix_hits"] for tp, e in engines.items()}
        assert hits[1] > 0 and hits[1] == hits[2] == hits[4]
    if spec:       # bitwise-equal accept decisions, not just tokens
        acc = {tp: (e.stats["draft_tokens"], e.stats["draft_accepted"])
               for tp, e in engines.items()}
        assert acc[1] == acc[2] == acc[4]


@needs4
def test_greedy_parity_fallback_config(causal):
    """Stock reduced tinyllama (KH=2): tp=4 falls back to replicated
    attention + sharded mlp and must STILL be token-identical."""
    cfg = get_arch("tinyllama-1.1b", reduced=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    engines = _parity((cfg, params), (1, 4))
    assert not engines[4]._plan.attn and engines[4]._plan.mlp


@needs4
def test_greedy_parity_sliding_window(windowed):
    """Ring wrap under TP: prompts longer than the window, budgets that
    decode across the wrap point."""
    cfg, _ = windowed
    prompts = _prompts(cfg, 4, seed=3, lo=40, hi=90)     # > window = 64
    _parity(windowed, (1, 2), prompts=prompts)


@needs4
def test_greedy_parity_int8_kv(int8kv):
    """int8 KV quantization per (token, head): head-sliced quantize is
    elementwise across kv_heads, so the sharded cache holds bit-equal
    payloads AND scales."""
    engines = _parity(int8kv, (1, 2))
    assert engines[2]._cspecs["k_scale"] == P(None, None, None, "model")


@needs4
def test_greedy_parity_self_drafter(causal):
    """Truncated-layer self-drafting reuses the sharded packed weights
    inside the TP decode loop (draft cache carved from the sharded
    ring)."""
    _parity(causal, (1, 2), drafter="self", draft_k=2, draft_layers=1)


@needs4
def test_temperature_parity_meshes(causal):
    """Sampling: logits are replicated bit-identically, PRNG keys split
    identically on every shard, so temperature sampling is ALSO
    token-identical across tp degrees (padded datapath)."""
    _parity(causal, (1, 2, 4), temperature=0.8, seed=7)


@needs4
def test_quantized_padded_token_parity(causal):
    """Packed q2/q3 weights, padded datapath: dequantization is
    lane-elementwise and the gemm keeps the single-device shape, so even
    the QUANTIZED pipeline is token-identical across meshes."""
    cfg, params = causal
    qp, _ = quantize_params(params, get_policy("paper_llama_mix"))
    _parity((cfg, qp), (1, 2), prompts=_prompts(cfg, 4, seed=5, hi=14))


@needs2
def test_greedy_parity_gpt2_gelu(int8kv):
    """gpt2 family under TP: fused-qkv attention replicates (lane slices
    would interleave q/k/v), the gelu mlp shards with its LANE-SHARDED
    b_fc added to the still-local hidden and replicated b_proj added
    after the output gather -- the one bias-placement path no other
    config exercises. LayerNorm + learned positions ride along."""
    cfg = get_arch("gpt2-paper", reduced=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    engines = _parity((cfg, params), (1, 2),
                      prompts=_prompts(cfg, 4, seed=13, hi=14))
    assert not engines[2]._plan.attn and engines[2]._plan.mlp


@needs2
def test_sliced_datapath_logit_tolerance(causal):
    """The throughput ("sliced") datapath: true lane-sliced gemms. CPU
    gemms round shape-dependently, so logits match the tp=1 program only
    to ~an f32 ulp of the accumulation (documented tolerance; greedy
    tokens may flip on near-ties, same caveat as test_spec_decode's
    batched verify -- so this test pins LOGITS, not tokens)."""
    cfg, params = causal
    lens = [14, 9, 11]
    rng = np.random.default_rng(11)
    toks = np.zeros((3, 16), np.int32)
    for i, n in enumerate(lens):
        toks[i, :n] = rng.integers(0, cfg.vocab_size, n)
    lengths = jnp.asarray(lens, jnp.int32)
    cached = jnp.zeros(3, jnp.int32)
    logits = {}
    for tp, mm in ((1, "padded"), (2, "sliced")):
        eng = Engine(cfg, params, ServeConfig(tp=tp, tp_matmul=mm, **BASE))
        gcache = eng._new_cache(3)
        last = jnp.zeros((3, cfg.vocab_size), jnp.float32)
        for j in range(2):
            gcache, last = eng._prefill_chunk(
                eng.params, gcache, jnp.asarray(toks[:, j * 8:(j + 1) * 8]),
                jnp.asarray(j * 8, jnp.int32), lengths, last, cached)
        logits[tp] = np.asarray(jax.device_get(last))
    np.testing.assert_allclose(
        logits[2], logits[1], rtol=1e-4,
        atol=1e-4 * np.abs(logits[1]).max())


def _chunked_logits(cfg, params, tp, mm):
    """Logits after two prefill chunks of a 3-request ragged group under
    one (tp, tp_matmul) engine -- the shared probe for the sliced-family
    tolerance tests."""
    lens = [14, 9, 11]
    rng = np.random.default_rng(11)
    toks = np.zeros((3, 16), np.int32)
    for i, n in enumerate(lens):
        toks[i, :n] = rng.integers(0, cfg.vocab_size, n)
    lengths = jnp.asarray(lens, jnp.int32)
    cached = jnp.zeros(3, jnp.int32)
    eng = Engine(cfg, params, ServeConfig(tp=tp, tp_matmul=mm, **BASE))
    gcache = eng._new_cache(3)
    last = jnp.zeros((3, cfg.vocab_size), jnp.float32)
    for j in range(2):
        gcache, last = eng._prefill_chunk(
            eng.params, gcache, jnp.asarray(toks[:, j * 8:(j + 1) * 8]),
            jnp.asarray(j * 8, jnp.int32), lengths, last, cached)
    return np.asarray(jax.device_get(last)), eng


@needs2
def test_sliced_row_logit_tolerance_bf16(causal):
    """The "sliced_row" datapath (row-parallel o-/down-proj, fp32
    partials psummed then rounded once): splitting the K reduction
    across shards cannot bit-match the full-K dot once activations
    round to bf16 at layer boundaries, so with the default bf16
    activations the contract is ~a few BF16 ulps of the logits
    (measured ~5e-3 rel on CPU XLA; eps_bf16 = 7.8e-3), not the f32
    envelope the lane-only "sliced" datapath keeps."""
    cfg, params = causal
    ref, _ = _chunked_logits(cfg, params, 1, "padded")
    got, eng = _chunked_logits(cfg, params, 2, "sliced_row")
    # unquantized fixture: plain wo/w_down K-rows divide -> "packed"
    assert eng._plan.attn_row == "packed" and eng._plan.mlp_row == "packed"
    np.testing.assert_allclose(got, ref, rtol=2e-2,
                               atol=2e-2 * np.abs(ref).max())
    assert np.abs(got - ref).max() <= 1.5e-2 * np.abs(ref).max()


@needs2
def test_sliced_row_logit_tolerance_f32(causal):
    """With fp32 activations the ONLY divergence left in "sliced_row" is
    the K-reduction split itself, so the logits sit inside the same
    f32-ulp envelope as the lane-only "sliced" datapath (measured
    ~2e-5 rel)."""
    cfg, _ = causal
    cfg = cfg.replace(dtype="float32")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    ref, _ = _chunked_logits(cfg, params, 1, "padded")
    got, _ = _chunked_logits(cfg, params, 2, "sliced_row")
    np.testing.assert_allclose(got, ref, rtol=1e-4,
                               atol=1e-4 * np.abs(ref).max())


@needs2
def test_sliced_row_quantized_row_modes(causal):
    """Quantized params pick per-leaf row modes: the causal fixture's
    wo (K=256, one q3_k super-block) cannot K-shard and falls back to
    "dequant" (replicated payload, per-shard row slice), while w_down
    (K=512) shards whole super-blocks ("packed"). Logits stay inside
    the activation-ulp envelope either way."""
    cfg, params = causal
    qp, _ = quantize_params(params, get_policy("paper_llama_mix"))
    ref, _ = _chunked_logits(cfg, qp, 1, "padded")
    got, eng = _chunked_logits(cfg, qp, 2, "sliced_row")
    assert eng._plan.attn_row == "dequant"
    assert eng._plan.mlp_row == "packed"
    np.testing.assert_allclose(got, ref, rtol=2e-2,
                               atol=2e-2 * np.abs(ref).max())


@needs2
def test_ring_collective_matmul_parity():
    """layers.tp_ring_dense -- the collective-matmul fallback that
    "sliced_row" full-output projections use when no row-parallel mode
    applies: lane-sharded input chunks accumulate against the local
    lane slice of the weight in an fp32 carry while ppermute forwards
    them around the ring. Must match the plain full matmul within the
    activation-ulp contract, for a packed QTensor and a plain weight."""
    from jax.sharding import Mesh
    from repro.models import layers as L
    from repro.serving.engine import _shard_map
    size = 2
    K, N, M = 512, 256, 8
    kx, kw = jax.random.split(jax.random.PRNGKey(3))
    x = jax.random.normal(kx, (M, K), jnp.float32).astype(jnp.bfloat16)
    w = jax.random.normal(kw, (K, N), jnp.float32) * 0.2
    t = Q.quantize("q3_k", w)
    plan = SH.ServeTPPlan(size=size, attn=True, mlp=True,
                          matmul="sliced_row")
    mesh = Mesh(np.asarray(jax.devices()[:size]), ("model",))
    for weight, wspec in (
            (t, Q.QTensor(t.variant, t.shape,
                          {k: P(None, "model") for k in t.data})),
            (w.astype(jnp.bfloat16), P(None, "model"))):
        def body(xl, wl):
            wl = SH.localize_serve_params(
                wl, jax.tree.map(lambda _: wspec, wl,
                                 is_leaf=lambda q: isinstance(q, Q.QTensor)),
                size) if isinstance(wl, Q.QTensor) else wl
            with SH.serve_tp(plan):
                return L.tp_ring_dense(xl, wl)
        f = _shard_map(body, mesh=mesh, in_specs=(P(None, "model"), wspec),
                       out_specs=P(), check_rep=False)
        got = np.asarray(jax.jit(f)(x, weight), np.float32)
        wf = Q.dequantize(t, dtype=jnp.bfloat16) if isinstance(
            weight, Q.QTensor) else w.astype(jnp.bfloat16)
        ref = np.asarray(jnp.dot(x, wf).astype(x.dtype), np.float32)
        np.testing.assert_allclose(got, ref, rtol=2e-2,
                                   atol=2e-2 * np.abs(ref).max())


@needs2
def test_cancel_midstream_under_tp(causal):
    """In-flight cancel from an on_token callback behaves identically at
    tp=2 (host scheduler state is mesh-oblivious)."""
    cfg, params = causal
    prompts = _prompts(cfg, 3, seed=9, hi=12)

    def run(tp):
        eng = Engine(cfg, params, ServeConfig(tp=tp, **BASE))
        ids, seen = [], {}

        def cb(rid, tok):
            seen[rid] = seen.get(rid, 0) + 1
            if rid == ids[0] and seen[rid] == 2:
                eng.cancel(ids[1])
        for p in prompts:
            ids.append(eng.submit(p, on_token=cb))
        res = eng.run()
        return [res[i] for i in ids]

    assert run(1) == run(2)


# ---------------------------------------------------------------------------
# acceptance core under the plain tier-1 run: subprocess forces 4 host
# devices in a fresh interpreter (XLA_FLAGS must precede jax init)
# ---------------------------------------------------------------------------

TP_SNIPPET = r"""
import sys
sys.path.insert(0, "src")
from repro.launch.hostdev import force_host_devices
force_host_devices(4)
import jax
import numpy as np
from repro.configs.base import get_arch
from repro.models import transformer as T
from repro.serving.engine import Engine, ServeConfig

cfg = get_arch("tinyllama-1.1b", reduced=True).replace(n_kv_heads=4)
params = T.init_params(cfg, jax.random.PRNGKey(0))
rng = np.random.default_rng(1)
shared = list(rng.integers(0, cfg.vocab_size, 24))
prompts = [shared + list(rng.integers(0, cfg.vocab_size,
                                      int(rng.integers(2, 8))))
           for _ in range(5)]
outs = {}
for tp in (1, 2, 4):
    eng = Engine(cfg, params, ServeConfig(
        max_new_tokens=6, cache_len=64, decode_chunk=8, max_slots=3,
        prefill_bucket=4, prefill_chunk=8, prefill_batch=3,
        tp=tp, drafter="ngram", draft_k=3,
        prefix_cache=True, prefix_page=8))
    cold = eng.generate(prompts)
    warm = eng.generate(prompts)
    assert eng.stats["prefix_hits"] == len(prompts), eng.stats
    outs[tp] = (cold, warm)
assert outs[1] == outs[2] == outs[4], outs
print("SUBPROCESS_TP_PARITY_OK")
"""


@pytest.mark.slow
def test_subprocess_forced4_spec_prefix_parity():
    """fp32 greedy + ngram speculation + warm prefix cache: token
    parity across meshes {1, 2, 4} -- the acceptance core, provable even
    when this pytest process only sees one device."""
    out = subprocess.run([sys.executable, "-c", TP_SNIPPET], cwd=REPO,
                         capture_output=True, text=True, timeout=1200)
    assert "SUBPROCESS_TP_PARITY_OK" in out.stdout, out.stdout + out.stderr
