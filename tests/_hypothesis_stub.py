"""Minimal deterministic stand-in for ``hypothesis``.

Loaded by conftest.py ONLY when the real package is missing (offline /
hermetic environments); CI installs the real one via
``pip install -e .[test]``.  Implements just the surface the test suite
uses -- ``given``/``settings`` (any kwargs accepted and ignored beyond
``max_examples``, in either decorator order), the ``floats``/
``integers``/``booleans``/``sampled_from``/``just``/``lists``/``tuples``
strategies and ``assume`` -- with examples drawn from an RNG seeded by
the test name, so runs are reproducible (no shrinking, no database).
Suites written against real hypothesis must collect and run unchanged.
"""
import types
import zlib

import numpy as np


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def sample(self, rng):
        return self._draw(rng)


def floats(min_value, max_value, **_):
    return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))


def integers(min_value, max_value, **_):
    return _Strategy(lambda rng: int(rng.integers(min_value,
                                                  max_value + 1)))


def booleans(**_):
    return _Strategy(lambda rng: bool(rng.integers(0, 2)))


def sampled_from(options):
    opts = list(options)
    return _Strategy(lambda rng: opts[int(rng.integers(len(opts)))])


def just(value):
    return _Strategy(lambda rng: value)


def lists(elements, min_size=0, max_size=10, **_):
    def draw(rng):
        n = int(rng.integers(min_size, max_size + 1))
        return [elements.sample(rng) for _ in range(n)]
    return _Strategy(draw)


def tuples(*strats):
    return _Strategy(lambda rng: tuple(s.sample(rng) for s in strats))


strategies = types.ModuleType("hypothesis.strategies")
for _name in ("floats", "integers", "booleans", "sampled_from", "just",
              "lists", "tuples"):
    setattr(strategies, _name, globals()[_name])


class _Unsatisfied(Exception):
    """Raised by assume(False); the example is skipped and redrawn."""


def assume(condition):
    if not condition:
        raise _Unsatisfied()
    return True


class HealthCheck:
    """Attribute sink: ``suppress_health_check=[HealthCheck.x]`` for any
    x must parse under the stub."""
    def __getattr__(self, name):                 # pragma: no cover
        return name


HealthCheck = HealthCheck()


def settings(max_examples=10, deadline=None, **_):
    """Accept and ignore every real-hypothesis kwarg (deadline,
    suppress_health_check, derandomize, ...); only max_examples matters.
    Works above or below @given: the attribute is copied through."""
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


def example(*_a, **_k):
    """@example pins explicit cases in real hypothesis; the stub ignores
    them (the seeded RNG sweep stands in)."""
    def deco(fn):
        return fn
    return deco


def given(**strats):
    def deco(fn):
        # deliberately NOT functools.wraps: pytest must see only the
        # NON-strategy parameters (fixtures, e.g. a shared model), not the
        # wrapped signature (it would demand fixtures for strategy args).
        # exec builds a runner whose signature is exactly the fixture
        # params, so pytest injects them and we forward them through.
        import inspect

        fixtures = [p for p in inspect.signature(fn).parameters
                    if p not in strats]
        args = ", ".join(fixtures)
        ns = {}
        exec(f"def runner({args}):\n"
             f"    __drive({{{', '.join(f'{a!r}: {a}' for a in fixtures)}}})",
             {"__drive": lambda fkw: _drive(runner, fn, strats, fkw)}, ns)
        runner = ns["runner"]
        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        runner.__module__ = fn.__module__
        # @settings below @given (applied to fn first) must still count
        if hasattr(fn, "_stub_max_examples"):
            runner._stub_max_examples = fn._stub_max_examples
        return runner
    return deco


def _drive(runner, fn, strats, fixture_kwargs):
    n = getattr(runner, "_stub_max_examples", 10)
    rng = np.random.default_rng(zlib.adler32(fn.__name__.encode()))
    done = tries = 0
    while done < n and tries < 50 * n:           # assume() may discard
        tries += 1
        try:
            fn(**fixture_kwargs,
               **{k: s.sample(rng) for k, s in strats.items()})
        except _Unsatisfied:
            continue
        done += 1
    if done == 0:
        # mirror real hypothesis's Unsatisfiable: a test whose assume()
        # rejects every draw must not silently pass with zero examples
        raise AssertionError(
            f"{fn.__name__}: assume() discarded all {tries} examples")
