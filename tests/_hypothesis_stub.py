"""Minimal deterministic stand-in for ``hypothesis``.

Loaded by conftest.py ONLY when the real package is missing (offline /
hermetic environments); CI installs the real one via
``pip install -e .[test]``.  Implements just the surface the test suite
uses -- ``given``/``settings`` and the ``floats``/``integers``/
``sampled_from`` strategies -- with examples drawn from an RNG seeded by
the test name, so runs are reproducible (no shrinking, no database).
"""
import types
import zlib

import numpy as np


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def sample(self, rng):
        return self._draw(rng)


def floats(min_value, max_value, **_):
    return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))


def integers(min_value, max_value, **_):
    return _Strategy(lambda rng: int(rng.integers(min_value,
                                                  max_value + 1)))


def sampled_from(options):
    opts = list(options)
    return _Strategy(lambda rng: opts[int(rng.integers(len(opts)))])


strategies = types.ModuleType("hypothesis.strategies")
strategies.floats = floats
strategies.integers = integers
strategies.sampled_from = sampled_from


def settings(max_examples=10, deadline=None, **_):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


def given(**strats):
    def deco(fn):
        # deliberately NOT functools.wraps: pytest must see only the
        # NON-strategy parameters (fixtures, e.g. a shared model), not the
        # wrapped signature (it would demand fixtures for strategy args).
        # exec builds a runner whose signature is exactly the fixture
        # params, so pytest injects them and we forward them through.
        import inspect

        fixtures = [p for p in inspect.signature(fn).parameters
                    if p not in strats]
        args = ", ".join(fixtures)
        ns = {}
        exec(f"def runner({args}):\n"
             f"    __drive({{{', '.join(f'{a!r}: {a}' for a in fixtures)}}})",
             {"__drive": lambda fkw: _drive(runner, fn, strats, fkw)}, ns)
        runner = ns["runner"]
        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        runner.__module__ = fn.__module__
        return runner
    return deco


def _drive(runner, fn, strats, fixture_kwargs):
    n = getattr(runner, "_stub_max_examples", 10)
    rng = np.random.default_rng(zlib.adler32(fn.__name__.encode()))
    for _ in range(n):
        fn(**fixture_kwargs,
           **{k: s.sample(rng) for k, s in strats.items()})
