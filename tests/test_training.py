"""Training substrate tests: optimizer, microbatching, convergence, loss."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.optim import adamw
from repro.training import steps as S
from repro.training.loop import run_training


def test_adamw_reduces_quadratic():
    opt = adamw.AdamWConfig(lr=0.1, warmup_steps=0, total_steps=100,
                            weight_decay=0.0)
    params = {"w": jnp.ones((4, 4)) * 3.0}
    state = adamw.init_state(opt, params)
    for _ in range(50):
        grads = {"w": 2 * params["w"]}
        params, state, m = adamw.apply_updates(opt, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 1.0


def test_cosine_schedule():
    opt = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            min_lr_ratio=0.1)
    assert float(adamw.cosine_lr(opt, jnp.asarray(0))) == 0.0
    assert abs(float(adamw.cosine_lr(opt, jnp.asarray(10))) - 1.0) < 1e-6
    end = float(adamw.cosine_lr(opt, jnp.asarray(100)))
    assert abs(end - 0.1) < 1e-6


def test_grad_clipping():
    opt = adamw.AdamWConfig(lr=0.0, grad_clip=1.0)
    params = {"w": jnp.zeros((2, 2))}
    state = adamw.init_state(opt, params)
    _, _, m = adamw.apply_updates(opt, params, {"w": jnp.ones((2, 2)) * 100},
                                  state)
    assert float(m["grad_norm"]) > 100


def test_microbatch_equivalence():
    """grad accumulation over microbatches == one big batch (same loss
    trajectory within fp tolerance)."""
    cfg = get_arch("llama3.2-1b", reduced=True)
    opt = adamw.AdamWConfig(warmup_steps=0, total_steps=10, lr=1e-3)
    state1 = S.init_train_state(cfg, opt, jax.random.PRNGKey(0))
    state2 = jax.tree.map(lambda x: x, state1)
    step1 = jax.jit(S.make_train_step(cfg, opt, microbatches=1))
    step2 = jax.jit(S.make_train_step(cfg, opt, microbatches=2))
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                     cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 32), 0,
                                     cfg.vocab_size),
    }
    s1, m1 = step1(state1, batch)
    s2, m2 = step2(state2, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3
    # updated params near-identical (Adam's rescaling amplifies fp noise
    # for near-zero grads, so the bound is loose relative to lr=1e-3)
    diffs = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                         s1["params"], s2["params"])
    assert max(jax.tree.leaves(diffs)) < 2e-3


def test_chunked_xent_equals_dense():
    cfg = get_arch("llama3.2-1b", reduced=True)
    key = jax.random.PRNGKey(0)
    B, Ssz, d, V = 2, 24, cfg.d_model, 1000
    h = jax.random.normal(key, (B, Ssz, d))
    head = jax.random.normal(jax.random.PRNGKey(1), (d, V)) * 0.02
    labels = jax.random.randint(jax.random.PRNGKey(2), (B, Ssz), 0, V)
    dense_loss = S.softmax_xent(
        jnp.dot(h, head).astype(jnp.float32), labels)
    chunk_loss = S.chunked_xent(h, head, labels, chunk=16)
    assert abs(float(dense_loss) - float(chunk_loss)) < 1e-3
    # gradients agree too
    g1 = jax.grad(lambda hh: S.softmax_xent(
        jnp.dot(hh, head).astype(jnp.float32), labels))(h)
    g2 = jax.grad(lambda hh: S.chunked_xent(hh, head, labels, chunk=16))(h)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-5)


def test_training_loss_decreases():
    cfg = get_arch("llama3.2-1b", reduced=True)
    opt = adamw.AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=60)
    res = run_training(cfg, steps=60, global_batch=8, seq_len=64, opt=opt,
                       log_fn=lambda *_: None)
    first = np.mean(res["losses"][:5])
    last = np.mean(res["losses"][-5:])
    assert last < first - 0.2, (first, last)
