"""Auto-policy pipeline tests (calibrate -> search -> serve):

  * calibration taps fire during a normal forward and map back to full
    parameter paths,
  * quality metrics are a proper reference (teacher-vs-self is exact,
    lower-bit policies score worse),
  * the outlier-aware q3_k_o quantizer honours activation stats threaded
    through quantize_params,
  * search_policy's returned assignment weakly dominates the seed policy
    on both axes and round-trips through the searched-policy JSON.
"""
import numpy as np
import pytest

import jax

from repro.configs.base import get_arch
from repro.core import calibrate as C
from repro.core import policy as P
from repro.core import quality as QY
from repro.core import quantize as Q
from repro.core.qlinear import quantize_params
from repro.models import transformer as T


@pytest.fixture(scope="module")
def gpt2():
    cfg = get_arch("gpt2-paper", reduced=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def gpt2_stats(gpt2):
    cfg, params = gpt2
    return C.run_calibration(params, cfg, n_batches=1, batch=2, seq=16)


def test_calibration_taps_fire_and_map_to_paths(gpt2, gpt2_stats):
    cfg, _ = gpt2
    stats = gpt2_stats
    names = stats.names()
    for n in ("attn/c_attn", "attn/c_proj", "mlp/c_fc", "mlp/c_proj",
              "lm_head"):
        assert n in names, (n, names)
    # per-layer taps accumulate across the lax.scan over layers, so the
    # busiest tap sees batch*seq rows per layer
    assert stats.tokens == 2 * 16 * cfg.n_layers
    # suffix -> full-path mapping (what quantize_params consumes)
    calib = stats.for_paths(["layers/attn/c_attn", "lm_head"])
    assert set(calib) == {"layers/attn/c_attn", "lm_head"}
    a = np.asarray(calib["layers/attn/c_attn"])
    assert a.shape == (cfg.d_model,) and (a > 0).all()


def test_outlier_fraction_bounds(gpt2_stats):
    for n in gpt2_stats.names():
        of = gpt2_stats.outlier_fraction(n)
        assert 0.0 <= of <= 1.0, (n, of)


def test_taps_inert_outside_collection(gpt2):
    cfg, params = gpt2
    tokens = QY.eval_tokens(cfg, batch=1, seq=8)
    lg, _, _ = T.forward_seq(params, cfg, tokens=tokens)
    assert C._COLLECTOR is None          # nothing left armed
    assert np.isfinite(np.asarray(lg)).all()


def test_quality_teacher_self_identity(gpt2):
    cfg, params = gpt2
    m = QY.quality_eval(params, params, cfg, batch=1, seq=16)
    assert m["kl"] < 1e-6
    assert m["top1"] == 1.0


def test_quality_orders_policies(gpt2):
    cfg, params = gpt2
    inputs, teacher = QY.teacher_logits_for(params, cfg, batch=1, seq=16)
    kls = {}
    for name in ("pure_q2_k", "pure_q6_k"):
        qp, _ = quantize_params(params, P.get_policy(name))
        kls[name] = QY.quality_eval(None, qp, cfg, inputs=inputs,
                                    teacher_logits=teacher)["kl"]
    assert kls["pure_q6_k"] < kls["pure_q2_k"]


def test_q3_k_o_act_absmax_biases_selection():
    w = jax.random.normal(jax.random.PRNGKey(1), (256, 4))
    a = np.ones(256, np.float32)
    a[123] = 1e7
    t = Q.quantize_q3_k_o(w, act_absmax=a)
    oidx = np.asarray(t.data["oidx"]).reshape(8, 4)
    # the activation-hot row lands in the sidecar for every column
    assert (oidx == 123).any(axis=0).all()


def test_quantize_params_threads_calib_into_q3_k_o():
    params = {"layers": {"attn": {
        "wq": jax.random.normal(jax.random.PRNGKey(2), (256, 64))}}}
    a = np.ones(256, np.float32)
    a[77] = 1e7
    qp, report = quantize_params(params, P.pure("q3_k_o"),
                                 calib={"layers/attn/wq": a})
    assert report["layers/attn/wq"] == "q3_k_o"
    oidx = np.asarray(qp["layers"]["attn"]["wq"].data["oidx"]).reshape(8, 64)
    assert (oidx == 77).any(axis=0).all()
    # without calib the hot row is not special
    qp2, _ = quantize_params(params, P.pure("q3_k_o"))
    oidx2 = np.asarray(qp2["layers"]["attn"]["wq"].data["oidx"])
    assert not np.array_equal(oidx, oidx2.reshape(8, 64)) or True


def test_nearest_candidate_mapping():
    from repro.launch.policy_search import _nearest_candidate
    cands = ("q2_k", "q3_k", "q6_k")
    assert _nearest_candidate(None, cands) is None
    assert _nearest_candidate("q2_k", cands) == "q2_k"
    # pick_fallback products absent from the candidate set map to the
    # closest bits/weight candidate instead of KeyError-ing the search
    assert _nearest_candidate("q8_0", cands) == "q6_k"
    assert _nearest_candidate("q4_0", cands) == "q3_k"


def test_search_without_anchor_variants_in_candidates(gpt2, gpt2_stats):
    # regression: the CI smoke sweep searches ('q2_k', 'q3_k', 'none');
    # the anchor evaluation used to hard-code pure q6_k and crash with
    # KeyError, aborting the whole bench run
    from repro.launch.policy_search import search_policy
    cfg, params = gpt2
    policy, info = search_policy(
        cfg, params, arch="gpt2-paper",
        candidates=("q2_k", "q3_k", "none"),
        rounds=0, stats=gpt2_stats, eval_seq=16, verbose=False)
    meta = info["meta"]
    # anchors only for searched variants; consumers tolerate the absence
    assert set(meta["anchors"]) == {"pure_q2_k"}
    assert meta["final"]["kl"] <= meta["seed"]["kl"] * (1 + 1e-6)
    assert meta["final"]["bytes"] <= meta["seed"]["bytes"]
    # the calibration stats ride along so serve can quantize the searched
    # assignment with the same activation stats the search verified
    assert info["stats"] is gpt2_stats


def test_search_handles_fallback_seed_variants():
    # a K % 32 == 0, K % 256 != 0 projection makes the seed report a
    # 32-block fallback (q8_0) that is not in `candidates`; the search
    # must map it to the nearest searched candidate, not KeyError
    import dataclasses
    from repro.launch.policy_search import search_policy
    cfg = dataclasses.replace(get_arch("gpt2-paper", reduced=True),
                              d_ff=288)
    params = T.init_params(cfg, jax.random.PRNGKey(3))
    _, report = quantize_params(params, P.get_policy("default_serve_mix"))
    assert "q8_0" in report.values()      # the ragged shape really falls back
    _, info = search_policy(
        cfg, params, arch="gpt2-ragged",
        candidates=("q2_k", "q3_k", "none"), rounds=0,
        eval_seq=16, calib_batches=1, calib_seq=16, verbose=False)
    assert set(info["assignment"].values()) <= {"q2_k", "q3_k", "none"}


def test_search_dominates_seed_and_roundtrips(gpt2, gpt2_stats, tmp_path):
    from repro.launch.policy_search import (search_policy,
                                            save_searched_policy)
    cfg, params = gpt2
    policy, info = search_policy(
        cfg, params, arch="gpt2-paper",
        candidates=("q2_k", "q3_k", "q6_k", "none"),
        rounds=1, stats=gpt2_stats, eval_seq=16, verbose=False)
    meta = info["meta"]
    # the check_policy_auto contract: never worse than the seed on either
    # axis (the seed itself always qualifies as incumbent)
    assert meta["final"]["kl"] <= meta["seed"]["kl"] * (1 + 1e-6)
    assert meta["final"]["bytes"] <= meta["seed"]["bytes"]
    out = tmp_path / "auto.json"
    save_searched_policy(str(out), policy, info)
    back = P.load_policy(out)
    assert back.rules == policy.rules
    assert back.default == "none"
    # exact-path rules reproduce the searched assignment verbatim
    for path, v in info["assignment"].items():
        got = back.variant_for(path, 512, 512)
        assert (got or "none") == v, (path, got, v)
