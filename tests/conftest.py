import os
import sys

# tests run on the single real CPU device (the dry-run subprocess test sets
# its own device count); keep CPU math deterministic enough for allclose
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# hypothesis is a declared test dependency (pyproject [test] extra), but
# hermetic/offline environments may not have it -- fall back to the
# deterministic stub so the property tests still collect and run
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import importlib.util

    _p = os.path.join(os.path.dirname(__file__), "_hypothesis_stub.py")
    _spec = importlib.util.spec_from_file_location("hypothesis", _p)
    _mod = importlib.util.module_from_spec(_spec)
    sys.modules["hypothesis"] = _mod
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis.strategies"] = _mod.strategies

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
