"""Family-adapter capability table and the one validation pass.

The engine <-> model feature contract used to live in four scattered
``raise ValueError(... needs a KV-ring family ...)`` sites plus ad-hoc
``cfg.family`` string checks. ``models/state.py`` makes it ONE table
(``CAPS``) consulted by ONE pass (``validate_serve_features``) at
``ServeConfig`` validation time. This suite pins:

* every family x feature cell of the matrix -- supported combos
  construct, unsupported ones raise the single consistent error shape
  ``"<feature> needs a KV-ring family (got <family>); <why>"``;
* the ``DecodeState`` adapter's capability asserts (ring snapshot on an
  SSM cache must fail loudly, not corrupt state);
* the recurrent batched-prefill compile-count regression: ONE jitted
  ``(B, C)`` chunk program serves every prompt length (the old
  exact-length ``_prefill_impl`` compiled once per length).
"""
import jax
import pytest

from repro.configs.base import get_arch
from repro.models import transformer as T
from repro.models.state import (CAPS, KV_FAMILIES, DecodeState,
                                family_caps, validate_serve_features)
from repro.serving.engine import Engine, ServeConfig

# one representative arch per family, so matrix cells run on real configs
ARCH_FOR = {
    "dense": "llama3.2-1b",
    "gpt2": "gpt2-paper",
    "vlm": "qwen2-vl-72b",
    "audio": "musicgen-large",
    "moe": "granite-moe-3b-a800m",
    "ssm": "mamba2-2.7b",
    "hybrid": "zamba2-1.2b",
}

FEATURE_KW = {
    "tensor-parallel serving": dict(tp=2),
    "speculative decoding": dict(drafter=True),
    "prefix caching": dict(prefix_cache=True),
}


# ---------------------------------------------------------------------------
# the capability table itself
# ---------------------------------------------------------------------------

def test_caps_table_covers_every_registered_family():
    registered = {get_arch(a, reduced=True).family for a in ARCH_FOR.values()}
    assert registered == set(CAPS)
    assert set(ARCH_FOR) == set(CAPS)


def test_kv_families_derived_from_table():
    assert set(KV_FAMILIES) == {f for f, c in CAPS.items() if c.kv_ring}
    assert "ssm" not in KV_FAMILIES and "hybrid" not in KV_FAMILIES


def test_caps_rows_are_internally_consistent():
    for f, c in CAPS.items():
        assert c.family == f
        assert c.kv_ring != c.recurrent          # exactly one cache kind
        if c.speculative:
            assert c.kv_ring                     # rewind needs a ring
        if c.prefix_cache:
            assert c.prefix_mode in ("pages", "checkpoints")
            assert (c.prefix_mode == "pages") == c.kv_ring
        else:
            assert c.prefix_mode == "none"
        if c.expert_parallel:
            assert f == "moe"
    # ssm is the only unbounded-context family (no attention ring at all)
    assert not CAPS["ssm"].ring_bounded_context
    assert CAPS["hybrid"].ring_bounded_context


def test_unknown_family_rejected_at_config_time():
    """A bogus family dies in ModelConfig.__post_init__ (config layer),
    and family_caps guards independently for duck-typed configs."""
    with pytest.raises(ValueError, match="unknown model family"):
        get_arch("mamba2-2.7b", reduced=True).replace(family="rwkv")
    import types
    with pytest.raises(ValueError, match="unknown model family"):
        family_caps(types.SimpleNamespace(family="rwkv"))


# ---------------------------------------------------------------------------
# the full family x feature matrix, one consistent error shape
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", sorted(CAPS))
@pytest.mark.parametrize("feature", sorted(FEATURE_KW))
def test_matrix_cell(family, feature):
    """Every cell: supported -> caps row returned; unsupported -> the one
    canonical error shape, naming both the feature and the family."""
    cfg = get_arch(ARCH_FOR[family], reduced=True)
    caps = CAPS[family]
    attr = {"tensor-parallel serving": "tensor_parallel",
            "speculative decoding": "speculative",
            "prefix caching": "prefix_cache"}[feature]
    if getattr(caps, attr):
        assert validate_serve_features(cfg, **FEATURE_KW[feature]) is caps
    else:
        with pytest.raises(ValueError) as e:
            validate_serve_features(cfg, **FEATURE_KW[feature])
        msg = str(e.value)
        assert f"{feature} needs a KV-ring family (got {family!r})" in msg


def test_no_features_requested_always_passes():
    for family, arch in ARCH_FOR.items():
        cfg = get_arch(arch, reduced=True)
        assert validate_serve_features(cfg) is CAPS[family]


def test_engine_validates_at_construction_time():
    """The gates fire from the Engine constructor -- before any memory is
    allocated or jit traced -- with the same canonical message."""
    cfg = get_arch("mamba2-2.7b", reduced=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError,
                       match="speculative decoding needs a KV-ring family"):
        Engine(cfg, params, ServeConfig(drafter="ngram"))


# ---------------------------------------------------------------------------
# DecodeState adapter guards
# ---------------------------------------------------------------------------

def test_decode_state_asserts_on_missing_capability():
    ssm = DecodeState(get_arch("mamba2-2.7b", reduced=True))
    with pytest.raises(AssertionError):
        ssm.ring_snapshot({}, None)              # no ring to snapshot
    with pytest.raises(AssertionError):
        ssm.ring_rewind({}, {}, None, None)
    dense = DecodeState(get_arch("tinyllama-1.1b", reduced=True))
    with pytest.raises(AssertionError):
        dense.scatter_checkpoints({}, {}, None, None)  # pages, not ckpts
    with pytest.raises(AssertionError):
        dense.insert_checkpoints({}, {}, None, None)


def test_decode_state_page_keys_split_by_family():
    """Pool-key vocabulary: KV families page ring payloads only; the
    recurrent families additionally carry conv/SSM state checkpoints."""
    dense = DecodeState(get_arch("tinyllama-1.1b", reduced=True))
    ssm = DecodeState(get_arch("mamba2-2.7b", reduced=True))
    hyb = DecodeState(get_arch("zamba2-1.2b", reduced=True))
    ring = {"k", "v", "k_scale", "v_scale"}
    assert set(dense.page_keys()) == ring
    assert set(ssm.page_keys()) == ring | {"conv", "state"}
    assert set(hyb.page_keys()) == ring | {"conv", "state"}


# ---------------------------------------------------------------------------
# recurrent batched prefill: ONE compiled chunk program for all lengths
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["mamba2-2.7b", "zamba2-1.2b"])
def test_recurrent_prefill_compiles_once_across_lengths(arch):
    """Regression: the recurrent exact-length ``_prefill_impl`` jitted a
    fresh program for EVERY distinct prompt length. Recurrent families
    now ride the same bucketed masked-chunk path as KV families: prompt
    lengths 3..21 against prefill_chunk=8 must all hit one compiled
    ``_prefill_chunk`` entry."""
    cfg = get_arch(arch, reduced=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, ServeConfig(
        max_new_tokens=2, cache_len=64, decode_chunk=2, max_slots=1,
        prefill_bucket=4, prefill_chunk=8))
    rng = jax.random.PRNGKey(1)
    for n in range(3, 22):
        rng, k = jax.random.split(rng)
        prompt = jax.random.randint(k, (n,), 0, cfg.vocab_size).tolist()
        eng.generate([prompt])
    assert eng._prefill_chunk._cache_size() == 1
