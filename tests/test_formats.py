"""Format-layer tests: pack/unpack, round-trip error bounds, density."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import formats as F
from repro.core import quantize as Q

WEIGHT_VARIANTS = ["q2_k", "q3_k", "q3_k_o", "q4_k", "q5_k", "q6_k",
                   "q8_0"]

# worst-case |w - dq(q(w))| / absmax_block for each variant (loose but
# monotone bounds: error halves roughly per extra bit; q3_k_o shares the
# q3_k bound -- its sidecar only removes error on the outlier rows)
ERR_BOUND = {"q2_k": 0.65, "q3_k": 0.40, "q3_k_o": 0.40, "q4_k": 0.12,
             "q5_k": 0.07, "q6_k": 0.06, "q8_0": 0.006}


def _rand(key, K=512, N=128, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), (K, N)) * scale


@pytest.mark.parametrize("variant", WEIGHT_VARIANTS)
def test_roundtrip_error_bound(variant):
    w = _rand(0)
    t = Q.quantize(variant, w)
    wd = Q.dequantize(t)
    # per-element error bounded relative to the max |w| in its block
    fmt = F.get_format(t.variant)
    blk = fmt.block
    K, N = w.shape
    wb = np.asarray(w).reshape(K // blk, blk, N)
    amax = np.abs(wb).max(axis=1, keepdims=True) + 1e-9
    rel = np.abs(np.asarray(wd).reshape(wb.shape) - wb) / amax
    assert rel.max() <= ERR_BOUND[variant], rel.max()


@pytest.mark.parametrize("variant", WEIGHT_VARIANTS)
def test_bits_per_weight_matches_format(variant):
    w = _rand(1)
    t = Q.quantize(variant, w)
    assert abs(t.bits_per_weight
               - F.get_format(t.variant).bits_per_weight) < 1e-6


def test_error_monotone_in_bits():
    w = _rand(2)
    errs = []
    for v in WEIGHT_VARIANTS:
        t = Q.quantize(v, w)
        errs.append(float(jnp.sqrt(jnp.mean((Q.dequantize(t) - w) ** 2))))
    assert errs == sorted(errs, reverse=True), errs


def test_slab_pack_unpack_roundtrip():
    for bits, sb in [(1, 256), (2, 256), (4, 256), (2, 64)]:
        rng = np.random.default_rng(bits)
        q = rng.integers(0, 1 << bits, size=(512, 64)).astype(np.uint8)
        packed = F.slab_pack(jnp.asarray(q), bits, sb)
        assert packed.shape == (512 * bits // 8, 64)
        out = F.slab_unpack(packed, bits, sb)
        np.testing.assert_array_equal(np.asarray(out), q)


def test_fallback_rule():
    # llama.cpp: K % 256 != 0 falls back to q8_0 (needs K % 32 == 0)
    assert F.pick_fallback("q2_k", 512) == "q2_k"
    assert F.pick_fallback("q2_k", 29568) == "q8_0"   # qwen2-vl d_ff
    with pytest.raises(ValueError):
        F.pick_fallback("q2_k", 100)


def test_q8k_bsums_consistent():
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 512))
    qx = Q.quantize_q8_k(x)
    qs = np.asarray(qx["qs"], dtype=np.int32)
    bs = np.asarray(qx["bsums"], dtype=np.int32)
    np.testing.assert_array_equal(
        qs.reshape(qs.shape[0], -1, 16).sum(-1), bs)


@settings(max_examples=20, deadline=None)
@given(scale=st.floats(1e-3, 1e3), key=st.integers(0, 2**16),
       variant=st.sampled_from(WEIGHT_VARIANTS))
def test_scale_invariance_property(scale, key, variant):
    """Quantization error scales linearly with the data (BFP property)."""
    w = _rand(key, K=256, N=32)
    t1 = Q.quantize(variant, w)
    t2 = Q.quantize(variant, w * scale)
    e1 = np.abs(np.asarray(Q.dequantize(t1) - w)).max()
    e2 = np.abs(np.asarray(Q.dequantize(t2) - w * scale)).max()
    assert e2 <= (e1 * scale) * 1.25 + 1e-6


@settings(max_examples=15, deadline=None)
@given(key=st.integers(0, 2**16),
       variant=st.sampled_from(["q6_k", "q8_0"]))
def test_idempotence_property(key, variant):
    """Re-quantizing an already-dequantized tensor is near-stationary.

    This holds for the *symmetric* variants (scale refit on grid values is
    stable). The affine variants (Q2_K/Q4_K/Q5_K) re-fit scale AND min per
    block, which can oscillate by a quantization step -- so they are
    covered by the absolute error bound test instead."""
    step = {"q6_k": 0.12, "q8_0": 0.02}[variant]
    w = _rand(key, K=256, N=32)
    wd = Q.dequantize(Q.quantize(variant, w))
    wdd = Q.dequantize(Q.quantize(variant, wd))
    err = np.abs(np.asarray(wdd - wd))
    base = np.abs(np.asarray(wd)).max() + 1e-9
    assert err.max() / base < step


def test_q8k_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 512))
    qx = Q.quantize_q8_k(x)
    xd = Q.dequantize_q8_k(qx)
    rel = float(jnp.abs(xd - x).max() / jnp.abs(x).max())
    assert rel < 0.02


def test_qtensor_pytree_jit():
    w = _rand(4)
    t = Q.quantize("q3_k", w)
    out = jax.jit(Q.dequantize)(t)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(Q.dequantize(t)), rtol=1e-6)


def test_qtensor_spec_nbytes():
    s = Q.qtensor_spec("q2_k", 512, 384)
    assert s.nbytes == F.Q2_K.nbytes(512, 384)
    assert abs(s.bits_per_weight - 2.625) < 1e-9
