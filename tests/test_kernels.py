"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle,
swept over shapes, dtypes and variants -- plus a property-style parity
suite over ALL registered formats x ragged shapes x compute dtypes, so a
format added to ``core.formats.WEIGHT_VARIANTS`` later is covered with no
test edits."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import formats as F
from repro.core import quantize as Q
from repro.kernels import ops, ref
from repro.kernels.bfp_matmul import (_choose_block_k, bfp_matmul_pallas,
                                      vmem_bytes)
from repro.kernels.q8k_quant import q8k_quantize_pallas

VARIANTS = list(F.WEIGHT_VARIANTS)

# per-variant parity tolerance (relative to |ref|.max()), by compute
# dtype: the fused kernel and the oracle share the dequant formulas, so
# f32-compute disagreement is pure accumulation-order noise; bf16 compute
# adds rounding of x and w. A format registered later gets the default
# unless it needs its own entry.
_DEFAULT_RTOL = {"float32": 2e-5, "bfloat16": 2e-2}
PARITY_RTOL = {v: dict(_DEFAULT_RTOL) for v in VARIANTS}
PARITY_RTOL["q2_k"]["bfloat16"] = 3e-2      # coarsest grid, widest blocks


def _parity_rtol(variant: str, compute: str) -> float:
    return PARITY_RTOL.get(variant, _DEFAULT_RTOL)[compute]


def _mk(key, M, K, N, dtype=jnp.float32):
    kx, kw = jax.random.split(jax.random.PRNGKey(key))
    x = jax.random.normal(kx, (M, K), jnp.float32).astype(dtype)
    w = jax.random.normal(kw, (K, N), jnp.float32) * 0.2
    return x, w


@pytest.mark.parametrize("variant", VARIANTS)
@pytest.mark.parametrize("shape", [(8, 256, 128), (24, 768, 200),
                                   (1, 512, 384), (130, 512, 96)])
def test_pallas_vs_ref_shapes(variant, shape):
    M, K, N = shape
    x, w = _mk(0, M, K, N)
    t = Q.quantize(variant, w)
    o_ref = np.asarray(ref.matmul_ref(x, t))
    o_pal = np.asarray(bfp_matmul_pallas(
        x, t, interpret=True, compute_dtype=jnp.float32,
        out_dtype=jnp.float32, block_m=16, block_n=128, block_k=256))
    np.testing.assert_allclose(o_pal, o_ref, rtol=2e-5,
                               atol=2e-5 * np.abs(o_ref).max())


@pytest.mark.parametrize("variant", ["q2_k", "q3_k"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pallas_dtypes(variant, dtype):
    x, w = _mk(1, 16, 512, 128, dtype=dtype)
    t = Q.quantize(variant, w)
    o_ref = np.asarray(ref.matmul_ref(x.astype(jnp.float32), t))
    o_pal = np.asarray(bfp_matmul_pallas(
        x, t, interpret=True, compute_dtype=jnp.float32,
        out_dtype=jnp.float32, block_m=8, block_n=128, block_k=256))
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(o_pal, o_ref, rtol=tol,
                               atol=tol * np.abs(o_ref).max())


@pytest.mark.parametrize("block_k", [256, 512])
@pytest.mark.parametrize("block_n", [128, 256])
def test_pallas_block_sweep(block_k, block_n):
    x, w = _mk(2, 32, 1024, 256)
    t = Q.quantize("q2_k", w)
    o_ref = np.asarray(ref.matmul_ref(x, t))
    o_pal = np.asarray(bfp_matmul_pallas(
        x, t, interpret=True, compute_dtype=jnp.float32,
        out_dtype=jnp.float32, block_m=16, block_n=block_n,
        block_k=block_k))
    np.testing.assert_allclose(o_pal, o_ref, rtol=2e-5,
                               atol=2e-5 * np.abs(o_ref).max())


def test_integer_datapath_matches_dequant():
    """llama.cpp vec_dot (integer) semantics vs dequant matmul."""
    x, w = _mk(3, 16, 512, 64)
    qx = Q.quantize_q8_k(x)
    xd = Q.dequantize_q8_k(qx)
    for v in ("q2_k", "q3_k"):
        t = Q.quantize(v, w)
        oi = np.asarray(ref.matmul_q8k_ref(qx, t))
        od = np.asarray(ref.matmul_ref(xd, t))
        np.testing.assert_allclose(oi, od, rtol=1e-5,
                                   atol=1e-5 * np.abs(od).max())


def test_q8k_quant_kernel_matches_jnp():
    x = jax.random.normal(jax.random.PRNGKey(4), (24, 768))
    qk = q8k_quantize_pallas(x, interpret=True)
    qj = Q.quantize_q8_k(x)
    np.testing.assert_allclose(np.asarray(qk["d"]), np.asarray(qj["d"]),
                               rtol=1e-6)
    # quant values may differ by 1 ulp of rounding at scale boundaries
    assert np.abs(np.asarray(qk["qs"], np.int32)
                  - np.asarray(qj["qs"], np.int32)).max() <= 1
    np.testing.assert_array_equal(
        np.asarray(qk["qs"], np.int32).reshape(24, -1, 16).sum(-1),
        np.asarray(qk["bsums"], np.int32))


def test_ops_dispatch_and_batched():
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 3, 512))
    w = jax.random.normal(jax.random.PRNGKey(6), (512, 128)) * 0.1
    t = Q.quantize("q3_k", w)
    o_xla = ops.bfp_matmul(x, t, impl="xla", compute_dtype=jnp.float32,
                           out_dtype=jnp.float32)
    o_pal = ops.bfp_matmul(x, t, impl="pallas", interpret=True,
                           compute_dtype=jnp.float32,
                           out_dtype=jnp.float32)
    assert o_xla.shape == (2, 3, 128)
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_xla),
                               rtol=2e-5, atol=1e-4)


def test_vmem_budget_fits():
    """Kernel working set must fit v5e VMEM (16 MiB usable ~= 0.5 for us)."""
    for v in VARIANTS:
        b = vmem_bytes(v, 128, 256, 512)
        assert b["total"] < 8 * 2**20, (v, b)


@settings(max_examples=12, deadline=None)
@given(variant=st.sampled_from(VARIANTS),
       m=st.integers(1, 48), nsb=st.integers(1, 4),
       n=st.integers(1, 260),
       compute=st.sampled_from(["float32", "bfloat16"]),
       seed=st.integers(0, 2**16))
def test_property_pallas_matches_ref(variant, m, nsb, n, compute, seed):
    """Every registered weight format, ragged (M, K, N), both compute
    dtypes: fused Pallas kernel == dequant-matmul oracle within the
    per-variant tolerance. Formats registered later are swept
    automatically via F.WEIGHT_VARIANTS."""
    K = 256 * nsb                       # super-block multiple fits ALL
    x, w = _mk(seed, m, K, n)           # registered formats (q8_0 too)
    t = Q.quantize(variant, w)
    cd = jnp.dtype(compute)
    o_ref = np.asarray(ref.matmul_ref(x, t))
    o_pal = np.asarray(bfp_matmul_pallas(
        x.astype(cd), t, interpret=True, compute_dtype=cd,
        out_dtype=jnp.float32, block_m=16, block_n=128, block_k=256))
    tol = _parity_rtol(variant, compute)
    np.testing.assert_allclose(o_pal, o_ref, rtol=tol,
                               atol=tol * (np.abs(o_ref).max() + 1e-9))


@settings(max_examples=16, deadline=None)
@given(variant=st.sampled_from(VARIANTS),
       nsb=st.integers(1, 3), n=st.integers(1, 200),
       pad=st.integers(1, 190), seed=st.integers(0, 2**16))
def test_property_packed_lane_padding_is_inert(variant, nsb, n, pad, seed):
    """The fused kernel pads every packed payload array with zero bytes
    along the lane (N) axis when N is not a block multiple. That is only
    sound if zero payloads dequantize to EXACTLY 0.0 in every registered
    format -- including the offset-coded ones: Q3_K stores block scales
    biased by +32 (a zero byte decodes to scale -32) and Q4_0 pins
    ``d = mval / -8`` (a zero-weight block quantizes to d == -0.0, codes
    8), so inertness leans on the zeroed super-scale d (and dmin for the
    affine formats) annihilating the decoded fields. Property: for every
    format, zero-padded lane columns dequantize to +/-0.0 exactly, and
    the padded matmul's real columns are bit-identical to the unpadded
    run -- non-multiple-of-128 N never perturbs real outputs."""
    K = 256 * nsb
    x, w = _mk(seed, 4, K, n)
    t = Q.quantize(variant, w)
    padded = Q.QTensor(t.variant, (K, n + pad),
                       {k: jnp.pad(v, ((0, 0), (0, pad)))
                        for k, v in t.data.items()})
    wp = np.asarray(Q.dequantize(padded, dtype=jnp.float32))
    assert wp.shape == (K, n + pad)
    np.testing.assert_array_equal(wp[:, n:], 0.0)           # inert columns
    np.testing.assert_array_equal(
        wp[:, :n], np.asarray(Q.dequantize(t, dtype=jnp.float32)))
    o = np.asarray(bfp_matmul_pallas(
        x, t, interpret=True, compute_dtype=jnp.float32,
        out_dtype=jnp.float32, block_m=16, block_n=128, block_k=256))
    o_pad = np.asarray(bfp_matmul_pallas(
        x, padded, interpret=True, compute_dtype=jnp.float32,
        out_dtype=jnp.float32, block_m=16, block_n=128, block_k=256))
    np.testing.assert_array_equal(o_pad[:, :n], o)
    np.testing.assert_array_equal(o_pad[:, n:], 0.0)


@settings(max_examples=16, deadline=None)
@given(variant=st.sampled_from(VARIANTS),
       nsb=st.integers(1, 3), n=st.integers(1, 200),
       nshards=st.sampled_from([2, 4]), seed=st.integers(0, 2**16))
def test_property_lane_shard_dequant_bitexact(variant, nsb, n, nshards,
                                              seed):
    """Lane-only tensor parallelism's layout invariant, for EVERY
    registered format: slicing a packed QTensor's payload arrays on the
    lane (N) axis -- K rows whole, so no super-block ever straddles
    shards -- and dequantizing each shard reproduces EXACTLY the
    corresponding columns of the unsharded dequant, bit for bit. Ragged
    N is zero-padded up to a shard multiple first (exactly what the
    fused kernel's lane padding does), and the padded lanes must
    dequantize to +/-0.0 on whichever shard they land. This is what
    makes a TP shard's packed weights mathematically THE columns of the
    whole weight, the foundation of the serving parity guarantee."""
    K = 256 * nsb
    _, w = _mk(seed, 1, K, n)
    t = Q.quantize(variant, w)
    full = np.asarray(Q.dequantize(t, dtype=np.float32))
    pad = (-n) % nshards                    # ragged N -> shard multiple
    if pad:
        t = Q.QTensor(t.variant, (K, n + pad),
                      {k: jnp.pad(v, ((0, 0), (0, pad)))
                       for k, v in t.data.items()})
        full = np.concatenate([full, np.zeros((K, pad), np.float32)], 1)
    from repro.distributed.sharding import lane_shard_qtensor
    Np = n + pad
    chunk = Np // nshards
    for i in range(nshards):
        sh = lane_shard_qtensor(t, i, nshards)
        assert sh.shape == (K, chunk)
        got = np.asarray(Q.dequantize(sh, dtype=np.float32))
        np.testing.assert_array_equal(
            got, full[:, i * chunk:(i + 1) * chunk])
    # shard boundaries compose: re-concatenating every shard's dequant
    # is the unsharded dequant, so padded lanes decoded to exact zeros
    np.testing.assert_array_equal(full[:, n:], 0.0)


@settings(max_examples=12, deadline=None)
@given(variant=st.sampled_from(VARIANTS),
       m=st.integers(1, 24), nsb=st.integers(1, 3),
       n=st.integers(1, 130), nshards=st.sampled_from([2, 4]),
       seed=st.integers(0, 2**16))
def test_property_sliced_fused_matmul_matches_full(variant, m, nsb, n,
                                                   nshards, seed):
    """The sliced TP datapath's kernel invariant, for EVERY registered
    format over ragged (M, K, N): running each lane shard's packed
    payload through the fused dequant-matmul reproduces the matching
    output columns of the full-matrix fused run BIT-exactly (packing
    runs along K, so a lane slice never crosses a quantization group and
    the kernel sees literally the same bytes and the same K loop),
    and the full run itself sits within f32-ulp accumulation noise of
    the dequant-matmul oracle. Ragged N pads to a shard multiple with
    inert zero lanes, mirroring serve_param_specs' layout."""
    K = 256 * nsb
    x, w = _mk(seed, m, K, n)
    t = Q.quantize(variant, w)
    pad = (-n) % (nshards * 8)          # shard multiple, modest lane pad
    if pad:
        t = Q.QTensor(t.variant, (K, n + pad),
                      {k: jnp.pad(v, ((0, 0), (0, pad)))
                       for k, v in t.data.items()})
    Np = n + pad
    kw = dict(interpret=True, compute_dtype=jnp.float32,
              out_dtype=jnp.float32, block_m=16, block_n=64, block_k=256)
    o_full = np.asarray(bfp_matmul_pallas(x, t, **kw))
    o_ref = np.asarray(ref.matmul_ref(x, t))
    np.testing.assert_allclose(o_full, o_ref, rtol=2e-5,
                               atol=2e-5 * (np.abs(o_ref).max() + 1e-9))
    from repro.distributed.sharding import lane_shard_qtensor
    chunk = Np // nshards
    for i in range(nshards):
        sh = lane_shard_qtensor(t, i, nshards)
        o_sh = np.asarray(bfp_matmul_pallas(x, sh, **kw))
        np.testing.assert_array_equal(
            o_sh, o_full[:, i * chunk:(i + 1) * chunk])


@settings(max_examples=12, deadline=None)
@given(variant=st.sampled_from(VARIANTS),
       nsb=st.sampled_from([2, 4]), n=st.integers(1, 130),
       m=st.integers(1, 16), seed=st.integers(0, 2**16))
def test_property_row_shard_packed_bitexact(variant, nsb, n, m, seed):
    """The row-parallel ("sliced_row") layout invariant, for EVERY
    registered format: slicing a packed QTensor into whole-super-block
    K-row shards (row_shard_qtensor) dequantizes each shard
    bit-identically to its K rows of the full dequant, and the shards'
    fused-gemm f32 partials sum back to the full fused product within
    f32-ulp accumulation noise (the psum the serving datapath
    performs)."""
    from repro.distributed.sharding import row_shard_qtensor
    nshards = 2
    K = 256 * nsb                       # nsb super-blocks -> whole SBs/shard
    x, w = _mk(seed, m, K, n)
    t = Q.quantize(variant, w)
    sb = F.get_format(t.variant).super_block
    if K % (nshards * sb):              # q4_0/q8_0: sb=32, always fine here
        return
    full = np.asarray(Q.dequantize(t, dtype=np.float32))
    kl = K // nshards
    kw = dict(interpret=True, compute_dtype=jnp.float32,
              out_dtype=jnp.float32, block_m=16, block_n=64, block_k=256)
    o_full = np.asarray(bfp_matmul_pallas(x, t, **kw))
    acc = np.zeros_like(o_full)
    for i in range(nshards):
        sh = row_shard_qtensor(t, i, nshards)
        assert sh.shape == (kl, n)
        got = np.asarray(Q.dequantize(sh, dtype=np.float32))
        np.testing.assert_array_equal(got, full[i * kl:(i + 1) * kl])
        acc += np.asarray(bfp_matmul_pallas(x[:, i * kl:(i + 1) * kl],
                                            sh, **kw))
    np.testing.assert_allclose(acc, o_full, rtol=2e-5,
                               atol=2e-5 * (np.abs(o_full).max() + 1e-9))


def test_row_shard_rejects_split_super_blocks():
    """K rows that do not divide into whole super-blocks per shard must
    raise (the plan's "dequant" fallback handles those tensors)."""
    from repro.distributed.sharding import row_shard_qtensor
    _, w = _mk(13, 1, 256, 32)
    t = Q.quantize("q3_k", w)           # sb=256: 2 shards would split it
    with pytest.raises(ValueError, match="dequant"):
        row_shard_qtensor(t, 0, 2)


@settings(max_examples=8, deadline=None)
@given(m=st.integers(1, 20), nsb=st.integers(1, 3),
       masked=st.integers(0, 1), seed=st.integers(0, 2**16))
def test_property_q8k_batched_masked(m, nsb, masked, seed):
    """Batched activation quantization over ragged row counts, with and
    without the padded-row validity mask: kernel payloads match the jnp
    reference, and masked rows are exactly zero everywhere."""
    K = 256 * nsb
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(m, K)).astype(np.float32))
    valid = jnp.asarray(rng.integers(0, 2, m).astype(bool)) if masked \
        else None
    qk = ops.q8k_quantize(x, valid=valid, impl="pallas", interpret=True)
    qj = ops.q8k_quantize(x, valid=valid, impl="xla")
    np.testing.assert_allclose(np.asarray(qk["d"]), np.asarray(qj["d"]),
                               rtol=1e-6)
    assert np.abs(np.asarray(qk["qs"], np.int32)
                  - np.asarray(qj["qs"], np.int32)).max() <= 1
    np.testing.assert_array_equal(
        np.asarray(qk["qs"], np.int32).reshape(m, -1, 16).sum(-1),
        np.asarray(qk["bsums"], np.int32))
    if valid is not None:
        dead = ~np.asarray(valid)
        assert not np.asarray(qk["qs"])[dead].any()
        assert not np.asarray(qk["d"])[dead].any()
        assert not np.asarray(qk["bsums"])[dead].any()


def test_choose_block_k_awkward_K_falls_back():
    """Regression: K with no super-block-aligned divisor near the target
    (e.g. 7*256 with target 384) must fall back to bk=sb, not raise."""
    assert _choose_block_k(1792, 256, target=384) == 256
    assert _choose_block_k(1792, 256, target=512) == 256
    assert _choose_block_k(1024, 256, target=512) == 512
    assert _choose_block_k(512, 256, target=512) == 512
    assert _choose_block_k(96, 32, target=512) == 96      # K <= target
    assert _choose_block_k(1792, 256, target=128) == 256  # target < sb
    with pytest.raises(ValueError, match="super-block"):
        _choose_block_k(100, 256)
    # end to end: the awkward K actually runs and matches the oracle
    x, w = _mk(11, 8, 1792, 64)
    t = Q.quantize("q2_k", w)
    o_pal = np.asarray(bfp_matmul_pallas(
        x, t, interpret=True, compute_dtype=jnp.float32,
        out_dtype=jnp.float32, block_m=8, block_n=64, block_k=384))
    o_ref = np.asarray(ref.matmul_ref(x, t))
    np.testing.assert_allclose(o_pal, o_ref, rtol=2e-5,
                               atol=2e-5 * np.abs(o_ref).max())


def test_pallas_under_jit():
    x, w = _mk(7, 8, 256, 128)
    t = Q.quantize("q2_k", w)
    f = jax.jit(lambda xx, tt: bfp_matmul_pallas(
        xx, tt, interpret=True, compute_dtype=jnp.float32,
        out_dtype=jnp.float32, block_m=8, block_n=128, block_k=256))
    o = f(x, t)
    np.testing.assert_allclose(np.asarray(o),
                               np.asarray(ref.matmul_ref(x, t)),
                               rtol=2e-5, atol=1e-4)
