"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle,
swept over shapes, dtypes and variants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quantize as Q
from repro.kernels import ops, ref
from repro.kernels.bfp_matmul import bfp_matmul_pallas, vmem_bytes
from repro.kernels.q8k_quant import q8k_quantize_pallas

VARIANTS = ["q2_k", "q3_k", "q4_k", "q5_k", "q6_k", "q8_0"]


def _mk(key, M, K, N, dtype=jnp.float32):
    kx, kw = jax.random.split(jax.random.PRNGKey(key))
    x = jax.random.normal(kx, (M, K), jnp.float32).astype(dtype)
    w = jax.random.normal(kw, (K, N), jnp.float32) * 0.2
    return x, w


@pytest.mark.parametrize("variant", VARIANTS)
@pytest.mark.parametrize("shape", [(8, 256, 128), (24, 768, 200),
                                   (1, 512, 384), (130, 512, 96)])
def test_pallas_vs_ref_shapes(variant, shape):
    M, K, N = shape
    x, w = _mk(0, M, K, N)
    t = Q.quantize(variant, w)
    o_ref = np.asarray(ref.matmul_ref(x, t))
    o_pal = np.asarray(bfp_matmul_pallas(
        x, t, interpret=True, compute_dtype=jnp.float32,
        out_dtype=jnp.float32, block_m=16, block_n=128, block_k=256))
    np.testing.assert_allclose(o_pal, o_ref, rtol=2e-5,
                               atol=2e-5 * np.abs(o_ref).max())


@pytest.mark.parametrize("variant", ["q2_k", "q3_k"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pallas_dtypes(variant, dtype):
    x, w = _mk(1, 16, 512, 128, dtype=dtype)
    t = Q.quantize(variant, w)
    o_ref = np.asarray(ref.matmul_ref(x.astype(jnp.float32), t))
    o_pal = np.asarray(bfp_matmul_pallas(
        x, t, interpret=True, compute_dtype=jnp.float32,
        out_dtype=jnp.float32, block_m=8, block_n=128, block_k=256))
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(o_pal, o_ref, rtol=tol,
                               atol=tol * np.abs(o_ref).max())


@pytest.mark.parametrize("block_k", [256, 512])
@pytest.mark.parametrize("block_n", [128, 256])
def test_pallas_block_sweep(block_k, block_n):
    x, w = _mk(2, 32, 1024, 256)
    t = Q.quantize("q2_k", w)
    o_ref = np.asarray(ref.matmul_ref(x, t))
    o_pal = np.asarray(bfp_matmul_pallas(
        x, t, interpret=True, compute_dtype=jnp.float32,
        out_dtype=jnp.float32, block_m=16, block_n=block_n,
        block_k=block_k))
    np.testing.assert_allclose(o_pal, o_ref, rtol=2e-5,
                               atol=2e-5 * np.abs(o_ref).max())


def test_integer_datapath_matches_dequant():
    """llama.cpp vec_dot (integer) semantics vs dequant matmul."""
    x, w = _mk(3, 16, 512, 64)
    qx = Q.quantize_q8_k(x)
    xd = Q.dequantize_q8_k(qx)
    for v in ("q2_k", "q3_k"):
        t = Q.quantize(v, w)
        oi = np.asarray(ref.matmul_q8k_ref(qx, t))
        od = np.asarray(ref.matmul_ref(xd, t))
        np.testing.assert_allclose(oi, od, rtol=1e-5,
                                   atol=1e-5 * np.abs(od).max())


def test_q8k_quant_kernel_matches_jnp():
    x = jax.random.normal(jax.random.PRNGKey(4), (24, 768))
    qk = q8k_quantize_pallas(x, interpret=True)
    qj = Q.quantize_q8_k(x)
    np.testing.assert_allclose(np.asarray(qk["d"]), np.asarray(qj["d"]),
                               rtol=1e-6)
    # quant values may differ by 1 ulp of rounding at scale boundaries
    assert np.abs(np.asarray(qk["qs"], np.int32)
                  - np.asarray(qj["qs"], np.int32)).max() <= 1
    np.testing.assert_array_equal(
        np.asarray(qk["qs"], np.int32).reshape(24, -1, 16).sum(-1),
        np.asarray(qk["bsums"], np.int32))


def test_ops_dispatch_and_batched():
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 3, 512))
    w = jax.random.normal(jax.random.PRNGKey(6), (512, 128)) * 0.1
    t = Q.quantize("q3_k", w)
    o_xla = ops.bfp_matmul(x, t, impl="xla", compute_dtype=jnp.float32,
                           out_dtype=jnp.float32)
    o_pal = ops.bfp_matmul(x, t, impl="pallas", interpret=True,
                           compute_dtype=jnp.float32,
                           out_dtype=jnp.float32)
    assert o_xla.shape == (2, 3, 128)
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_xla),
                               rtol=2e-5, atol=1e-4)


def test_vmem_budget_fits():
    """Kernel working set must fit v5e VMEM (16 MiB usable ~= 0.5 for us)."""
    for v in VARIANTS:
        b = vmem_bytes(v, 128, 256, 512)
        assert b["total"] < 8 * 2**20, (v, b)


def test_pallas_under_jit():
    x, w = _mk(7, 8, 256, 128)
    t = Q.quantize("q2_k", w)
    f = jax.jit(lambda xx, tt: bfp_matmul_pallas(
        xx, tt, interpret=True, compute_dtype=jnp.float32,
        out_dtype=jnp.float32, block_m=8, block_n=128, block_k=256))
    o = f(x, t)
    np.testing.assert_allclose(np.asarray(o),
                               np.asarray(ref.matmul_ref(x, t)),
                               rtol=2e-5, atol=1e-4)
