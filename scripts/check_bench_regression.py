"""Benchmark regression gate: compare a fresh e2e_serve JSON against the
committed baseline and fail (exit 1) on serving-metric regressions.

Usage (what CI runs):

    PYTHONPATH=src python -m benchmarks.e2e_serve --smoke --out new.json
    PYTHONPATH=src python scripts/check_bench_regression.py \
        --new new.json --baseline benchmarks/results/e2e_serve.json

Runs are matched on (params, queue_depth); only pairs present in BOTH
files are compared, so the smoke sweep gates against the full committed
baseline (and the spec-decode smoke run gates against the committed
speculative row). A metric absent from the BASELINE row skips that gate
instead of KeyError-ing (tensor-parallel rows, for instance, only exist
in sweeps run with multiple forced devices, and older baselines predate
some metrics); a metric the baseline has but the new run dropped is a
reporting regression and FAILS. Three metrics are gated:

  * decode tok/s        -- fail if new < (1 - tol) * baseline
  * prefill tok/s       -- fail if new < (1 - tol-prefill) * baseline
  * time-to-first-token -- fail if new > (1 + tol-ttft) * baseline

Prefill/ttft wall-clock at these tiny shapes is dispatch-dominated and
much noisier across runner generations than decode, so their default
tolerances are wider (and CI retries the whole sweep; a real regression
fails every attempt, a noisy neighbor does not). Speculative rows also
report acceptance rate for context (not gated -- it is a property of the
drafter/workload pair, not of the code path's speed). Shared-prefix rows
gate ``prefix_hit_rate > 0`` whenever the baseline row hits: the radix
tree matching is deterministic for that workload, so a zero hit rate
means the prefix cache structurally stopped working (their ttft rides
the ordinary ttft gate).

Tensor-parallel rows additionally carry a SAME-RUN structural gate
(``check_tp_sliced``): whenever a sweep produced the forced-host-device
TP rows, every tp>1 sliced datapath (``sliced`` / ``sliced_row``) must
beat the same run's tp=1 row on decode tok/s, and at least one of them
must beat it on prefill tok/s too -- the reason those datapaths exist.
Comparing rows from ONE run cancels machine drift, so this gate is
tight where the cross-run gates must be loose; it is skipped entirely
on 1-device sweeps that produce no TP rows.
"""
from __future__ import annotations

import argparse
import json
import sys


def check_tp_sliced(new: dict) -> int:
    """Same-run structural gate on the TP datapaths: sliced must be the
    fast path. Every tp>1 ``sliced``/``sliced_row`` row must beat the
    run's tp=1 row on decode tok/s, and at least one must beat it on
    prefill tok/s. Returns the number of failures (0 when the sweep has
    no TP rows -- e.g. CI's 1-device smoke sweep)."""
    tp_rows = [r for r in new.get("runs", []) if "tp_matmul" in r]
    base1 = [r for r in tp_rows if r.get("tp") == 1]
    sliced = [r for r in tp_rows
              if r.get("tp", 1) > 1 and "sliced" in r["tp_matmul"]]
    if not base1 or not sliced:
        return 0
    t1 = base1[0]
    fails = 0
    for r in sliced:
        ok = r["tok_per_s"] > t1["tok_per_s"]
        fails += not ok
        print(f"{'OK ' if ok else 'FAIL'} tp{r['tp']} {r['tp_matmul']:>10} "
              f"decode {r['tok_per_s']:>8.1f} vs tp1 {t1['tok_per_s']:>8.1f}")
    best = max(sliced, key=lambda r: r["prefill_tok_per_s"])
    ok = best["prefill_tok_per_s"] > t1["prefill_tok_per_s"]
    fails += not ok
    print(f"{'OK ' if ok else 'FAIL'} tp{best['tp']} {best['tp_matmul']:>10} "
          f"prefill {best['prefill_tok_per_s']:>8.1f} vs tp1 "
          f"{t1['prefill_tok_per_s']:>8.1f}")
    if fails:
        print(f"REGRESSION: sliced TP stopped beating tp1 "
              f"({fails} structural failure(s))")
    return fails


def compare(new: dict, baseline: dict, tol: float, tol_prefill: float,
            tol_ttft: float) -> int:
    base_by_key = {(r["params"], r["queue_depth"]): r
                   for r in baseline.get("runs", [])}
    failures, compared = [], 0
    for r in new.get("runs", []):
        key = (r["params"], r["queue_depth"])
        b = base_by_key.get(key)
        if b is None:
            continue
        compared += 1
        bad = []
        # a metric absent from the BASELINE skips that gate instead of
        # KeyError-ing (old baselines predate some metrics; rows only a
        # richer sweep produces -- e.g. the multi-device tensor-parallel
        # rows -- are already handled by the pair matching above). A
        # metric the baseline HAS but the new run LACKS is a reporting
        # regression and fails: every engine row is expected to keep
        # emitting tok_per_s/prefill_tok_per_s/ttft_s.
        bt, rt = b.get("tok_per_s"), r.get("tok_per_s")
        floor = (1.0 - tol) * bt if bt is not None else 0.0
        if bt is not None and (rt is None or rt < floor):
            bad.append("decode" if rt is not None else "decode-missing")
        p_floor = (1.0 - tol_prefill) * b.get("prefill_tok_per_s", 0)
        if b.get("prefill_tok_per_s") is not None:
            rp = r.get("prefill_tok_per_s")
            if rp is None or rp < p_floor:
                bad.append("prefill" if rp is not None
                           else "prefill-missing")
        t_ceil = (1.0 + tol_ttft) * b.get("ttft_s", 0)
        if b.get("ttft_s", 0) > 0:
            rtt = r.get("ttft_s")
            if rtt is None or rtt > t_ceil:
                bad.append("ttft" if rtt is not None else "ttft-missing")
        # prefix rows: the radix tree must actually hit on the
        # shared-system-prompt workload -- a structural gate (hit rate is
        # deterministic for this workload), not a wall-clock one
        if b.get("prefix_hit_rate", 0) > 0 and r.get("prefix_hit_rate",
                                                     0) <= 0:
            bad.append("prefix_hit_rate")
        status = "OK " if not bad else "FAIL"
        accept = (f" accept_rate {r['accept_rate']:.2f} vs "
                  f"{b.get('accept_rate', 0):.2f}"
                  if "accept_rate" in r else "")
        if "prefix_hit_rate" in r:
            accept += (f" prefix_hit_rate {r['prefix_hit_rate']:.2f} vs "
                       f"{b.get('prefix_hit_rate', 0):.2f}")
        print(f"{status} {key[0]:>26} d{key[1]:<3} decode tok/s "
              f"{r.get('tok_per_s', 0):>8.1f} vs {b.get('tok_per_s', 0):>8.1f} "
              f"(floor {floor:.1f}) | prefill tok/s "
              f"{r.get('prefill_tok_per_s', 0):>8.1f} vs "
              f"{b.get('prefill_tok_per_s', 0):>8.1f} "
              f"(floor {p_floor:.1f}) | ttft_s "
              f"{r.get('ttft_s', 0):.5f} vs {b.get('ttft_s', 0):.5f} "
              f"(ceil {t_ceil:.5f}){accept}")
        if bad:
            failures.append((key, tuple(bad)))
    if compared == 0:
        print("ERROR: no (params, queue_depth) pairs in common with the "
              "baseline -- wrong file?")
        return 2
    tp_fails = check_tp_sliced(new)
    if failures or tp_fails:
        if failures:
            print(f"REGRESSION: {failures} exceeded tolerances "
                  f"(decode {tol:.0%}, prefill {tol_prefill:.0%}, "
                  f"ttft +{tol_ttft:.0%})")
        return 1
    print(f"all {compared} compared runs within tolerance "
          f"(decode {tol:.0%}, prefill {tol_prefill:.0%}, "
          f"ttft +{tol_ttft:.0%})")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--new", required=True, help="freshly produced JSON")
    ap.add_argument("--baseline", required=True,
                    help="committed baseline JSON")
    ap.add_argument("--tol", type=float, default=0.20,
                    help="allowed fractional decode tok/s drop (0.20)")
    ap.add_argument("--tol-prefill", type=float, default=0.60,
                    help="allowed fractional prefill tok/s drop (0.60; "
                         "prefill wall-clock is dispatch-noisy at smoke "
                         "shapes and swings hard on shared runners)")
    ap.add_argument("--tol-ttft", type=float, default=2.00,
                    help="allowed fractional time-to-first-token GROWTH "
                         "(2.00, i.e. 3x; ttft is the noisiest metric -- "
                         "the gate exists to catch structural "
                         "regressions like losing batched admission)")
    args = ap.parse_args()
    with open(args.new) as f:
        new = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)
    return compare(new, baseline, args.tol, args.tol_prefill,
                   args.tol_ttft)


if __name__ == "__main__":
    sys.exit(main())
