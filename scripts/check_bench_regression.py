"""Benchmark regression gate: compare a fresh e2e_serve JSON against the
committed baseline and fail (exit 1) on decode-throughput regressions.

Usage (what CI runs):

    PYTHONPATH=src python -m benchmarks.e2e_serve --smoke --out new.json
    PYTHONPATH=src python scripts/check_bench_regression.py \
        --new new.json --baseline benchmarks/results/e2e_serve.json

Runs are matched on (params, queue_depth); only pairs present in BOTH
files are compared, so the smoke sweep gates against the full committed
baseline. Decode tok/s is the gated metric (fail if new < (1 - tol) *
baseline); prefill tok/s and time-to-first-token are reported for
context but not gated -- wall-clock prefill at these tiny shapes is
dominated by dispatch overhead and too noisy across runner generations
to gate on.
"""
from __future__ import annotations

import argparse
import json
import sys


def compare(new: dict, baseline: dict, tol: float) -> int:
    base_by_key = {(r["params"], r["queue_depth"]): r
                   for r in baseline.get("runs", [])}
    failures, compared = [], 0
    for r in new.get("runs", []):
        key = (r["params"], r["queue_depth"])
        b = base_by_key.get(key)
        if b is None:
            continue
        compared += 1
        floor = (1.0 - tol) * b["tok_per_s"]
        status = "OK " if r["tok_per_s"] >= floor else "FAIL"
        print(f"{status} {key[0]:>16} d{key[1]:<3} decode tok/s "
              f"{r['tok_per_s']:>8.1f} vs baseline {b['tok_per_s']:>8.1f} "
              f"(floor {floor:.1f}) | prefill tok/s "
              f"{r.get('prefill_tok_per_s', 0):>8.1f} vs "
              f"{b.get('prefill_tok_per_s', 0):>8.1f} | ttft_s "
              f"{r.get('ttft_s', 0):.5f} vs {b.get('ttft_s', 0):.5f}")
        if r["tok_per_s"] < floor:
            failures.append(key)
    if compared == 0:
        print("ERROR: no (params, queue_depth) pairs in common with the "
              "baseline -- wrong file?")
        return 2
    if failures:
        print(f"REGRESSION: decode tok/s dropped more than {tol:.0%} on "
              f"{failures}")
        return 1
    print(f"all {compared} compared runs within {tol:.0%} of baseline")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--new", required=True, help="freshly produced JSON")
    ap.add_argument("--baseline", required=True,
                    help="committed baseline JSON")
    ap.add_argument("--tol", type=float, default=0.20,
                    help="allowed fractional decode tok/s drop (0.20)")
    args = ap.parse_args()
    with open(args.new) as f:
        new = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)
    return compare(new, baseline, args.tol)


if __name__ == "__main__":
    sys.exit(main())
