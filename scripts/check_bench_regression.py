"""Benchmark regression gate: compare a fresh e2e_serve JSON against the
committed baseline and fail (exit 1) on serving-metric regressions.

Usage (what CI runs):

    PYTHONPATH=src python -m benchmarks.e2e_serve --smoke --out new.json
    PYTHONPATH=src python scripts/check_bench_regression.py \
        --new new.json --baseline benchmarks/results/e2e_serve.json

Runs are matched on (params, queue_depth); only pairs present in BOTH
files are compared, so the smoke sweep gates against the full committed
baseline (and the spec-decode smoke run gates against the committed
speculative row). A metric absent from the BASELINE row -- or carried as
an explicit JSON ``null`` -- skips that gate instead of crashing
(tensor-parallel rows, for instance, only exist in sweeps run with
multiple forced devices, and older baselines predate some metrics); a
metric the baseline has that the new run dropped is a reporting
regression and FAILS. Three metrics are gated:

  * decode tok/s        -- fail if new < (1 - tol) * baseline
  * prefill tok/s       -- fail if new < (1 - tol-prefill) * baseline
  * time-to-first-token -- fail if new > (1 + tol-ttft) * baseline

Prefill/ttft wall-clock at these tiny shapes is dispatch-dominated and
much noisier across runner generations than decode, so their default
tolerances are wider (and CI retries the whole sweep; a real regression
fails every attempt, a noisy neighbor does not). Speculative rows also
report acceptance rate for context (not gated -- it is a property of the
drafter/workload pair, not of the code path's speed). Shared-prefix rows
gate ``prefix_hit_rate > 0`` whenever the baseline row hits: the radix
tree matching is deterministic for that workload, so a zero hit rate
means the prefix cache structurally stopped working (their ttft rides
the ordinary ttft gate).

Four SAME-RUN structural gates ride along (rows from ONE run cancel
machine drift, so these are tight where the cross-run gates must be
loose):

* ``check_tp_sliced``: whenever a sweep produced the forced-host-device
  TP rows, every tp>1 sliced datapath (``sliced`` / ``sliced_row``) must
  beat the tp=1 row AT THE SAME QUEUE DEPTH on decode tok/s, and at
  least one of them must beat it on prefill tok/s too -- the reason
  those datapaths exist. Skipped entirely on 1-device sweeps that
  produce no TP rows; a TP row MISSING a gated metric is a failure, not
  a crash.
* ``check_disagg``: whenever a sweep produced the monolithic-vs-
  disaggregated row pair, each disagg row must (a) serve exactly as many
  tokens as the mono row at the same depth (the parity contract,
  structurally), (b) have actually migrated KV pages, and (c) show
  decode-side prefix hits (migrated pages being USED). Missing or null
  fields are failures.
* ``check_recurrent_prefill``: every recurrent (ssm / hybrid) batched
  row must beat its own same-run ``exact_prefill_tok_per_s`` (the old
  one-compile-per-prompt-length prefill) on prefill tok/s, and every
  recurrent prefix row must show a positive checkpoint hit rate --
  batched fixed-grid chunking and checkpoint-mode prefix caching are
  the reasons those rows exist. Missing or null fields are failures.
* ``check_policy_auto``: whenever a sweep produced the auto-policy
  quality-at-size rows, the searched assignment must dominate-or-match
  default_serve_mix on both teacher-logit KL and model bytes for every
  benched arch (the search's documented return contract), and beat the
  pure_q2_k anchor on quality / pure_q6_k anchor on size when present.
  Missing or null fields are failures.

Trace-bench JSONs (``benchmark: "trace_serve"``) dispatch to
``check_trace`` instead: rows are matched on (mix, rate_rps, params),
tail TTFT is gated by the same --tol-ttft growth ceiling, goodput-
under-SLO by an absolute-fraction floor (--goodput-drop), and a
same-run structural pass pins the arrival-time accounting contract:
every row must carry non-null tail stats and its arrival-stamped TTFT
percentiles must not exceed the run-entry-stamped ones the bench also
records (the bugfix this gate exists to keep fixed).
"""
from __future__ import annotations

import argparse
import json
import sys


def _fmt(v, spec: str = ">8.1f") -> str:
    """Format a metric that may be missing (None / explicit JSON null)
    without crashing the report line."""
    if isinstance(v, bool) or not isinstance(v, (int, float)):
        return format("--", ">8") if spec.startswith(">8") else "--"
    return format(v, spec)


def check_tp_sliced(new: dict) -> int:
    """Same-run structural gate on the TP datapaths: sliced must be the
    fast path. Every tp>1 ``sliced``/``sliced_row`` row must beat the
    tp=1 row AT THE SAME QUEUE DEPTH on decode tok/s, and per depth at
    least one sliced row must beat tp=1 on prefill tok/s. Returns the
    number of failures (0 when the sweep has no TP rows -- e.g. CI's
    1-device smoke sweep). A sliced row whose gated metric is missing or
    null counts as a failure (reporting regression), never a crash; a
    depth with no tp=1 counterpart is skipped (nothing to compare)."""
    tp_rows = [r for r in new.get("runs", []) if "tp_matmul" in r]
    base1 = {r.get("queue_depth"): r for r in tp_rows if r.get("tp") == 1}
    sliced = [r for r in tp_rows
              if r.get("tp", 1) > 1 and "sliced" in r["tp_matmul"]]
    if not base1 or not sliced:
        return 0
    fails = 0
    best_prefill: dict = {}     # depth -> best sliced prefill tok/s
    for r in sliced:
        d = r.get("queue_depth")
        t1 = base1.get(d)
        if t1 is None:
            print(f"SKIP tp{r.get('tp')} {r.get('tp_matmul', '?'):>10} "
                  f"d{d}: no tp=1 row at this queue depth")
            continue
        rt, bt = r.get("tok_per_s"), t1.get("tok_per_s")
        if rt is None or bt is None:
            fails += 1
            print(f"FAIL tp{r.get('tp')} {r['tp_matmul']:>10} d{d} "
                  f"decode tok/s missing "
                  f"({'sliced' if rt is None else 'tp1'} row)")
        else:
            ok = rt > bt
            fails += not ok
            print(f"{'OK ' if ok else 'FAIL'} tp{r['tp']} "
                  f"{r['tp_matmul']:>10} d{d} decode {rt:>8.1f} vs tp1 "
                  f"{bt:>8.1f}")
        rp = r.get("prefill_tok_per_s")
        if rp is None:
            fails += 1
            print(f"FAIL tp{r.get('tp')} {r['tp_matmul']:>10} d{d} "
                  f"prefill tok/s missing")
        elif rp > best_prefill.get(d, (0.0, None))[0]:
            best_prefill[d] = (rp, r)
    for d, (rp, r) in sorted(best_prefill.items(),
                             key=lambda kv: str(kv[0])):
        bp = base1[d].get("prefill_tok_per_s")
        if bp is None:
            fails += 1
            print(f"FAIL tp1 d{d} prefill tok/s missing from tp=1 row")
            continue
        ok = rp > bp
        fails += not ok
        print(f"{'OK ' if ok else 'FAIL'} tp{r['tp']} {r['tp_matmul']:>10} "
              f"d{d} prefill {rp:>8.1f} vs tp1 {bp:>8.1f}")
    if fails:
        print(f"REGRESSION: sliced TP stopped beating tp1 "
              f"({fails} structural failure(s))")
    return fails


def check_disagg(new: dict) -> int:
    """Same-run structural gate on the monolithic-vs-disaggregated row
    pair. For every depth where the sweep emitted both a ``disagg:
    "mono"`` row and disaggregated rows, each disagg row must serve the
    SAME token count as the mono row (routed output is parity-pinned
    token-identical, so the structural echo of that contract is an exact
    match), must have migrated KV pages (the hand-off actually ran), and
    must show decode-side prefix hits (the migrated pages were used at
    admission). Missing or null fields are failures, not crashes.
    Returns the failure count (0 when the sweep has no disagg rows)."""
    rows = [r for r in new.get("runs", []) if "disagg" in r]
    mono = {r.get("queue_depth"): r for r in rows
            if r.get("disagg") == "mono"}
    dis = [r for r in rows if r.get("disagg") not in (None, "mono")]
    if not mono or not dis:
        return 0
    fails = 0
    for r in dis:
        d = r.get("queue_depth")
        m = mono.get(d)
        tag = f"disagg {r.get('disagg')} d{d}"
        if m is None:
            fails += 1
            print(f"FAIL {tag}: no mono row at this queue depth")
            continue
        bad = []
        rt, mt = r.get("tokens"), m.get("tokens")
        if not isinstance(rt, int) or not isinstance(mt, int):
            bad.append("tokens-missing")
        elif rt != mt:
            bad.append(f"tokens {rt} != mono {mt}")
        mig = r.get("migrated_pages")
        if not isinstance(mig, int):
            bad.append("migrated_pages-missing")
        elif mig <= 0:
            bad.append("migrated_pages=0")
        hit = r.get("prefix_hit_rate")
        if not isinstance(hit, (int, float)) or isinstance(hit, bool):
            bad.append("prefix_hit_rate-missing")
        elif hit <= 0:
            bad.append("prefix_hit_rate=0")
        fails += len(bad)
        print(f"{'OK ' if not bad else 'FAIL'} {tag} tokens "
              f"{_fmt(rt, 'd') if isinstance(rt, int) else '--'} vs mono "
              f"{_fmt(mt, 'd') if isinstance(mt, int) else '--'}, migrated "
              f"{mig if isinstance(mig, int) else '--'}, prefix_hit_rate "
              f"{_fmt(hit, '.2f')}"
              + (f" [{'; '.join(bad)}]" if bad else ""))
    if fails:
        print(f"REGRESSION: disaggregated serving structurally broken "
              f"({fails} failure(s))")
    return fails


def check_recurrent_prefill(new: dict) -> int:
    """Same-run structural gate on the recurrent (ssm / hybrid) serving
    rows. Every ``prefill_mode: "batched"`` recurrent row must beat its
    own ``exact_prefill_tok_per_s`` (the old one-compile-per-prompt-
    length prefill, measured in the SAME run) on prefill tok/s -- the
    reason recurrent families ride the batched fixed-grid chunk path.
    Every ``prefill_mode: "prefix_on"`` recurrent row must show a
    positive ``prefix_hit_rate`` (checkpoint matching is deterministic
    for the shared-system-prompt workload, so zero means checkpoint-mode
    prefix caching structurally stopped working). Missing or null fields
    are failures, not crashes. Returns the failure count (0 when the
    sweep has no recurrent rows)."""
    rows = [r for r in new.get("runs", [])
            if r.get("family") in ("ssm", "hybrid")
            and "prefill_mode" in r]
    if not rows:
        return 0
    fails = 0
    for r in rows:
        tag = (f"recurrent {r.get('family')} {r.get('prefill_mode')} "
               f"d{r.get('queue_depth')}")
        if r["prefill_mode"] == "batched":
            rp = r.get("prefill_tok_per_s")
            ep = r.get("exact_prefill_tok_per_s")
            if not isinstance(rp, (int, float)) or \
                    not isinstance(ep, (int, float)):
                fails += 1
                print(f"FAIL {tag}: prefill tok/s missing "
                      f"({'batched' if rp is None else 'exact'} side)")
                continue
            ok = rp > ep
            fails += not ok
            print(f"{'OK ' if ok else 'FAIL'} {tag} batched prefill "
                  f"{rp:>8.1f} vs exact-length {ep:>8.1f}")
        elif r["prefill_mode"] == "prefix_on":
            hit = r.get("prefix_hit_rate")
            if not isinstance(hit, (int, float)) or isinstance(hit, bool):
                fails += 1
                print(f"FAIL {tag}: prefix_hit_rate missing")
                continue
            ok = hit > 0
            fails += not ok
            print(f"{'OK ' if ok else 'FAIL'} {tag} prefix_hit_rate "
                  f"{hit:.2f}")
    if fails:
        print(f"REGRESSION: recurrent batched prefill / checkpoint "
              f"prefix cache structurally broken ({fails} failure(s))")
    return fails


def _num(v):
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def check_policy_auto(new: dict) -> int:
    """Same-run structural gate on the auto-policy quality-at-size rows.
    For every arch where the sweep emitted both a ``policy: "auto"`` row
    and a ``policy: "default_serve_mix"`` row, the searched assignment
    must dominate-or-match the default on BOTH axes: teacher-logit
    ``kl`` no worse and ``model_bytes`` no larger (the search returns
    the best verified state weakly dominating its seed, so a violation
    means the search or its serialization structurally broke). When the
    pure anchors are present, auto must also beat pure_q2_k on quality
    and pure_q6_k on size -- the quality-at-size headline. Missing or
    null fields are failures, not crashes. Returns the failure count
    (0 when the sweep has no auto-policy rows)."""
    rows = [r for r in new.get("runs", []) if "policy" in r]
    by = {}
    for r in rows:
        by.setdefault(r.get("policy_arch"), {})[r.get("policy")] = r
    autos = [(a, d) for a, d in sorted(by.items()) if "auto" in d]
    if not autos:
        return 0
    fails = 0
    for arch, d in autos:
        r = d["auto"]
        tag = f"policy auto {arch}"
        base = d.get("default_serve_mix")
        if base is None:
            fails += 1
            print(f"FAIL {tag}: no default_serve_mix row for this arch")
            continue
        bad = []
        rkl, bkl = r.get("kl"), base.get("kl")
        rby, bby = r.get("model_bytes"), base.get("model_bytes")
        if not _num(rkl) or not _num(bkl):
            bad.append("kl-missing")
        elif rkl > bkl * (1 + 1e-6):
            bad.append(f"kl {rkl} > default {bkl}")
        if not _num(rby) or not _num(bby):
            bad.append("model_bytes-missing")
        elif rby > bby:
            bad.append(f"bytes {rby} > default {bby}")
        q2, q6 = d.get("pure_q2_k"), d.get("pure_q6_k")
        if q2 is not None and _num(rkl):
            if not _num(q2.get("kl")):
                bad.append("q2_k-anchor-kl-missing")
            elif rkl >= q2["kl"]:
                bad.append(f"kl {rkl} >= pure_q2_k {q2['kl']}")
        if q6 is not None and _num(rby):
            if not _num(q6.get("model_bytes")):
                bad.append("q6_k-anchor-bytes-missing")
            elif rby >= q6["model_bytes"]:
                bad.append(f"bytes {rby} >= pure_q6_k "
                           f"{q6['model_bytes']}")
        fails += len(bad)
        print(f"{'OK ' if not bad else 'FAIL'} {tag} kl "
              f"{_fmt(rkl, '.4f')} vs default {_fmt(bkl, '.4f')}, bytes "
              f"{_fmt(rby, 'd') if _num(rby) else '--'} vs default "
              f"{_fmt(bby, 'd') if _num(bby) else '--'}"
              + (f" [{'; '.join(bad)}]" if bad else ""))
    if fails:
        print(f"REGRESSION: auto policy stopped dominating "
              f"default_serve_mix ({fails} structural failure(s))")
    return fails


_TRACE_REQUIRED = ("ttft_p50_s", "ttft_p99_s", "itl_p50_s", "itl_p99_s",
                   "goodput_frac")


def check_trace(new: dict, baseline: dict, tol_ttft: float,
                goodput_drop: float) -> int:
    """Gate for trace_serve JSONs. Cross-run, matched on
    (mix, rate_rps, params) against the committed baseline:

      * tail TTFT   -- fail if new ttft_p99_s > (1 + tol_ttft) * baseline
      * goodput     -- fail if new goodput_frac < baseline - goodput_drop
                       (absolute fraction: SLO-conditioned goodput is a
                       ratio in [0, 1], so a fractional tolerance would
                       explode near zero)

    A metric absent from (or null in) the BASELINE row skips that gate;
    a metric the baseline has that the new run dropped fails (reporting
    regression) -- same contract as ``compare``. Same-run structural
    checks ride along for every new row regardless of baseline pairing:

      * required tail stats present and non-null (_TRACE_REQUIRED),
        requests > 0, p99 >= p50 >= 0
      * the arrival-time accounting contract: arrival-stamped TTFT
        percentiles must not exceed the run-entry-stamped percentiles
        recorded alongside them (run() entry always precedes a mid-cycle
        arrival, so the fixed stamp can only shrink TTFT)
      * the summary must report a saturation_rps per swept mix
    """
    base_by_key = {(r.get("mix"), r.get("rate_rps"), r.get("params")): r
                   for r in baseline.get("runs", [])}
    failures, compared = 0, 0
    for r in new.get("runs", []):
        key = (r.get("mix"), r.get("rate_rps"), r.get("params"))
        tag = f"{key[2]:>18} {key[0]:>5} @{key[1]:g} rps"
        bad = []
        # --- same-run structural checks (no baseline needed) ---
        for f in _TRACE_REQUIRED:
            v = r.get(f)
            if isinstance(v, bool) or not isinstance(v, (int, float)):
                bad.append(f"{f}-missing")
        if not isinstance(r.get("requests"), int) or r["requests"] <= 0:
            bad.append("requests<=0")
        p50, p99 = r.get("ttft_p50_s"), r.get("ttft_p99_s")
        if isinstance(p50, float) and isinstance(p99, float):
            if not (0.0 <= p50 <= p99):
                bad.append("ttft-percentiles-disordered")
            # the bugfix contract, structurally: arrival-stamped tails
            # can only be <= the run-entry-stamped tails (percentiles of
            # pointwise-dominated samples, tolerance for rounding)
            o50 = r.get("ttft_runentry_p50_s")
            o99 = r.get("ttft_runentry_p99_s")
            if isinstance(o50, float) and p50 > o50 + 1e-5:
                bad.append(f"ttft_p50 {p50} > runentry {o50}")
            if isinstance(o99, float) and p99 > o99 + 1e-5:
                bad.append(f"ttft_p99 {p99} > runentry {o99}")
        # --- cross-run gates vs the committed baseline ---
        b = base_by_key.get(key)
        t_ceil = g_floor = None
        if b is not None:
            compared += 1
            btt = b.get("ttft_p99_s")
            if isinstance(btt, (int, float)) and btt > 0:
                t_ceil = (1.0 + tol_ttft) * btt
                if not isinstance(p99, float):
                    bad.append("ttft_p99-dropped")
                elif p99 > t_ceil:
                    bad.append("ttft_p99")
            bg = b.get("goodput_frac")
            rg = r.get("goodput_frac")
            if isinstance(bg, (int, float)):
                g_floor = bg - goodput_drop
                if not isinstance(rg, (int, float)):
                    bad.append("goodput-dropped")
                elif rg < g_floor:
                    bad.append("goodput")
        failures += len(bad)
        print(f"{'OK ' if not bad else 'FAIL'} {tag} ttft_p99 "
              f"{_fmt(p99, '.5f')} vs {_fmt(b.get('ttft_p99_s') if b else None, '.5f')} "
              f"(ceil {_fmt(t_ceil, '.5f')}) | goodput "
              f"{_fmt(r.get('goodput_frac'), '.3f')} vs "
              f"{_fmt(b.get('goodput_frac') if b else None, '.3f')} "
              f"(floor {_fmt(g_floor, '.3f')}) | itl_p99 "
              f"{_fmt(r.get('itl_p99_s'), '.6f')}"
              + (f" [{'; '.join(bad)}]" if bad else ""))
    for mix in new.get("workload", {}).get("mixes", {}):
        s = new.get("summary", {}).get(mix, {})
        if not isinstance(s.get("saturation_rps"), (int, float)):
            failures += 1
            print(f"FAIL summary[{mix}]: saturation_rps missing")
        else:
            print(f"OK  summary[{mix}] saturation_rps "
                  f"{s['saturation_rps']:g} (met {s.get('rates_met')})")
    if compared == 0:
        print("ERROR: no (mix, rate_rps, params) rows in common with "
              "the baseline -- wrong file?")
        return 2
    if failures:
        print(f"REGRESSION: trace gate failed ({failures} failure(s); "
              f"ttft_p99 ceiling +{tol_ttft:.0%}, goodput floor "
              f"-{goodput_drop:.2f} absolute)")
        return 1
    print(f"all {compared} compared trace rows within tolerance "
          f"(ttft_p99 +{tol_ttft:.0%}, goodput -{goodput_drop:.2f})")
    return 0


def compare(new: dict, baseline: dict, tol: float, tol_prefill: float,
            tol_ttft: float) -> int:
    base_by_key = {(r["params"], r["queue_depth"]): r
                   for r in baseline.get("runs", [])}
    failures, compared = [], 0
    for r in new.get("runs", []):
        key = (r["params"], r["queue_depth"])
        b = base_by_key.get(key)
        if b is None:
            continue
        compared += 1
        bad = []
        # a metric absent from the BASELINE (or null -- hand-edited
        # baselines carry explicit nulls) skips that gate instead of
        # crashing (old baselines predate some metrics; rows only a
        # richer sweep produces -- e.g. the multi-device tensor-parallel
        # rows -- are already handled by the pair matching above). A
        # metric the baseline HAS but the new run LACKS is a reporting
        # regression and fails: every engine row is expected to keep
        # emitting tok_per_s/prefill_tok_per_s/ttft_s. Floors/ceilings
        # are computed only AFTER the presence check -- arithmetic on a
        # null baseline metric is exactly the TypeError this gate must
        # never die of.
        bt, rt = b.get("tok_per_s"), r.get("tok_per_s")
        floor = None
        if bt is not None:
            floor = (1.0 - tol) * bt
            if rt is None or rt < floor:
                bad.append("decode" if rt is not None else "decode-missing")
        bp, rp = b.get("prefill_tok_per_s"), r.get("prefill_tok_per_s")
        p_floor = None
        if bp is not None:
            p_floor = (1.0 - tol_prefill) * bp
            if rp is None or rp < p_floor:
                bad.append("prefill" if rp is not None
                           else "prefill-missing")
        btt, rtt = b.get("ttft_s"), r.get("ttft_s")
        t_ceil = None
        if btt is not None and btt > 0:
            t_ceil = (1.0 + tol_ttft) * btt
            if rtt is None or rtt > t_ceil:
                bad.append("ttft" if rtt is not None else "ttft-missing")
        # tail TTFT (p99 over the depth's requests) rides the same
        # growth ceiling; baselines predating the percentile stats skip
        bt99, rt99 = b.get("ttft_p99_s"), r.get("ttft_p99_s")
        if bt99 is not None and bt99 > 0:
            if rt99 is None or rt99 > (1.0 + tol_ttft) * bt99:
                bad.append("ttft_p99" if rt99 is not None
                           else "ttft_p99-missing")
        # prefix rows: the radix tree must actually hit on the
        # shared-system-prompt workload -- a structural gate (hit rate is
        # deterministic for this workload), not a wall-clock one
        if (b.get("prefix_hit_rate") or 0) > 0 and \
                (r.get("prefix_hit_rate") or 0) <= 0:
            bad.append("prefix_hit_rate")
        status = "OK " if not bad else "FAIL"
        accept = (f" accept_rate {_fmt(r.get('accept_rate'), '.2f')} vs "
                  f"{_fmt(b.get('accept_rate'), '.2f')}"
                  if "accept_rate" in r else "")
        if "prefix_hit_rate" in r:
            accept += (f" prefix_hit_rate "
                       f"{_fmt(r.get('prefix_hit_rate'), '.2f')} vs "
                       f"{_fmt(b.get('prefix_hit_rate'), '.2f')}")
        print(f"{status} {key[0]:>26} d{key[1]:<3} decode tok/s "
              f"{_fmt(rt)} vs {_fmt(bt)} "
              f"(floor {_fmt(floor, '.1f')}) | prefill tok/s "
              f"{_fmt(rp)} vs {_fmt(bp)} "
              f"(floor {_fmt(p_floor, '.1f')}) | ttft_s "
              f"{_fmt(rtt, '.5f')} vs {_fmt(btt, '.5f')} "
              f"(ceil {_fmt(t_ceil, '.5f')}){accept}")
        if bad:
            failures.append((key, tuple(bad)))
    if compared == 0:
        print("ERROR: no (params, queue_depth) pairs in common with the "
              "baseline -- wrong file?")
        return 2
    tp_fails = check_tp_sliced(new)
    disagg_fails = check_disagg(new)
    recurrent_fails = check_recurrent_prefill(new)
    policy_fails = check_policy_auto(new)
    if failures or tp_fails or disagg_fails or recurrent_fails \
            or policy_fails:
        if failures:
            print(f"REGRESSION: {failures} exceeded tolerances "
                  f"(decode {tol:.0%}, prefill {tol_prefill:.0%}, "
                  f"ttft +{tol_ttft:.0%})")
        return 1
    print(f"all {compared} compared runs within tolerance "
          f"(decode {tol:.0%}, prefill {tol_prefill:.0%}, "
          f"ttft +{tol_ttft:.0%})")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--new", required=True, help="freshly produced JSON")
    ap.add_argument("--baseline", required=True,
                    help="committed baseline JSON")
    ap.add_argument("--tol", type=float, default=0.20,
                    help="allowed fractional decode tok/s drop (0.20)")
    ap.add_argument("--tol-prefill", type=float, default=0.60,
                    help="allowed fractional prefill tok/s drop (0.60; "
                         "prefill wall-clock is dispatch-noisy at smoke "
                         "shapes and swings hard on shared runners)")
    ap.add_argument("--tol-ttft", type=float, default=2.00,
                    help="allowed fractional time-to-first-token GROWTH "
                         "(2.00, i.e. 3x; ttft is the noisiest metric -- "
                         "the gate exists to catch structural "
                         "regressions like losing batched admission)")
    ap.add_argument("--goodput-drop", type=float, default=0.25,
                    help="allowed ABSOLUTE goodput-fraction drop for "
                         "trace_serve gates (0.25; goodput is a ratio "
                         "in [0,1], fractional tolerances explode near "
                         "zero)")
    args = ap.parse_args()
    with open(args.new) as f:
        new = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)
    if new.get("benchmark") == "trace_serve":
        if baseline.get("benchmark") != "trace_serve":
            print("ERROR: --new is a trace_serve JSON but --baseline "
                  "is not")
            return 2
        return check_trace(new, baseline, args.tol_ttft,
                           args.goodput_drop)
    return compare(new, baseline, args.tol, args.tol_prefill,
                   args.tol_ttft)


if __name__ == "__main__":
    sys.exit(main())
