#!/usr/bin/env python
"""Regenerate the generated tables inside EXPERIMENTS.md from results/*.

  PYTHONPATH=src python scripts/update_experiments.py
"""
import json
import os
import re
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.summarize import load, dryrun_table, roofline_table  # noqa

ROOT = os.path.join(os.path.dirname(__file__), "..")


def replace_block(text: str, marker: str, content: str) -> str:
    """Replace '<!-- marker -->' (and any previously generated block that
    follows it up to the next '## ' or '### ' heading) with content."""
    pat = re.compile(rf"(<!-- {marker} -->)(.*?)(?=\n##|\n###|\Z)",
                     re.DOTALL)
    return pat.sub(lambda m: f"<!-- {marker} -->\n{content}\n", text)


def main() -> None:
    path = os.path.join(ROOT, "EXPERIMENTS.md")
    text = open(path).read()

    single = os.path.join(ROOT, "results", "dryrun_single")
    multi = os.path.join(ROOT, "results", "dryrun_multi")
    recs = []
    if os.path.isdir(single):
        recs += load(single)
    if os.path.isdir(multi):
        recs += load(multi)
    if recs:
        text = replace_block(text, "DRYRUN_TABLE", dryrun_table(recs))
        text = replace_block(text, "ROOFLINE_TABLE", roofline_table(recs))

    open(path, "w").write(text)
    print(f"updated {path} with {len(recs)} records")


if __name__ == "__main__":
    main()
