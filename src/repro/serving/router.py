"""KV-aware request router for disaggregated prefill/decode serving.

The router sits in front of N prefill-worker and M decode-worker engine
instances (serving/disagg.py) and answers two questions per request:

* **which prefill worker?** -- score the prompt against every prefill
  worker's prefix-cache radix tree (``Engine.prefix_match_len``: pure
  host state, no LRU side effects) and route to the worker with the
  longest cached prefix, so cluster-wide prefix reuse concentrates where
  the KV already lives (the vLLM/triton-distributed kv_router idea,
  in-process). Ties break to the shallowest queue, then the lowest
  worker index -- deterministic, which is what lets the parity suite pin
  routed output token-for-token.
* **which decode worker?** -- least outstanding requests, ties to the
  lowest index. Decode placement needs no KV affinity: the migrated
  pages travel WITH the request (``export_kv_pages``/``import_kv_pages``),
  so any decode worker is equally warm by the time it admits.

The router also owns the observability the tentpole asks for: per-worker
request counts and overlap-hit rates, migrated page counts, and queue
depths (live + peak), snapshot()-able into engine stats and the serving
benchmark rows.
"""
from __future__ import annotations

from typing import Dict, List, Sequence


class KVRouter:
    """Host-side scoring and bookkeeping. The router never touches device
    state: migration itself is the DisaggEngine's job (it owns the
    export/import calls); the router only decides placement and counts
    what happened."""

    def __init__(self, prefill_workers: Sequence, decode_workers: Sequence):
        if not prefill_workers or not decode_workers:
            raise ValueError("router needs >= 1 prefill and >= 1 decode "
                             "worker")
        self._pw = list(prefill_workers)
        self._dw = list(decode_workers)
        nP, nD = len(self._pw), len(self._dw)
        # live queue depths (outstanding requests per worker) + peaks
        self._p_depth = [0] * nP
        self._d_depth = [0] * nD
        self._p_peak = [0] * nP
        self._d_peak = [0] * nD
        # lifetime counters
        self.prefill_requests = [0] * nP
        self.prefill_overlap_hits = [0] * nP
        self.prefill_overlap_tokens = [0] * nP
        self.decode_requests = [0] * nD
        self.migrated_pages = [0] * nD
        self.direct_decode = 0          # requests too small to page
        # double-done / done-without-pick calls used to drive a depth
        # negative and bias least-loaded placement toward that worker
        # forever after; they now clamp at 0 and count here
        self.depth_underflows = 0

    # -- placement ----------------------------------------------------------
    def pick_prefill(self, prompt: List[int]) -> int:
        """Route a prompt to the prefill worker with maximal radix-tree
        overlap (ties: shallowest queue, then lowest index)."""
        scores = [w.prefix_match_len(prompt) for w in self._pw]
        best = max(range(len(self._pw)),
                   key=lambda i: (scores[i], -self._p_depth[i], -i))
        self.prefill_requests[best] += 1
        if scores[best] > 0:
            self.prefill_overlap_hits[best] += 1
            self.prefill_overlap_tokens[best] += scores[best]
        self._p_depth[best] += 1
        self._p_peak[best] = max(self._p_peak[best], self._p_depth[best])
        return best

    def pick_decode(self) -> int:
        """Least-loaded decode worker (ties: lowest index)."""
        best = max(range(len(self._dw)),
                   key=lambda i: (-self._d_depth[i], -i))
        self.decode_requests[best] += 1
        self._d_depth[best] += 1
        self._d_peak[best] = max(self._d_peak[best], self._d_depth[best])
        return best

    # -- bookkeeping --------------------------------------------------------
    def note_prefill_done(self, worker: int) -> None:
        """Mark one outstanding prefill finished. A depth can never go
        below zero: a stray extra done (double-done, or done without a
        matching pick) would otherwise make that worker look permanently
        shallower than it is, silently corrupting every future
        least-loaded tie-break. Clamp and count instead."""
        if self._p_depth[worker] <= 0:
            self.depth_underflows += 1
            self._p_depth[worker] = 0
            return
        self._p_depth[worker] -= 1

    def note_decode_done(self, worker: int) -> None:
        """Decode twin of note_prefill_done (same clamp rationale)."""
        if self._d_depth[worker] <= 0:
            self.depth_underflows += 1
            self._d_depth[worker] = 0
            return
        self._d_depth[worker] -= 1

    def note_migrated(self, worker: int, n_pages: int) -> None:
        self.migrated_pages[worker] += n_pages

    def note_direct_decode(self) -> None:
        self.direct_decode += 1

    # -- observability ------------------------------------------------------
    def snapshot(self) -> Dict[str, object]:
        """Router stats for engine stats / benchmark rows: per-worker
        request counts, overlap-hit rates, migrated pages, and queue
        depths (live + peak)."""
        rate = lambda h, n: round(h / n, 4) if n else 0.0
        return dict(
            prefill_workers=len(self._pw),
            decode_workers=len(self._dw),
            prefill_requests=list(self.prefill_requests),
            prefill_overlap_hits=list(self.prefill_overlap_hits),
            prefill_overlap_tokens=list(self.prefill_overlap_tokens),
            prefill_hit_rate=[rate(h, n) for h, n in
                              zip(self.prefill_overlap_hits,
                                  self.prefill_requests)],
            decode_requests=list(self.decode_requests),
            migrated_pages=list(self.migrated_pages),
            migrated_pages_total=sum(self.migrated_pages),
            direct_decode=self.direct_decode,
            depth_underflows=self.depth_underflows,
            prefill_queue_depth=list(self._p_depth),
            decode_queue_depth=list(self._d_depth),
            prefill_peak_depth=list(self._p_peak),
            decode_peak_depth=list(self._d_peak),
        )
