"""Host-side radix tree over token-ID prefixes for the paged KV prefix
cache (SGLang-style RadixAttention, adapted to this engine's contiguous
slot rings).

The device side is a fixed-capacity page pool (``transformer.
cache_page_pool``): every cached page is ``page`` consecutive positions'
worth of KV rows (all layers, int8 scales included), copied bit-for-bit
out of a freshly prefilled group cache and copied back into a later
request's ring at admission. Because slot caches receive page COPIES
(gather -> scatter, never aliases), attention kernels are untouched and
greedy output stays token-identical to a cold prefill.

This module owns everything host-side:

* the radix tree: one node per page, keyed by that page's token tuple,
  so a lookup descends page by page along the longest cached prefix.
  Position is implicit (a node at depth d covers positions
  [d*page, (d+1)*page)) -- prefixes always start at position 0.
* partial-page hits: when the longest match ends mid-page, the best
  child's leading rows are still reusable (``take < page``); the engine
  scatters just those rows and recomputes the divergent tail --
  copy-on-write at row granularity (the pool page is never mutated).
* refcounts + LRU eviction: a node's refcount is its child count, so
  only childless nodes (tree leaves) are evictable; under pool-capacity
  pressure the least-recently-touched evictable leaf is freed. Evicting
  never breaks an in-flight admission: matched pages are device-copied
  before any insertion can evict them.
* the byte budget: capacity is ``prefix_bytes // cache_page_bytes``,
  fixed at engine construction, so device memory for the pool is bounded
  and allocated once.

Matching is capped at ``len(tokens) - 1``: the last prompt token always
recomputes, because its logits seed the first sampled token (the same
rule vLLM/SGLang apply).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple


class _Node:
    __slots__ = ("key", "page_idx", "children", "parent", "stamp")

    def __init__(self, key: Tuple[int, ...], page_idx: int,
                 parent: "_Node"):
        self.key = key                  # this page's token ids
        self.page_idx = page_idx        # row in the device page pool
        self.children: Dict[Tuple[int, ...], _Node] = {}
        self.parent = parent
        self.stamp = 0                  # LRU clock value at last touch

    @property
    def refcount(self) -> int:
        return len(self.children)


class PrefixCache:
    """Radix tree + page-pool accounting. Pure host state: device copies
    are the engine's job (it owns the pool arrays)."""

    def __init__(self, page: int, capacity: int):
        if page < 1:
            raise ValueError(f"page must be >= 1, got {page}")
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.page = page
        self.capacity = capacity
        self._root = _Node((), -1, None)
        self._free: List[int] = list(range(capacity - 1, -1, -1))
        self._clock = 0
        self.evictions = 0              # lifetime counter
        self.insert_drops = 0           # lifetime counter: full pages an
                                        # insert() dropped because the pool
                                        # was exhausted and nothing was
                                        # evictable (saturated-pool signal)

    # -- introspection ------------------------------------------------------
    @property
    def pages_in_use(self) -> int:
        return self.capacity - len(self._free)

    def _touch(self, node: _Node) -> None:
        self._clock += 1
        node.stamp = self._clock

    # -- lookup -------------------------------------------------------------
    def match(self, tokens: List[int]) -> Tuple[int, List[Tuple[int, int, int]]]:
        """Longest cached prefix of ``tokens``, capped at len(tokens)-1.

        Returns (matched_len, pages) with pages a list of
        (pool_idx, start_pos, take): ``take == page`` for full pages, and
        at most one trailing partial page (``take < page``) when the
        match ends inside a cached page. Touches every matched node's LRU
        stamp."""
        page = self.page
        cap = len(tokens) - 1
        node = self._root
        pages: List[Tuple[int, int, int]] = []
        m = 0
        while m + page <= cap:
            child = node.children.get(tuple(tokens[m:m + page]))
            if child is None:
                break
            self._touch(child)
            pages.append((child.page_idx, m, page))
            node = child
            m += page
        # partial-page hit: longest common prefix with any child's page
        want = tokens[m:min(m + page, cap)]
        best_r, best_child = 0, None
        for key, child in node.children.items():
            r = 0
            for a, b in zip(key, want):
                if a != b:
                    break
                r += 1
            if r > best_r:
                best_r, best_child = r, child
        if best_child is not None:
            self._touch(best_child)
            pages.append((best_child.page_idx, m, best_r))
            m += best_r
        return m, pages

    def match_len(self, tokens: List[int]) -> int:
        """Overlap score for router probes: the length ``match`` would
        return, WITHOUT touching LRU stamps -- a router scoring one
        request against every worker's tree must not distort the eviction
        order of the workers it does not pick."""
        page = self.page
        cap = len(tokens) - 1
        node = self._root
        m = 0
        while m + page <= cap:
            child = node.children.get(tuple(tokens[m:m + page]))
            if child is None:
                break
            node = child
            m += page
        want = tokens[m:min(m + page, cap)]
        best_r = 0
        for key in node.children:
            r = 0
            for a, b in zip(key, want):
                if a != b:
                    break
                r += 1
            best_r = max(best_r, r)
        return m + best_r

    def page_chain(self, tokens: List[int]) -> List[Tuple[int, int]]:
        """The FULL-page chain cached for ``tokens``: [(pool_idx,
        start_pos), ...] for every whole page resident from position 0,
        stopping at the first miss. Unlike ``match`` there is no len-1
        cap and no partial-page entry -- this is the export granularity
        for cross-engine KV hand-off (pool pages only exist whole).
        Touches LRU stamps: an exported page was genuinely used."""
        page = self.page
        node = self._root
        chain: List[Tuple[int, int]] = []
        m = 0
        while m + page <= len(tokens):
            child = node.children.get(tuple(tokens[m:m + page]))
            if child is None:
                break
            self._touch(child)
            chain.append((child.page_idx, m))
            node = child
            m += page
        return chain

    # -- insertion / eviction ------------------------------------------------
    def _evict_one(self, protect: set) -> Optional[int]:
        """Free the least-recently-touched childless node not in
        ``protect`` (the current insertion batch's paths). Returns its
        pool index, or None if nothing is evictable. The DFS is
        O(pages_in_use) host-side python; it only runs once the pool is
        full and per page actually allocated, and the pool capacity is
        bounded by the byte budget -- negligible next to the device
        prefill it rides behind."""
        victim = None
        stack = list(self._root.children.values())
        while stack:
            n = stack.pop()
            if n.children:
                stack.extend(n.children.values())
            elif id(n) not in protect and (victim is None
                                           or n.stamp < victim.stamp):
                victim = n
        if victim is None:
            return None
        del victim.parent.children[victim.key]
        self.evictions += 1
        return victim.page_idx

    def _alloc(self, protect: set) -> Optional[int]:
        if self._free:
            return self._free.pop()
        return self._evict_one(protect)

    def insert(self, tokens: List[int],
               protect: Optional[set] = None) -> List[Tuple[int, int]]:
        """Record ``tokens``'s full pages, allocating pool rows for pages
        not already cached (evicting LRU leaves under capacity pressure).
        Returns [(pool_idx, start_pos), ...] for the NEW pages -- the
        engine must copy those rows out of its freshly prefilled cache.
        Stops early (dropping the tail) if the pool is exhausted and
        nothing is evictable; the dropped page count accumulates in
        ``insert_drops`` so saturated pools are diagnosable. Matched
        pages are LRU-touched, so a re-hit after eviction re-inserts and
        re-ranks naturally.

        ``protect``: nodes eviction must not free. The caller batching
        SEVERAL insertions into one device copy passes a shared set so a
        later insertion can never evict (and recycle the pool index of) a
        page an earlier insertion in the same batch just allocated --
        duplicate destinations in one batched scatter are undefined in
        XLA. Each call adds its own path to the set."""
        page = self.page
        node = self._root
        path: set = set() if protect is None else protect
        new: List[Tuple[int, int]] = []
        for q in range(len(tokens) // page):
            key = tuple(tokens[q * page:(q + 1) * page])
            child = node.children.get(key)
            if child is None:
                idx = self._alloc(path)
                if idx is None:
                    self.insert_drops += len(tokens) // page - q
                    break
                child = _Node(key, idx, node)
                node.children[key] = child
                new.append((idx, q * page))
            self._touch(child)
            path.add(id(child))
            node = child
        return new

    def clear(self) -> None:
        self._root = _Node((), -1, None)
        self._free = list(range(self.capacity - 1, -1, -1))
