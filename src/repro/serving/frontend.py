"""Streaming async HTTP front-end over the serving engine.

OpenAI-compatible ``POST /v1/completions`` on top of the existing
``submit()/on_token`` engine API -- the missing piece between "an engine
that drains a queue" and "a service that takes traffic" (ROADMAP item 4).
Stdlib only: the HTTP layer is a hand-rolled HTTP/1.1 parser on
``asyncio.start_server`` (the container mounts no web framework), and no
tokenizer is mounted either, so ``prompt`` is a list of token ids --
which the OpenAI completions schema legitimately allows.

Threading model. The engine is single-threaded and blocking (``run()``
owns the device), so the front-end runs THREE cooperating parties:

* the **engine thread**: blocks on an inbox ``queue.SimpleQueue`` while
  idle; on any command it drains the inbox and calls
  ``engine.run(poll=...)``, where ``poll`` re-drains the inbox every
  scheduler iteration -- mid-cycle arrivals and cancellations land
  between decode chunks without the engine ever knowing about threads.
* the **asyncio loop thread**: owns the listening socket and all client
  connections. Handlers never touch the engine directly; they enqueue
  ``("submit", ...)`` / ``("cancel", rid)`` commands and await their
  per-request ``asyncio.Queue``, which engine-side callbacks feed via
  ``loop.call_soon_threadsafe`` (the only cross-thread hop).
* the **caller's thread**: ``start()`` / ``close()`` lifecycle.

Per-request SLO surface: ``priority`` and ``deadline_s`` pass straight
through to ``Engine.submit``; ``timeout_s`` (default
``FrontendConfig.request_timeout_s``) is enforced on the engine thread --
an overdue request is cancelled through the ordinary ``cancel()``
machinery and finishes with ``finish_reason: "timeout"``, keeping the
tokens it already streamed. A client disconnect mid-stream cancels the
same way. ``EngineSaturated`` (bounded queue / saturated page pool) maps
to HTTP 429 with the machine-readable reason in the body.
"""
from __future__ import annotations

import asyncio
import dataclasses
import heapq
import json
import queue as queue_mod
import threading
import time
from typing import Any, Dict, List, Optional

from repro.serving.engine import EngineSaturated

_JSON = "application/json"


@dataclasses.dataclass
class FrontendConfig:
    host: str = "127.0.0.1"
    port: int = 0                       # 0 -> ephemeral (read .port after
                                        # start(); what the tests use)
    model_name: str = "repro"           # echoed in completion payloads
    request_timeout_s: float = 120.0    # per-request wall ceiling
                                        # (overridable per request)
    idle_wait_s: float = 0.02           # engine-thread inbox block while
                                        # the engine is idle
    max_tokens_default: int = 16


class _Pending:
    """Async-side handle for one in-flight completion. The engine thread
    posts ("rid"|"tok"|"done"|"err", payload) events into ``q`` via
    call_soon_threadsafe; flags written on the engine thread before the
    terminal event are read by the handler after it (happens-before via
    the queue hop)."""

    def __init__(self, loop: asyncio.AbstractEventLoop):
        self.loop = loop
        self.q: asyncio.Queue = asyncio.Queue()
        self.rid: Optional[int] = None
        self.timed_out = False

    def post(self, kind: str, payload: Any = None) -> None:
        self.loop.call_soon_threadsafe(self.q.put_nowait, (kind, payload))


class Frontend:
    """HTTP front-end over an ``Engine`` (or ``DisaggEngine``: anything
    with submit/cancel/run/stats and the SLO submit fields)."""

    def __init__(self, engine, fcfg: Optional[FrontendConfig] = None):
        self.engine = engine
        self.fcfg = fcfg or FrontendConfig()
        self.port: Optional[int] = None
        self.stats: Dict[str, int] = dict(
            http_requests=0, completions=0, rejected=0, timeouts=0,
            disconnects=0, streamed_tokens=0)
        self._inbox: queue_mod.SimpleQueue = queue_mod.SimpleQueue()
        self._timeouts: List = []       # heap of (wall_deadline, rid, pend)
        self._shutdown = threading.Event()
        self._started = threading.Event()
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._server = None
        self._loop_thread = threading.Thread(
            target=self._loop_main, name="frontend-http", daemon=True)
        self._engine_thread = threading.Thread(
            target=self._engine_main, name="frontend-engine", daemon=True)

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "Frontend":
        self._loop_thread.start()
        if not self._started.wait(timeout=10):
            raise RuntimeError("HTTP front-end failed to start listening")
        self._engine_thread.start()
        return self

    def close(self) -> None:
        self._shutdown.set()
        self._inbox.put(("wake", None))
        self._engine_thread.join(timeout=30)
        if self._loop is not None:
            self._loop.call_soon_threadsafe(self._stop_loop)
        self._loop_thread.join(timeout=10)

    def _stop_loop(self) -> None:
        if self._server is not None:
            self._server.close()
        self._loop.stop()

    def _loop_main(self) -> None:
        self._loop = asyncio.new_event_loop()
        asyncio.set_event_loop(self._loop)

        async def boot():
            self._server = await asyncio.start_server(
                self._serve_conn, self.fcfg.host, self.fcfg.port)
            self.port = self._server.sockets[0].getsockname()[1]

        self._loop.run_until_complete(boot())
        self._started.set()
        try:
            self._loop.run_forever()
        finally:
            self._loop.close()

    # -- engine thread -------------------------------------------------------
    def _engine_main(self) -> None:
        while not self._shutdown.is_set():
            try:
                item = self._inbox.get(timeout=self.fcfg.idle_wait_s)
            except queue_mod.Empty:
                self._check_timeouts()
                continue
            self._apply(item)
            self._drain_inbox()
            if self._shutdown.is_set():
                break
            # run() returns once queue + slots drain; poll keeps feeding
            # it mid-cycle arrivals until then
            self.engine.run(poll=self._poll)

    def _poll(self) -> None:
        self._drain_inbox()
        self._check_timeouts()

    def _drain_inbox(self) -> None:
        while True:
            try:
                self._apply(self._inbox.get_nowait())
            except queue_mod.Empty:
                return

    def _apply(self, item) -> None:
        kind, payload = item
        if kind == "submit":
            self._apply_submit(payload)
        elif kind == "cancel":
            self.engine.cancel(payload)

    def _apply_submit(self, spec: Dict[str, Any]) -> None:
        pend: _Pending = spec["pending"]

        def on_token(_rid: int, tok: int) -> None:
            self.stats["streamed_tokens"] += 1
            pend.post("tok", tok)

        def on_done(req) -> None:
            pend.post("done", dict(
                tokens=list(req.tokens), cancelled=req.cancelled,
                preempted=req.preempted, ttft_s=req.ttft_s,
                queue_wait_s=req.queue_wait_s,
                deadline_missed=req.deadline_missed))

        try:
            rid = self.engine.submit(
                spec["prompt"], max_new_tokens=spec["max_tokens"],
                on_token=on_token, priority=spec["priority"],
                deadline_s=spec["deadline_s"], on_done=on_done)
        except (EngineSaturated, ValueError) as e:
            pend.post("err", e)
            return
        pend.rid = rid
        if spec["timeout_s"] is not None:
            heapq.heappush(self._timeouts,
                           (time.perf_counter() + spec["timeout_s"],
                            rid, pend))
        pend.post("rid", rid)

    def _check_timeouts(self) -> None:
        now = time.perf_counter()
        while self._timeouts and self._timeouts[0][0] <= now:
            _, rid, pend = heapq.heappop(self._timeouts)
            # the flag must be visible before cancel() fires on_done (the
            # handler reads it after the done event); reset on a failed
            # cancel so a request that finished just under the wire is
            # not mislabeled "timeout"
            pend.timed_out = True
            if self.engine.cancel(rid):
                self.stats["timeouts"] += 1
            else:
                pend.timed_out = False

    # -- HTTP layer (asyncio loop thread) ------------------------------------
    async def _serve_conn(self, reader: asyncio.StreamReader,
                          writer: asyncio.StreamWriter) -> None:
        try:
            while True:
                reqline = await reader.readline()
                if not reqline or reqline in (b"\r\n", b"\n"):
                    break
                try:
                    method, path, _ = reqline.decode("latin-1").split()
                except ValueError:
                    break
                headers: Dict[str, str] = {}
                while True:
                    line = await reader.readline()
                    if line in (b"\r\n", b"\n", b""):
                        break
                    k, _, v = line.decode("latin-1").partition(":")
                    headers[k.strip().lower()] = v.strip()
                body = b""
                n = int(headers.get("content-length", "0") or 0)
                if n:
                    body = await reader.readexactly(n)
                self.stats["http_requests"] += 1
                keep = await self._route(method, path, body, reader,
                                         writer)
                if not keep or headers.get("connection", "") == "close":
                    break
            await writer.drain()
        except (ConnectionError, asyncio.IncompleteReadError,
                BrokenPipeError, asyncio.TimeoutError):
            pass
        finally:
            try:
                writer.close()
            except Exception:
                pass

    async def _route(self, method: str, path: str, body: bytes,
                     reader, writer) -> bool:
        """Dispatch one request; returns False when the connection must
        close (streaming responses end the connection)."""
        if method == "POST" and path == "/v1/completions":
            return await self._completions(body, reader, writer)
        if method == "GET" and path == "/health":
            self._respond(writer, 200, dict(
                status="ok", model=self.fcfg.model_name,
                queue_depth=len(getattr(self.engine, "_queue", ()))))
            return True
        if method == "GET" and path == "/v1/models":
            self._respond(writer, 200, dict(
                object="list",
                data=[dict(id=self.fcfg.model_name, object="model",
                           owned_by="repro")]))
            return True
        if method == "GET" and path == "/stats":
            self._respond(writer, 200, dict(
                frontend=dict(self.stats),
                engine={k: v for k, v in self.engine.stats.items()
                        if not isinstance(v, dict)}))
            return True
        self._respond(writer, 404, dict(error=dict(
            message=f"no route for {method} {path}", type="not_found")))
        return True

    async def _completions(self, body: bytes, reader, writer) -> bool:
        try:
            payload = json.loads(body or b"{}")
        except json.JSONDecodeError:
            self._respond(writer, 400, _err("body is not valid JSON",
                                            "invalid_request_error"))
            return True
        prompt = payload.get("prompt")
        if (not isinstance(prompt, list) or not prompt
                or not all(isinstance(t, int) and not isinstance(t, bool)
                           for t in prompt)):
            self._respond(writer, 400, _err(
                "prompt must be a non-empty list of token ids (no "
                "tokenizer is mounted; the OpenAI completions schema "
                "allows token-id prompts)", "invalid_request_error"))
            return True
        try:
            max_tokens = int(payload.get("max_tokens",
                                         self.fcfg.max_tokens_default))
            priority = int(payload.get("priority", 0))
            deadline_s = payload.get("deadline_s")
            deadline_s = None if deadline_s is None else float(deadline_s)
            timeout_s = payload.get("timeout_s",
                                    self.fcfg.request_timeout_s)
            timeout_s = None if timeout_s is None else float(timeout_s)
            stream = bool(payload.get("stream", False))
        except (TypeError, ValueError):
            self._respond(writer, 400, _err(
                "max_tokens/priority/deadline_s/timeout_s must be numbers",
                "invalid_request_error"))
            return True

        pend = _Pending(asyncio.get_running_loop())
        self._inbox.put(("submit", dict(
            prompt=list(prompt), max_tokens=max_tokens, priority=priority,
            deadline_s=deadline_s, timeout_s=timeout_s, pending=pend)))
        # generous hard ceiling so a wedged engine can't hang the handler
        wait_s = (timeout_s or self.fcfg.request_timeout_s) + 60.0
        kind, payload0 = await asyncio.wait_for(pend.q.get(), wait_s)
        if kind == "err":
            exc = payload0
            if isinstance(exc, EngineSaturated):
                self.stats["rejected"] += 1
                self._respond(writer, 429, dict(error=dict(
                    message=str(exc), type="engine_saturated",
                    reason=exc.reason, detail=exc.detail)))
            else:
                self._respond(writer, 400, _err(str(exc),
                                                "invalid_request_error"))
            return True
        assert kind == "rid", kind
        rid = payload0
        if stream:
            return await self._stream_response(rid, len(prompt), pend,
                                               reader, writer, wait_s)
        return await self._plain_response(rid, len(prompt), pend, writer,
                                          wait_s)

    async def _plain_response(self, rid: int, n_prompt: int,
                              pend: _Pending, writer,
                              wait_s: float) -> bool:
        toks: List[int] = []
        info = None
        while info is None:
            kind, payload = await asyncio.wait_for(pend.q.get(), wait_s)
            if kind == "tok":
                toks.append(payload)
            elif kind == "done":
                info = payload
        self.stats["completions"] += 1
        self._respond(writer, 200, self._completion_obj(
            rid, n_prompt, info, info["tokens"],
            self._finish_reason(pend, info)))
        return True

    async def _stream_response(self, rid: int, n_prompt: int,
                               pend: _Pending, reader, writer,
                               wait_s: float) -> bool:
        """Server-sent events, one chunk per token. Closes the connection
        when done (Connection: close framing -- no chunked encoding).
        Client disconnects surface as write errors on the next token;
        the handler then cancels through the ordinary inbox path."""
        head = (b"HTTP/1.1 200 OK\r\n"
                b"Content-Type: text/event-stream\r\n"
                b"Cache-Control: no-cache\r\n"
                b"Connection: close\r\n\r\n")
        writer.write(head)
        try:
            await writer.drain()
            while True:
                kind, payload = await asyncio.wait_for(pend.q.get(),
                                                       wait_s)
                if kind == "tok":
                    chunk = self._sse_obj(rid, token_id=payload)
                    writer.write(b"data: " + json.dumps(chunk).encode()
                                 + b"\n\n")
                    await writer.drain()
                elif kind == "done":
                    fin = self._sse_obj(
                        rid, finish_reason=self._finish_reason(
                            pend, payload),
                        usage=dict(prompt_tokens=n_prompt,
                                   completion_tokens=len(
                                       payload["tokens"]),
                                   total_tokens=n_prompt
                                   + len(payload["tokens"])))
                    writer.write(b"data: " + json.dumps(fin).encode()
                                 + b"\n\ndata: [DONE]\n\n")
                    await writer.drain()
                    self.stats["completions"] += 1
                    return False
        except (ConnectionError, BrokenPipeError, asyncio.TimeoutError):
            self.stats["disconnects"] += 1
            if pend.rid is not None:
                self._inbox.put(("cancel", pend.rid))
            return False

    # -- payload shaping -----------------------------------------------------
    def _finish_reason(self, pend: _Pending, info: Dict[str, Any]) -> str:
        if pend.timed_out:
            return "timeout"
        if info["preempted"]:
            return "preempted"
        if info["cancelled"]:
            return "cancelled"
        return "length"

    def _completion_obj(self, rid: int, n_prompt: int, info, toks,
                        finish_reason: str) -> Dict[str, Any]:
        return dict(
            id=f"cmpl-{rid}", object="text_completion",
            created=int(time.time()), model=self.fcfg.model_name,
            choices=[dict(index=0, text="", token_ids=list(toks),
                          finish_reason=finish_reason)],
            usage=dict(prompt_tokens=n_prompt,
                       completion_tokens=len(toks),
                       total_tokens=n_prompt + len(toks)),
            timing=dict(ttft_s=info["ttft_s"],
                        queue_wait_s=info["queue_wait_s"],
                        deadline_missed=info["deadline_missed"]))

    def _sse_obj(self, rid: int, token_id: Optional[int] = None,
                 finish_reason: Optional[str] = None,
                 usage: Optional[Dict] = None) -> Dict[str, Any]:
        choice: Dict[str, Any] = dict(index=0, text="",
                                      finish_reason=finish_reason)
        if token_id is not None:
            choice["token_id"] = token_id
        obj = dict(id=f"cmpl-{rid}", object="text_completion",
                   model=self.fcfg.model_name, choices=[choice])
        if usage is not None:
            obj["usage"] = usage
        return obj

    @staticmethod
    def _respond(writer, status: int, obj: Dict[str, Any]) -> None:
        phrase = {200: "OK", 400: "Bad Request", 404: "Not Found",
                  429: "Too Many Requests"}.get(status, "Error")
        data = json.dumps(obj).encode()
        writer.write(
            f"HTTP/1.1 {status} {phrase}\r\n"
            f"Content-Type: {_JSON}\r\n"
            f"Content-Length: {len(data)}\r\n\r\n".encode() + data)


def _err(message: str, etype: str) -> Dict[str, Any]:
    return dict(error=dict(message=message, type=etype))


def serve_forever(engine, fcfg: Optional[FrontendConfig] = None) -> None:
    """Blocking entry point for ``launch/serve.py --http``: start the
    front-end and sleep until interrupted."""
    fe = Frontend(engine, fcfg).start()
    print(f"serving on http://{fe.fcfg.host}:{fe.port} "
          f"(model={fe.fcfg.model_name!r}); POST /v1/completions with a "
          "token-id prompt; GET /health, /v1/models, /stats")
    try:
        while True:
            time.sleep(1.0)
    except KeyboardInterrupt:
        pass
    finally:
        fe.close()
