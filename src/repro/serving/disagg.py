"""Disaggregated prefill/decode serving: N prefill-worker and M
decode-worker engine instances behind a KV-aware router.

Monolithic serving makes prefill and decode compete for the same device
steps: every admission stalls the decode loop for a full chunked-prefill
group. Disaggregation splits the phases onto separate engine instances --
prefill workers only ever run admission-shaped programs, decode workers
only ever see prompts whose KV is already resident -- which is the
architectural unlock for serving at depth (ROADMAP item 1; the
vllm/triton-distributed prefill/decode split, in-process).

The hand-off protocol rides the paged prefix cache end to end:

1. **route**: the router (serving/router.py) scores the prompt against
   every prefill worker's radix tree and routes to maximal overlap, so a
   shared system prompt concentrates on the worker that already holds
   its pages (warm prefill = suffix-only compute).
2. **prefill**: the chosen worker runs the prompt through its ordinary
   batched chunked admission with a 1-token budget -- pure prefill; the
   sampled token is discarded (the decode worker re-derives it, see
   below) -- and its prefix cache inserts the prompt's full KV pages
   into its page pool.
3. **migrate**: ``Engine.export_kv_pages`` copies those pool pages to
   host memory bit-for-bit (int8-KV scales included);
   ``Engine.import_kv_pages`` scatters them into the routed decode
   worker's pool and radix tree. In-process this is one device->host and
   one host->device copy; the same protocol shape extends to a wire.
4. **decode**: the request is submitted to the decode worker, whose
   ordinary prefix-cache admission matches the imported pages, scatters
   them into its ring, prefills ONLY the remaining tail (the last token
   plus any partial page -- where the first sampled token comes from),
   and decodes continuously.

**The parity contract.** The decode worker samples every token,
including the first, from its own PRNG stream with the same per-request
key-split discipline a monolithic engine uses, and warm-prefix admission
is already pinned token-identical to cold prefill (tests/
test_prefix_cache.py, greedy AND temperature). So with 1 decode worker,
routed output is TOKEN-IDENTICAL to one monolithic engine with the same
ServeConfig -- greedy and temperature, across causal/window/int8-KV,
with speculation and the prefix cache live on the workers
(tests/test_disagg.py). With M decode workers, greedy output stays
token-identical (greedy sampling is schedule-independent and admission
isolation is pinned); temperature splits into per-worker streams.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.serving.engine import (_KV_FAMILIES, Engine, EngineSaturated,
                                  Request, ServeConfig)
from repro.serving.router import KVRouter


class DisaggEngine:
    """N prefill + M decode engine instances, one router, page migration
    through host memory. Public surface mirrors ``Engine``:
    submit/cancel/run/generate and a ``stats`` dict (aggregated across
    workers, plus router fields)."""

    def __init__(self, cfg: ModelConfig, params, serve_cfg: ServeConfig,
                 prefill_workers: int = 1, decode_workers: int = 1):
        if prefill_workers < 1 or decode_workers < 1:
            raise ValueError(
                f"need >= 1 prefill and >= 1 decode worker, got "
                f"{prefill_workers}P + {decode_workers}D")
        if cfg.family not in _KV_FAMILIES:
            raise ValueError(
                f"disaggregated serving needs a KV-ring family (got "
                f"{cfg.family!r}): recurrent state is not positional and "
                "cannot be handed off as pages")
        self.cfg = cfg
        self.scfg = serve_cfg
        # decode workers ARE the serving engines: same config (same seed,
        # slots, drafter, sampling -- the parity contract), prefix cache
        # forced on because imported pages land in it
        dcfg = dataclasses.replace(serve_cfg, prefix_cache=True)
        # prefill workers never decode (1-token budgets finish at the
        # first sampled token), so drafters are dead weight there; the
        # prefix cache doubles as the router's scoring state and the
        # export source
        pcfg = dataclasses.replace(serve_cfg, prefix_cache=True,
                                   drafter=None)
        self.decode_engines = [Engine(cfg, params, dcfg)
                               for _ in range(decode_workers)]
        self.prefill_engines = [Engine(cfg, params, pcfg)
                                for _ in range(prefill_workers)]
        self.router = KVRouter(self.prefill_engines, self.decode_engines)
        self._page = self.prefill_engines[0]._page
        self._T = self.decode_engines[0]._T
        self._queue: collections.deque = collections.deque()
        self._results: Dict[int, Request] = {}
        self._handoff: Dict[Any, Request] = {}   # (worker, worker_req_id)
        self._next_id = 0
        self._run_t0: Optional[float] = None
        self.stats: Dict[str, Any] = self._fresh_stats()

    # -- stats --------------------------------------------------------------
    @staticmethod
    def _fresh_stats() -> Dict[str, Any]:
        s = Engine._fresh_stats()
        s.update(migrated_pages=0, migrated_requests=0,
                 prefill_prefix_hits=0, prefill_prefix_tokens_reused=0,
                 router={})
        return s

    def _absorb(self, ws: Dict[str, float], decode: bool) -> None:
        """Fold one worker's per-cycle stats into the aggregate. Both
        tiers contribute prefill-side counters (decode workers still
        prefill each request's uncached tail); only decode workers
        contribute decode/token/spec/prefix-serving counters -- a prefill
        worker's discarded first tokens are not served output, and its
        radix activity is reported separately (it measures routing
        locality, not serving reuse)."""
        for k in ("prefill_s", "prefill_tokens", "prefill_groups",
                  "host_syncs"):
            self.stats[k] += ws[k]
        if decode:
            for k in ("decode_s", "tokens", "chunks", "admissions",
                      "draft_tokens", "draft_accepted", "spec_rounds",
                      "prefix_hits", "prefix_tokens_reused",
                      "prefix_evictions", "prefix_insert_drops"):
                self.stats[k] += ws[k]
        else:
            self.stats["prefill_prefix_hits"] += ws["prefix_hits"]
            self.stats["prefill_prefix_tokens_reused"] += \
                ws["prefix_tokens_reused"]
            self.stats["prefix_insert_drops"] += ws["prefix_insert_drops"]

    # -- submission ---------------------------------------------------------
    def submit(self, prompt: List[int],
               max_new_tokens: Optional[int] = None,
               on_token: Optional[Callable[[int, int], None]] = None,
               speculate: Optional[bool] = None,
               priority: int = 0,
               deadline_s: Optional[float] = None,
               on_done: Optional[Callable[[Request], None]] = None,
               arrival_t: Optional[float] = None) -> int:
        """Queue a request; same contract as ``Engine.submit`` (including
        the KV-ring bound and the SLO fields), validated eagerly so a bad
        request fails at submission, not mid-hand-off. The arrival stamp
        taken here survives the prefill->decode hand-off: the decode-tier
        submit receives it via ``arrival_t``, so TTFT measured by the
        decode worker still counts from the request's true arrival."""
        if not prompt:
            raise ValueError("empty prompt")
        budget = (self.scfg.max_new_tokens if max_new_tokens is None
                  else max_new_tokens)
        if budget < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got {budget}")
        if speculate and self.scfg.drafter is None:
            raise ValueError("speculate=True needs ServeConfig.drafter")
        if (not self.cfg.sliding_window
                and len(prompt) + budget > self._T):
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new_tokens ({budget}) "
                f"exceeds cache_len {self._T}; raise ServeConfig.cache_len")
        if (self.scfg.max_queue > 0
                and len(self._queue) >= self.scfg.max_queue):
            raise EngineSaturated(
                "queue_full",
                f"queue holds {len(self._queue)} requests "
                f"(ServeConfig.max_queue={self.scfg.max_queue})")
        req = Request(id=self._next_id, prompt=list(prompt),
                      max_new_tokens=budget, on_token=on_token,
                      speculate=speculate, priority=int(priority),
                      deadline_s=deadline_s, on_done=on_done,
                      submit_t=(time.perf_counter() if arrival_t is None
                                else arrival_t))
        req._route = None               # (prefill worker, decode worker)
        self._next_id += 1
        self._queue.append(req)
        return req.id

    def _complete(self, req: Request) -> None:
        """Disagg-level completion point: records the result and fires the
        request's ``on_done`` exactly once (mirrors Engine._finish)."""
        already = req.done
        req.done = True
        self._results[req.id] = req
        if req.on_done is not None and not already:
            req.on_done(req)

    def cancel(self, request_id: int) -> bool:
        """Cancel a request: still queued here -> it never routes; already
        handed to a decode worker -> delegated to that worker (its slot
        frees at the next chunk boundary, streamed tokens are kept)."""
        for req in self._queue:
            if req.id == request_id:
                self._queue.remove(req)
                req.cancelled = True
                self._complete(req)
                return True
        for (dw, wid), req in self._handoff.items():
            if req.id == request_id and not req.done:
                if self.decode_engines[dw].cancel(wid):
                    req.cancelled = True
                    return True
        return False

    # -- the serving loop ---------------------------------------------------
    def _prefill_route(self, req: Request) -> Optional[int]:
        """Pick a prefill worker, or None when prefill can't help: a
        prompt without one full page exports nothing, and a prompt longer
        than the ring (windowed archs) skips insertion -- both go
        straight to a decode worker, which cold-prefills them."""
        if len(req.prompt) < self._page or len(req.prompt) > self._T:
            self.router.note_direct_decode()
            return None
        return self.router.pick_prefill(req.prompt)

    def _emit_cb(self, req: Request):
        """Wrap the user's on_token: stamp disagg-level ttft on the first
        token and re-key the callback to the DisaggEngine request id."""
        def cb(_wid: int, tok: int) -> None:
            if req.ttft_s is None:
                # measured from the request's ARRIVAL at the DisaggEngine
                # (the stamp the decode-tier submit also inherits via
                # arrival_t), not from run() entry -- same bugfix as
                # Engine._note_first_token
                if req.submit_t is not None:
                    req.ttft_s = time.perf_counter() - req.submit_t
                elif self._run_t0 is not None:
                    req.ttft_s = time.perf_counter() - self._run_t0
            if req.on_token is not None:
                req.on_token(req.id, tok)
        return cb

    def _copy_back_cb(self, req: Request):
        """on_done hook for the decode-tier request: copy the worker's
        queue-wait / deadline verdict back onto the disagg-level request
        (its ttft is already arrival-correct because the worker measured
        from the handed-off arrival_t)."""
        def cb(wreq: Request) -> None:
            req.queue_wait_s = wreq.queue_wait_s
            req.deadline_missed = wreq.deadline_missed
        return cb

    def run(self, poll: Optional[Callable[[], None]] = None
            ) -> Dict[int, List[int]]:
        """Drain the queue in waves: route -> prefill -> migrate ->
        decode. Requests submitted from ``on_token`` callbacks mid-wave
        join the next wave (same observable contract as ``Engine.run``).
        ``poll``, when given, is called once per wave so a front-end can
        inject arrivals between waves. Returns {request_id: tokens} for
        THIS cycle; stats cover this cycle only."""
        self.stats = self._fresh_stats()
        self._run_t0 = time.perf_counter()
        while True:
            if poll is not None:
                poll()
            if not self._queue:
                break
            wave = list(self._queue)
            self._queue.clear()
            # -- phase 1: route + prefill (per-worker batched admission)
            assigned: Dict[int, List[Request]] = {}
            for req in wave:
                pw = self._prefill_route(req)
                req._route = pw
                if pw is not None:
                    assigned.setdefault(pw, []).append(req)
            for pw, reqs in assigned.items():
                eng = self.prefill_engines[pw]
                for req in reqs:
                    eng.submit(list(req.prompt), max_new_tokens=1)
                eng.run()               # pure prefill: budget-1 requests
                self._absorb(eng.stats, decode=False)
                for _ in reqs:
                    self.router.note_prefill_done(pw)
            # -- phase 2: migrate + hand off, in submission order (with
            # one decode worker this preserves the exact admission order
            # a monolithic engine would see -- the temperature-parity leg)
            batches: Dict[int, List[int]] = {}
            for req in wave:
                if req.cancelled:
                    self._complete(req)
                    continue
                dw = self.router.pick_decode()
                deng = self.decode_engines[dw]
                if req._route is not None:
                    kv = self.prefill_engines[req._route].export_kv_pages(
                        req.prompt)
                    n = deng.import_kv_pages(kv)
                    self.router.note_migrated(dw, n)
                    self.stats["migrated_pages"] += n
                    self.stats["migrated_requests"] += n > 0
                # arrival_t hands the original arrival stamp across the
                # tier boundary: the decode worker's TTFT/queue-wait clock
                # keeps counting from when the user submitted, not from
                # when the hand-off happened
                wid = deng.submit(list(req.prompt),
                                  max_new_tokens=req.max_new_tokens,
                                  on_token=self._emit_cb(req),
                                  speculate=req.speculate,
                                  priority=req.priority,
                                  deadline_s=req.deadline_s,
                                  on_done=self._copy_back_cb(req),
                                  arrival_t=req.submit_t)
                self._handoff[(dw, wid)] = req
                batches.setdefault(dw, []).append(wid)
            # -- phase 3: decode (continuous batching inside each worker)
            for dw, wids in batches.items():
                deng = self.decode_engines[dw]
                res = deng.run()
                self._absorb(deng.stats, decode=True)
                self.stats["deadline_misses"] += \
                    deng.stats["deadline_misses"]
                self.stats["preemptions"] += deng.stats["preemptions"]
                for wid in wids:
                    req = self._handoff.pop((dw, wid))
                    req.tokens = list(res.get(wid, []))
                    self._complete(req)
                    self.router.note_decode_done(dw)
        done = {rid: req.tokens for rid, req in self._results.items()}
        self._finalize_stats(done)
        self._results = {}
        self._run_t0 = None
        return done

    def _finalize_stats(self, done: Dict[int, List[int]]) -> None:
        s = self.stats
        s["requests"] = s["admissions"]
        s["tokens"] = sum(len(t) for t in done.values())
        s["tok_per_s"] = (s["tokens"] / s["decode_s"]
                          if s["decode_s"] > 0 else 0.0)
        s["prefill_tok_per_s"] = (s["prefill_tokens"] / s["prefill_s"]
                                  if s["prefill_s"] > 0 else 0.0)
        ttfts = [r.ttft_s for r in self._results.values()
                 if r.ttft_s is not None]
        s["ttft_s"] = sum(ttfts) / len(ttfts) if ttfts else 0.0
        s["ttft_p50_s"] = float(np.percentile(ttfts, 50)) if ttfts else 0.0
        s["ttft_p99_s"] = float(np.percentile(ttfts, 99)) if ttfts else 0.0
        waits = [r.queue_wait_s for r in self._results.values()
                 if r.queue_wait_s is not None]
        s["queue_wait_s"] = sum(waits) / len(waits) if waits else 0.0
        s["accept_rate"] = (s["draft_accepted"] / s["draft_tokens"]
                            if s["draft_tokens"] > 0 else 0.0)
        s["router"] = self.router.snapshot()

    # -- public API ---------------------------------------------------------
    def generate(self, prompts: List[List[int]]) -> List[List[int]]:
        """Generate completions for a batch of prompts through the
        disaggregated path. Resets every worker's scheduler/PRNG state
        (call-to-call determinism, and the exact discipline under which
        routed output is token-identical to ``Engine.generate`` with the
        same ServeConfig); radix trees and page pools persist, so repeat
        workloads stay warm."""
        if self._queue:
            raise RuntimeError(
                f"{len(self._queue)} submitted request(s) pending; call "
                "run() to drain them before generate() (which resets)")
        for eng in self.prefill_engines + self.decode_engines:
            eng._reset()
        ids = [self.submit(list(p)) for p in prompts]
        res = self.run()
        return [res[i] for i in ids]
