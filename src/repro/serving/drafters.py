"""Pluggable draft-token proposers for speculative decoding.

Speculative decoding attacks the decode wall the F-BFQ paper measures
tokens/second against: decode is inherently serial (one token per
MatMul pass), but the quantized verify path has idle batch bandwidth --
scoring k drafted tokens in one masked forward costs barely more than
scoring one. A ``Drafter`` proposes those k tokens; the engine verifies
them against the target model and accepts the longest correct prefix
(greedy: bit-identical to plain decode; temperature: rejection
sampling). Neither drafter here needs a second checkpoint:

* ``ngram``  -- prompt-lookup drafting: match the sequence's most recent
  n-gram against its own history (prompt + generated tokens) on device
  and propose the continuation of the latest earlier occurrence.
  Zero model cost per proposal; shines on repetitive/extractive text.
* ``self``   -- truncated-layer self-drafting: run the first
  ``draft_layers`` layers of the SAME model (same slab-packed quantized
  weights -- the stacked QTensor payloads slice per layer like any
  array), with an ephemeral draft KV cache re-carved from the main
  cache's leading layers each round, then the shared final norm + LM
  head. The draft cache is discarded after proposing, so rejected draft
  state never needs unwinding.

Both drafters are pure JAX on the device-resident state the engine
threads through its jitted decode loop -- proposing never costs a host
sync. Host-side state (admission fills) lives in plain numpy and is
uploaded with the rest of the chunk carry.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


class NGramDrafter:
    """Prompt-lookup drafter: device-side n-gram match over a per-slot
    rolling history ring of the last ``draft_hist`` tokens."""

    name = "ngram"
    uses_history = True

    def __init__(self, cfg: ModelConfig, scfg):
        self.k = scfg.draft_k
        self.n = scfg.draft_ngram
        self.H = scfg.draft_hist
        if self.H < self.n + 1:
            raise ValueError(
                f"draft_hist ({self.H}) must exceed draft_ngram ({self.n})")

    # -- host-side state ----------------------------------------------------
    def init_state_np(self, B: int) -> Dict[str, np.ndarray]:
        return dict(hist=np.full((B, self.H), -1, np.int32),
                    hpos=np.full((B, self.H), -1, np.int32),
                    hcnt=np.zeros((B,), np.int32))

    def admit_np(self, state: Dict[str, np.ndarray], slot: int,
                 tokens) -> None:
        """Fill a freshly admitted slot's history with prompt + first
        token (in place; admission is already a host sync point)."""
        H = self.H
        toks = np.asarray(tokens, np.int32)
        n = len(toks)
        state["hist"][slot] = -1
        state["hpos"][slot] = -1
        pos = np.arange(max(0, n - H), n)
        state["hist"][slot, pos % H] = toks[pos]
        state["hpos"][slot, pos % H] = pos
        state["hcnt"][slot] = n

    # -- device-side propose/update (called inside the jitted loop) ---------
    def propose(self, params, cfg, cache, state, tok, pos,
                act) -> Tuple[jnp.ndarray, Any]:
        """Latest earlier occurrence of the trailing n-gram; propose its
        continuation. No match (or history shorter than n): fall back to
        repeating the last token -- cheap, and verify fixes everything."""
        hist, hpos, hcnt = state["hist"], state["hpos"], state["hcnt"]
        B, H = hist.shape
        n, k = self.n, self.k
        # trailing query gram: absolute positions hcnt-n .. hcnt-1
        qpos = hcnt[:, None] - n + jnp.arange(n, dtype=jnp.int32)[None]
        qtok = jnp.take_along_axis(hist, qpos % H, 1)           # (B, n)
        # candidate gram ends at every ring slot's absolute position
        m = (hpos >= 0) & (hpos <= hcnt[:, None] - 2)           # strictly
        for j in range(n):                                      # earlier
            off = n - 1 - j
            cpos = hpos - off
            ctok = jnp.take_along_axis(hist, cpos % H, 1)
            cchk = jnp.take_along_axis(hpos, cpos % H, 1)
            m = m & (cchk == cpos) & (ctok == qtok[:, j:j + 1])
        m = m & (hcnt[:, None] >= n)                            # query valid
        best = jnp.max(jnp.where(m, hpos, -1), axis=1)          # (B,)
        prop_pos = best[:, None] + 1 + jnp.arange(k, dtype=jnp.int32)[None]
        ptok = jnp.take_along_axis(hist, prop_pos % H, 1)
        ok = (best[:, None] >= 0) & (
            jnp.take_along_axis(hpos, prop_pos % H, 1) == prop_pos)
        return jnp.where(ok, ptok, tok[:, None]), state

    def update(self, state, emit, e) -> Any:
        """Append each slot's e accepted tokens (emit[:, :e]) to its
        history ring -- a masked scatter, all on device."""
        hist, hpos, hcnt = state["hist"], state["hpos"], state["hcnt"]
        B, H = hist.shape
        cols = jnp.arange(emit.shape[1], dtype=jnp.int32)[None]
        wp = hcnt[:, None] + cols
        sel = jnp.where(cols < e[:, None], wp % H, H)           # H = drop
        bidx = jnp.arange(B)[:, None]
        return dict(hist=hist.at[bidx, sel].set(emit, mode="drop"),
                    hpos=hpos.at[bidx, sel].set(wp, mode="drop"),
                    hcnt=hcnt + e)


class SelfDrafter:
    """Truncated-layer self-drafter: the first ``draft_layers`` of the
    target model (sharing its packed weights), autoregressively greedy
    for k steps over an ephemeral draft cache carved from the main
    cache's leading layers."""

    name = "self"
    uses_history = False

    def __init__(self, cfg: ModelConfig, scfg):
        self.k = scfg.draft_k
        self.dl = scfg.draft_layers
        if not 1 <= self.dl <= cfg.n_layers:
            raise ValueError(
                f"draft_layers ({self.dl}) must be in [1, {cfg.n_layers}]")
        self.cfg_draft = cfg.replace(n_layers=self.dl)

    def init_state_np(self, B: int) -> Dict[str, np.ndarray]:
        return {}

    def admit_np(self, state, slot, tokens) -> None:
        pass

    def propose(self, params, cfg, cache, state, tok, pos,
                act) -> Tuple[jnp.ndarray, Any]:
        from repro.models import transformer as T
        dl = self.dl
        dparams = dict(params)
        # stacked layer params (QTensor payloads included) slice per layer
        dparams["layers"] = jax.tree.map(lambda a: a[:dl], params["layers"])
        dcache = {k: (v if k == "pos" else v[:dl]) for k, v in cache.items()}
        cur, p = tok, pos
        outs = []
        for _ in range(self.k):
            logits, dcache = T.decode_step(dparams, self.cfg_draft, dcache,
                                           tokens=cur, position=p, live=act)
            cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            p = p + 1
            outs.append(cur)
        # dcache (with the draft's own writes) is dropped here: the next
        # round re-carves it from the verified main cache, so no rollback
        return jnp.stack(outs, axis=1), state

    def update(self, state, emit, e) -> Any:
        return state


DRAFTERS = {"ngram": NGramDrafter, "self": SelfDrafter}


def make_drafter(name: str, cfg: ModelConfig, scfg):
    try:
        cls = DRAFTERS[name]
    except KeyError:
        raise ValueError(f"unknown drafter {name!r}; "
                         f"known: {sorted(DRAFTERS)}") from None
    return cls(cfg, scfg)
