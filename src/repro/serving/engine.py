"""Continuous-batching serving engine with a fully on-device decode loop.

The paper's end-to-end number is serving throughput, and at that scale the
bottleneck is not the MatMul but the per-token host round-trip (LlamaF,
arXiv:2409.11424).  This engine therefore keeps the whole decode loop on
device:

* ``decode chunk``: one jitted program runs up to ``decode_chunk`` decode
  steps inside a ``jax.lax.while_loop`` -- sampling, EOS masking, per-slot
  token-budget accounting and position bookkeeping are all arrays in the
  loop carry.  The host sees one sync per *chunk*, not per token, so host
  syncs per generated sequence are O(1).
* ``continuous batching``: a request queue feeds a fixed set of batch
  slots.  When a sequence finishes (EOS or budget), its slot is freed and
  the next queued request is admitted between chunks -- single-request
  prefill, cache scatter into the slot (``transformer.cache_set_slot``),
  no recompilation.  Dead slots still run the math (static shapes) but a
  live mask keeps them from touching their cache (``decode_step(live=)``).
* ``streaming``: each request may carry an ``on_token`` callback; tokens
  are delivered after every chunk (and the first token at admission).

Prompts are right-padded to a bucket length for attention families (exact
under causal masking; pad cache entries are disabled via ``pos = -1``).
Recurrent families (ssm/hybrid) prefill at exact prompt length, since
trailing pads would pollute the recurrent state.

``generate_reference`` keeps the pre-rewrite host-driven loop (one jitted
step per token, same math) for parity tests and as readable documentation
of the device loop's semantics.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as T


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32            # per-request default token budget
    temperature: float = 0.0            # 0 -> greedy
    eos_id: Optional[int] = None
    cache_len: int = 256                # KV ring length (fixed at compile)
    seed: int = 0
    max_slots: int = 4                  # concurrent batch slots
    decode_chunk: int = 32              # device-loop steps per host sync
    prefill_bucket: int = 16            # prompt pad granularity (attention)


@dataclasses.dataclass
class Request:
    id: int
    prompt: List[int]
    max_new_tokens: int
    on_token: Optional[Callable[[int, int], None]] = None
    tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False

    def _emit(self, tok: int) -> None:
        self.tokens.append(tok)
        if self.on_token is not None:
            self.on_token(self.id, tok)


class Engine:
    def __init__(self, cfg: ModelConfig, params, serve_cfg: ServeConfig):
        for field in ("max_slots", "decode_chunk", "max_new_tokens",
                      "cache_len"):
            if getattr(serve_cfg, field) < 1:
                raise ValueError(f"ServeConfig.{field} must be >= 1, got "
                                 f"{getattr(serve_cfg, field)}")
        self.cfg = cfg
        self.params = params
        self.scfg = serve_cfg
        self._B = serve_cfg.max_slots
        # ring length must match init_cache's clamp or slot scatter would
        # write a cache_len-long update into a window-long ring
        self._T = T.attn_cache_len(cfg, serve_cfg.cache_len)
        self._prefill = jax.jit(self._prefill_impl)
        # caches are donated so XLA aliases the ring buffers call-to-call
        self._admit_cache = jax.jit(self._admit_cache_impl,
                                    donate_argnums=(0,))
        self._decode_chunk = jax.jit(self._decode_chunk_impl,
                                     donate_argnums=(1,))
        self._ref_step = jax.jit(self._ref_step_impl)
        self._cache = None
        self.stats: Dict[str, float] = {}
        self._reset()

    # -- jitted internals ----------------------------------------------------
    def _sample(self, logits, key):
        """logits (B,V) -> token ids (B,) int32."""
        if self.scfg.temperature > 0:
            return jax.random.categorical(
                key, logits / self.scfg.temperature).astype(jnp.int32)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def _prefill_impl(self, params, tokens, length, key):
        """Single-request prefill: tokens (1,P) right-padded, length ().
        Returns (first sampled token (), slot cache with pads disabled)."""
        P = tokens.shape[1]
        logits, _, caches = T.forward_seq(params, self.cfg, tokens=tokens,
                                          want_cache=True)
        last = jax.lax.dynamic_index_in_dim(logits[0], length - 1, 0,
                                            keepdims=False)
        first = self._sample(last[None], key)[0]
        slot_cache = T.cache_from_prefill(self.cfg, caches, P,
                                          cache_len=self._T)
        if "pos" in slot_cache:
            # pad entries must never win decode attention
            slot_cache["pos"] = jnp.where(slot_cache["pos"] < length,
                                          slot_cache["pos"], -1)
        return first, slot_cache

    def _admit_cache_impl(self, cache, slot_cache, index):
        return T.cache_set_slot(cache, slot_cache, index)

    def _decode_chunk_impl(self, params, cache, tok, pos, live, n_gen,
                           budget, key):
        """Run up to ``decode_chunk`` decode steps on device.

        Carry: (step, cache, tok (B,), pos (B,), live (B,) bool,
        n_gen (B,), out (B,C), key).  Exits early once every slot is dead.
        ``out`` holds the tokens emitted this chunk, -1 where a slot was
        already dead at that step (so each row is a dense prefix).
        """
        C = self.scfg.decode_chunk
        B = tok.shape[0]
        out0 = jnp.full((B, C), -1, jnp.int32)

        def cond(st):
            step, _, _, _, live_, _, _, _ = st
            return (step < C) & jnp.any(live_)

        def body(st):
            step, cache_, tok_, pos_, live_, n_gen_, out_, key_ = st
            logits, cache_ = T.decode_step(params, self.cfg, cache_,
                                           tokens=tok_, position=pos_,
                                           live=live_)
            key_, sub = jax.random.split(key_)
            nxt = self._sample(logits, sub)
            nxt = jnp.where(live_, nxt, tok_)
            out_ = out_.at[:, step].set(jnp.where(live_, nxt, -1))
            n_gen_ = n_gen_ + live_.astype(jnp.int32)
            new_live = live_ & (n_gen_ < budget)
            if self.scfg.eos_id is not None:
                new_live = new_live & (nxt != self.scfg.eos_id)
            pos_ = pos_ + live_.astype(jnp.int32)
            return step + 1, cache_, nxt, pos_, new_live, n_gen_, out_, key_

        st = (jnp.zeros((), jnp.int32), cache, tok, pos, live, n_gen,
              out0, key)
        _, cache, tok, pos, live, n_gen, out, key = jax.lax.while_loop(
            cond, body, st)
        return cache, out, tok, pos, live, n_gen, key

    def _ref_step_impl(self, params, cache, tok, pos, live, key):
        """One host-driven decode step (reference path)."""
        logits, cache = T.decode_step(params, self.cfg, cache, tokens=tok,
                                      position=pos, live=live)
        nxt = self._sample(logits, key)
        return jnp.where(live, nxt, tok), cache

    # -- host-side scheduler -------------------------------------------------
    def _reset(self) -> None:
        B = self._B
        self._queue: collections.deque = collections.deque()
        self._slots: List[Optional[Request]] = [None] * B
        self._results: Dict[int, Request] = {}
        self._next_id = 0
        self._key = jax.random.PRNGKey(self.scfg.seed)
        self._tok = np.zeros(B, np.int32)
        self._pos = np.zeros(B, np.int32)
        self._live = np.zeros(B, bool)
        self._ngen = np.zeros(B, np.int32)
        self._budget = np.full(B, self.scfg.max_new_tokens, np.int32)
        self.stats = dict(prefill_s=0.0, decode_s=0.0, tokens=0,
                          tok_per_s=0.0, host_syncs=0, admissions=0,
                          chunks=0, requests=0)

    def submit(self, prompt: List[int],
               max_new_tokens: Optional[int] = None,
               on_token: Optional[Callable[[int, int], None]] = None) -> int:
        """Queue a request; returns its id. Tokens stream via ``on_token``
        (called as on_token(request_id, token)) if given."""
        if not prompt:
            raise ValueError("empty prompt")
        budget = (self.scfg.max_new_tokens if max_new_tokens is None
                  else max_new_tokens)
        if budget < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got {budget}")
        if (self.cfg.family != "ssm" and not self.cfg.sliding_window
                and len(prompt) + budget > self._T):
            # full-attention archs must not wrap the KV ring (that would
            # silently truncate context); windowed archs wrap by design
            # (the ring IS the window) and take prompts of any length
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new_tokens ({budget}) "
                f"exceeds cache_len {self._T}; raise ServeConfig.cache_len")
        req = Request(id=self._next_id, prompt=list(prompt),
                      max_new_tokens=budget, on_token=on_token)
        self._next_id += 1
        self._queue.append(req)
        return req.id

    def _bucket_len(self, n: int) -> int:
        # recurrent state would absorb trailing pads -> exact length there;
        # prompts at/beyond the ring (windowed archs) also go exact, so the
        # kept last-window slots hold real tokens, not masked pads
        if self.cfg.family in ("ssm", "hybrid") or n >= self._T:
            return n
        b = max(self.scfg.prefill_bucket, 1)
        return min(-(-n // b) * b, self._T)

    def _admit_request(self, slot: int, req: Request) -> None:
        n = len(req.prompt)
        P = self._bucket_len(n)
        toks = np.zeros((1, P), np.int32)
        toks[0, :n] = req.prompt
        t0 = time.perf_counter()
        self._key, sub = jax.random.split(self._key)
        first, slot_cache = self._prefill(self.params, jnp.asarray(toks),
                                          jnp.asarray(n, jnp.int32), sub)
        if self._cache is None:
            self._cache = T.init_cache(self.cfg, self._B, self._T)
        self._cache = self._admit_cache(self._cache, slot_cache,
                                        jnp.asarray(slot, jnp.int32))
        first_tok = int(first)                    # 1 host sync / admission
        self.stats["host_syncs"] += 1
        self.stats["admissions"] += 1
        self.stats["prefill_s"] += time.perf_counter() - t0
        req._emit(first_tok)
        finished = req.max_new_tokens <= 1 or (
            self.scfg.eos_id is not None and first_tok == self.scfg.eos_id)
        if finished:
            req.done = True
            self._results[req.id] = req
            return
        self._slots[slot] = req
        self._tok[slot] = first_tok
        self._pos[slot] = n
        self._live[slot] = True
        self._ngen[slot] = 1
        self._budget[slot] = req.max_new_tokens

    def _admit_pending(self) -> None:
        for i in range(self._B):
            if not self._queue:
                break
            if self._slots[i] is None:
                self._admit_request(i, self._queue.popleft())

    def _run_chunk(self) -> None:
        t0 = time.perf_counter()
        self._cache, out_d, tok_d, pos_d, live_d, ngen_d, self._key = \
            self._decode_chunk(self.params, self._cache,
                               jnp.asarray(self._tok),
                               jnp.asarray(self._pos),
                               jnp.asarray(self._live),
                               jnp.asarray(self._ngen),
                               jnp.asarray(self._budget), self._key)
        out, tok, pos, live, ngen = jax.device_get(
            (out_d, tok_d, pos_d, live_d, ngen_d))  # THE sync of this chunk
        # device_get hands back read-only buffers; admission mutates these
        self._tok, self._pos = np.array(tok), np.array(pos)
        self._live, self._ngen = np.array(live), np.array(ngen)
        self.stats["host_syncs"] += 1
        self.stats["chunks"] += 1
        self.stats["decode_s"] += time.perf_counter() - t0
        for i, req in enumerate(self._slots):
            if req is None:
                continue
            for tok in out[i][out[i] >= 0].tolist():
                req._emit(tok)
            if not self._live[i]:
                req.done = True
                self._results[req.id] = req
                self._slots[i] = None               # slot freed -> eviction

    def run(self) -> Dict[int, List[int]]:
        """Drive admission + fused decode chunks until queue and slots are
        drained. Returns {request_id: tokens} for THIS cycle; stats cover
        this cycle only (slots are always empty between run() calls, so
        resetting the counters here is safe)."""
        self.stats.update(prefill_s=0.0, decode_s=0.0, tokens=0,
                          tok_per_s=0.0, host_syncs=0, admissions=0,
                          chunks=0, requests=len(self._queue))
        while self._queue or any(r is not None for r in self._slots):
            self._admit_pending()
            if not self._live.any():
                continue
            self._run_chunk()
        done = {rid: req.tokens for rid, req in self._results.items()}
        self._results = {}                  # next submit/run cycle is fresh
        ntok = sum(len(t) for t in done.values())
        self.stats["tokens"] = ntok
        self.stats["tok_per_s"] = ntok / max(self.stats["decode_s"], 1e-9)
        return done

    # -- public API ----------------------------------------------------------
    def generate(self, prompts: List[List[int]]) -> List[List[int]]:
        """Generate completions for a batch of prompts. Prompts beyond
        ``max_slots`` are continuously batched into freed slots. Resets
        engine state (fresh PRNG seed) for call-to-call determinism."""
        if self._queue:
            raise RuntimeError(
                f"{len(self._queue)} submitted request(s) pending; call "
                "run() to drain them before generate() (which resets)")
        self._reset()
        ids = [self.submit(list(p)) for p in prompts]
        res = self.run()
        return [res[i] for i in ids]

    def generate_reference(self,
                           prompts: List[List[int]]) -> List[List[int]]:
        """Pre-rewrite reference: same admission/prefill/sampling math but
        one host round-trip per token. O(tokens) syncs -- parity oracle
        for the on-device loop, not a serving path."""
        if len(prompts) > self._B:
            raise ValueError("reference path has no queue; "
                             f"need <= {self._B} prompts")
        if self._queue:
            raise RuntimeError(
                f"{len(self._queue)} submitted request(s) pending; call "
                "run() to drain them before generate_reference()")
        self._reset()
        ids = [self.submit(list(p)) for p in prompts]
        self.stats["requests"] = len(ids)
        self._admit_pending()
        t0 = time.perf_counter()
        while self._live.any():
            self._key, sub = jax.random.split(self._key)
            nxt_d, self._cache = self._ref_step(
                self.params, self._cache, jnp.asarray(self._tok),
                jnp.asarray(self._pos), jnp.asarray(self._live), sub)
            nxt = np.asarray(jax.device_get(nxt_d))
            self.stats["host_syncs"] += 1
            for i, req in enumerate(self._slots):
                if req is None or not self._live[i]:
                    continue
                tok = int(nxt[i])
                req._emit(tok)
                self._ngen[i] += 1
                self._pos[i] += 1
                self._tok[i] = tok
                if (self._ngen[i] >= self._budget[i]
                        or (self.scfg.eos_id is not None
                            and tok == self.scfg.eos_id)):
                    self._live[i] = False
                    req.done = True
                    self._results[req.id] = req
                    self._slots[i] = None
        self.stats["decode_s"] += time.perf_counter() - t0
        res = {rid: req.tokens for rid, req in self._results.items()}
        self._results = {}
        ntok = sum(len(t) for t in res.values())
        self.stats["tokens"] = ntok
        self.stats["tok_per_s"] = ntok / max(self.stats["decode_s"], 1e-9)
        return [res[i] for i in ids]
