"""Continuous-batching serving engine with a fully on-device decode loop
and a batched, chunked prefill pipeline.

The paper's end-to-end number is serving throughput, and at that scale the
bottleneck is not the MatMul but host round-trips and under-filled batches
(LlamaF, arXiv:2409.11424).  This engine therefore keeps both phases busy:

* ``decode chunk``: one jitted program runs up to ``decode_chunk`` decode
  steps inside a ``jax.lax.while_loop`` -- sampling, EOS masking, per-slot
  token-budget accounting and position bookkeeping are all arrays in the
  loop carry.  The host sees one sync per *chunk*, not per token, so host
  syncs per generated sequence are O(1).
* ``batched chunked prefill``: at each chunk boundary the scheduler drains
  up to ``prefill_batch`` queued requests into the free slots at once,
  right-pads their prompts to a shared bucketed length, and feeds them
  through ONE jitted ``transformer.prefill_chunk`` program per fixed
  (group, chunk) shape.  A length mask keeps padding out of the KV ring
  and out of the sampled first token; prompts longer than
  ``prefill_chunk`` stream through the same program chunk by chunk, so
  prefill compilations are O(#buckets), not O(#distinct prompt lengths).
  All resulting caches scatter into their slots in a single
  ``transformer.cache_set_slots`` call.  Recurrent families (ssm/hybrid)
  ride the SAME batched path: padding columns are identity on the
  conv/SSM state (``transformer._recurrent_chunk`` zeroes their dt and
  gathers each row's conv tail at its last real column), and the chunk
  grid is FIXED (``prefill_chunk`` clamped down to divide the ring) so
  every prompt sees the same absolute chunk boundaries -- which makes
  batched admission bit-identical to sequential admission and lets ONE
  compiled (group, chunk) program serve every prompt length.
* ``continuous batching``: when a sequence finishes (EOS, budget, or
  ``cancel``), its slot is freed and queued requests are admitted between
  chunks -- no recompilation.  Dead slots still run the math (static
  shapes) but a live mask keeps them from touching their cache
  (``decode_step(live=)``).
* ``streaming``: each request may carry an ``on_token`` callback; tokens
  are delivered after every chunk (and the first token at admission).
* ``speculative decoding``: with a ``Drafter`` configured (drafters.py:
  n-gram prompt lookup, or a truncated-layer self-draft over the same
  quantized weights), the decode chunk becomes draft -> verify -> accept
  rounds: k drafted tokens are scored per slot in one fused verify pass,
  the longest correct prefix is accepted (greedy) or rejection-sampled
  (temperature), and the ring rows written for rejected drafts are
  restored from a pre-verify snapshot (``cache_ring_rewind``). All of it
  rides the jitted while_loop carry -- still ONE host sync per chunk --
  and every decision is per-slot, so a continuous batch freely mixes
  speculative and plain sequences (``submit(speculate=...)``).
  ``draft_verify="scan"`` (default) replays decode_step per column and
  makes greedy speculative output BIT-identical to plain decode;
  ``"batched"`` scores the block in one masked prefill-style forward
  (throughput datapath, equal to within float rounding).
* ``tensor parallelism`` (``tp=N``): every jitted program above runs via
  ``shard_map`` over a ("model",) mesh. Weights shard lane-only (packed
  QTensor payload lanes / attention heads / the ffn hidden; K rows stay
  whole per shard so super-blocks never straddle devices), the KV cache
  and prefix-cache page pool shard over kv_heads, and each projection
  pays ONE collective (a tiled lane all-gather of disjoint blocks --
  exact). The default "padded" matmul datapath keeps every
  gemm at the single-device program shape (off-shard lanes zero-embedded
  -- exact), so serving output is TOKEN-IDENTICAL across tp degrees, in
  fp32 and quantized, with speculation and the prefix cache on
  (tests/test_tp_serving.py); "sliced" trades that bitwise parity for
  1/N per-shard FLOPs. Host-side scheduling is mesh-oblivious; the
  ``generate_reference``/``generate_spec_reference`` oracles run their
  plain jitted programs over the sharded params via GSPMD (correct, but
  compare them at tp=1 where they are the pinned bitwise oracle).
* ``prefix caching`` (``prefix_cache=True``): a host-side radix tree over
  token-ID prefixes maps to a refcounted device page pool
  (serving/prefix_cache.py). Admission matches each queued request's
  longest cached prefix, scatters those pages into its group-cache row
  (bit-for-bit KV copies, copy-on-write for partial-page hits) and runs
  chunked prefill only over the uncached suffix; freshly computed prompt
  pages are inserted back, with LRU eviction of zero-ref (childless)
  pages under a byte budget. Greedy output is token-identical to running
  with the cache off, and admission still costs ONE host sync per group.
  Recurrent families cache CHECKPOINTS instead of positional pages: the
  page size is pinned to the prefill chunk, each pool page stores the
  whole conv/SSM state after its last token (the inter-chunk carry the
  chunk loop already materializes -- zero extra compute, bit-identical
  to cold by construction), and a warm admission restores the checkpoint
  at the group's shared full-page horizon and prefills only the suffix
  (hybrid additionally scatters the ring pages below that horizon).
* ``family adapters`` (models/state.py): which family supports which
  feature lives in ONE capability table (``FamilyCaps``) checked by ONE
  validation pass (``validate_serve_features``) at construction, and the
  engine drives every cache operation through a ``DecodeState`` adapter
  instead of ad-hoc ``cfg.family`` string checks.

``generate_reference`` keeps the pre-rewrite host-driven loop (one jitted
step per token, same math) for parity tests and as readable documentation
of the device loop's semantics; ``generate_spec_reference`` does the same
for the speculative path with the acceptance/rollback bookkeeping
re-implemented in numpy (the rejection-sampling oracle).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.configs.base import ModelConfig
from repro.distributed import sharding as SH
from repro.models import transformer as T
from repro.models.state import (DecodeState, KV_FAMILIES,
                                validate_serve_features)
from repro.serving.drafters import make_drafter
from repro.serving.prefix_cache import PrefixCache

# jax.shard_map only exists as a top-level API in newer jax releases
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _shard_map

# re-export: the KV-ring family set now lives in the capability table
# (models/state.py); serving/disagg.py and tests import it from here
_KV_FAMILIES = KV_FAMILIES


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32            # per-request default token budget
    temperature: float = 0.0            # 0 -> greedy
    eos_id: Optional[int] = None
    cache_len: int = 256                # KV ring length (fixed at compile)
    seed: int = 0
    max_slots: int = 4                  # concurrent batch slots
    decode_chunk: int = 32              # device-loop steps per host sync
    prefill_bucket: int = 16            # prompt pad granularity (attention)
    prefill_batch: int = 8              # max requests per prefill group
    prefill_chunk: int = 64             # tokens per prefill chunk
    # speculative decoding (None = off; "ngram" | "self", drafters.py)
    drafter: Optional[str] = None
    draft_k: int = 4                    # drafted tokens per verify round
    draft_layers: int = 2               # "self": target-model prefix depth
    draft_ngram: int = 2                # "ngram": match gram length
    draft_hist: int = 64                # "ngram": history ring length
    draft_verify: str = "scan"          # "scan" (bit-exact vs plain decode)
                                        # | "batched" (one masked forward)
    # prefix cache (radix tree over token-ID prefixes; admission reuses
    # the longest cached prefix and prefills only the suffix -- greedy
    # output stays token-identical to a cold prefill). KV families page
    # the ring; recurrent families checkpoint conv/SSM state at prefill
    # chunk boundaries (page size == prefill_chunk there).
    prefix_cache: bool = False
    prefix_page: int = 16               # positions per page (clamped to a
                                        # divisor of the KV ring length;
                                        # recurrent families override it
                                        # with the prefill chunk)
    prefix_bytes: int = 64 << 20        # device byte budget for the pool
    # tensor parallelism: run every jitted serving program via shard_map
    # over a ("model",) mesh of this many devices. Lane-only sharding
    # (packed payload lanes / heads / ffn hidden over the mesh, K rows
    # whole per shard) + one exact lane all-gather per projection
    # keeps greedy output token-identical across tp degrees (see
    # distributed/sharding.py). CPU testing: export
    # XLA_FLAGS=--xla_force_host_platform_device_count=N first.
    # SLO-aware admission: with max_queue > 0, submit() rejects instead of
    # growing the queue without bound (EngineSaturated, reason
    # "queue_full"); with the prefix cache on it also rejects when the
    # queued prompts' combined KV-page demand exceeds the whole page pool
    # ("page_pool_saturated" -- admission would thrash the pool). 0 keeps
    # the historical unbounded-queue behavior.
    max_queue: int = 0
    # preempt-by-slot: when every slot is busy and the queue head has
    # STRICTLY higher priority than some running request, cancel the
    # lowest-priority (then youngest) victim to free its slot. Equal
    # priorities never preempt, so single-priority workloads (the parity-
    # pinned default) are unaffected.
    preempt: bool = False
    tp: int = 1
    tp_matmul: str = "padded"           # "padded" (bit-exact vs tp=1: the
                                        # gemm keeps the single-device
                                        # shape; weights/cache sharded,
                                        # FLOPs replicated) | "sliced"
                                        # (1/size FLOPs per shard, equal
                                        # to within an f32 ulp) |
                                        # "sliced_row" (sliced + row-
                                        # parallel o-/down-proj: half the
                                        # collectives per layer, equal to
                                        # within ~a few activation-dtype
                                        # ulps -- f32-ulp when the model
                                        # runs f32; the fast path on
                                        # collective-bound meshes)
    tp_ep: bool = True                  # MoE expert parallelism under tp:
                                        # shard the expert stacks over the
                                        # model axis when n_experts
                                        # divides tp (bit-identical to the
                                        # replicated path; see
                                        # distributed/sharding.py). False
                                        # forces replicated experts.


@dataclasses.dataclass
class KVPages:
    """Host-memory snapshot of a prompt's full KV pages, the hand-off
    unit for disaggregated serving (serving/disagg.py): bit-for-bit
    copies of page-pool rows (all layers, int8 scales included), plus
    the token ids they cover so the receiving engine can re-key them in
    its own radix tree. ``payload`` entries are pool-layout arrays with
    the page axis at dim 1 -- e.g. ``k``: (L, n_pages, page, KH, Dh) --
    where page q covers positions [q*page, (q+1)*page)."""
    page: int
    tokens: List[int]
    payload: Dict[str, np.ndarray]

    @property
    def n_pages(self) -> int:
        return len(self.tokens) // self.page


class EngineSaturated(RuntimeError):
    """submit() backpressure rejection (ServeConfig.max_queue > 0).

    ``reason`` is machine-readable -- "queue_full" (the bounded queue is
    at capacity) or "page_pool_saturated" (the queued prompts' combined
    KV-page demand already exceeds the prefix-cache pool, so admitting
    more would only thrash it) -- and ``detail`` is the human-readable
    explanation. Front-ends map this to a structured 429."""

    def __init__(self, reason: str, detail: str):
        super().__init__(f"{reason}: {detail}")
        self.reason = reason
        self.detail = detail


@dataclasses.dataclass
class Request:
    id: int
    prompt: List[int]
    max_new_tokens: int
    on_token: Optional[Callable[[int, int], None]] = None
    speculate: bool = False
    priority: int = 0                   # higher drains first
    deadline_s: Optional[float] = None  # TTFT SLO, relative to submit_t
    on_done: Optional[Callable[["Request"], None]] = None
    tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    cancelled: bool = False
    preempted: bool = False             # cancelled to free its slot for a
                                        # strictly-higher-priority request
    deadline_missed: bool = False       # first token landed past deadline
    submit_t: Optional[float] = None    # perf_counter at submit() -- the
                                        # arrival stamp TTFT is measured
                                        # from (survives the disagg
                                        # prefill->decode hand-off via
                                        # submit(arrival_t=))
    ttft_s: Optional[float] = None      # first token - submit_t
    queue_wait_s: Optional[float] = None  # submit -> prefill start

    def _emit(self, tok: int) -> None:
        self.tokens.append(tok)
        if self.on_token is not None:
            self.on_token(self.id, tok)


class Engine:
    def __init__(self, cfg: ModelConfig, params, serve_cfg: ServeConfig):
        for field in ("max_slots", "decode_chunk", "max_new_tokens",
                      "cache_len", "prefill_batch", "prefill_chunk", "tp"):
            if getattr(serve_cfg, field) < 1:
                raise ValueError(f"ServeConfig.{field} must be >= 1, got "
                                 f"{getattr(serve_cfg, field)}")
        self.cfg = cfg
        self.params = params
        self.scfg = serve_cfg
        self._B = serve_cfg.max_slots
        # ring length must match init_cache's clamp or slot scatter would
        # write a cache_len-long update into a window-long ring
        self._T = T.attn_cache_len(cfg, serve_cfg.cache_len)
        # ONE validation pass over the family x feature matrix replaces
        # the old scattered per-feature "needs a KV-ring family" gates
        self._caps = validate_serve_features(
            cfg, tp=serve_cfg.tp, drafter=serve_cfg.drafter is not None,
            prefix_cache=serve_cfg.prefix_cache)
        self._state = DecodeState(cfg)
        self._kv_family = self._caps.kv_ring
        # prefill chunk length. Recurrent families pin a FIXED chunk grid
        # (clamped down to a divisor of the ring): the SSD scan's numerics
        # depend on chunk-boundary placement, so a shared absolute grid is
        # what makes batched prefill bit-identical to sequential admission
        # and warm (checkpoint) admission bit-identical to cold -- and it
        # means ONE compiled program serves every prompt length
        chunk = max(1, min(serve_cfg.prefill_chunk, self._T))
        if self._caps.recurrent:
            while self._T % chunk:
                chunk -= 1
        self._chunk = chunk
        # -- tensor parallelism: a ("model",) mesh every jitted serving
        # program runs over via shard_map. Weights lane-shard (K whole
        # per shard -- packed super-blocks never straddle devices), the
        # KV cache shards over kv_heads, and each projection's output is
        # assembled by one exact lane all-gather, so greedy output
        # stays token-identical across tp degrees.
        self._mesh = None
        self._plan = SH.make_serve_tp_plan(cfg, 1,
                                           matmul=serve_cfg.tp_matmul)
        if serve_cfg.tp > 1:
            devs = jax.devices()
            if len(devs) < serve_cfg.tp:
                raise ValueError(
                    f"tp={serve_cfg.tp} needs {serve_cfg.tp} devices but "
                    f"jax sees {len(devs)}; on CPU export XLA_FLAGS="
                    "--xla_force_host_platform_device_count="
                    f"{serve_cfg.tp} before importing jax")
            self._plan = SH.make_serve_tp_plan(cfg, serve_cfg.tp,
                                               matmul=serve_cfg.tp_matmul,
                                               params=params,
                                               ep=serve_cfg.tp_ep)
            self._mesh = Mesh(np.asarray(devs[:serve_cfg.tp]),
                              (self._plan.axis,))
            self._pspecs = SH.serve_param_specs(params, self._plan)
            self.params = jax.device_put(
                params, SH.named(self._pspecs, self._mesh))
            ctmpl = jax.eval_shape(
                lambda: T.init_cache(cfg, self._B, self._T))
            self._cspecs = SH.serve_cache_specs(ctmpl, self._plan)
        self._drafter = None
        if serve_cfg.drafter is not None:
            if serve_cfg.draft_k < 1:
                raise ValueError("draft_k must be >= 1")
            if serve_cfg.draft_k + 1 > serve_cfg.decode_chunk:
                raise ValueError(
                    f"decode_chunk ({serve_cfg.decode_chunk}) must fit a "
                    f"whole verify round (draft_k + 1 = "
                    f"{serve_cfg.draft_k + 1}) or speculating slots can "
                    "never emit")
            if serve_cfg.draft_k + 1 > self._T:
                raise ValueError(
                    f"draft_k + 1 ({serve_cfg.draft_k + 1}) exceeds the KV "
                    f"ring ({self._T}); draft positions must map to "
                    "distinct ring rows")
            if serve_cfg.draft_verify not in ("scan", "batched"):
                raise ValueError(
                    f"draft_verify must be 'scan' or 'batched', got "
                    f"{serve_cfg.draft_verify!r}")
            self._drafter = make_drafter(serve_cfg.drafter, cfg, serve_cfg)
            P0 = jax.sharding.PartitionSpec()
            dspec = jax.tree.map(lambda _: P0,
                                 self._drafter.init_state_np(self._B))
            self._spec_chunk = self._tp_jit(
                self._spec_chunk_impl,
                rest_in=("cache",) + (P0,) * 7 + (dspec,),
                out_specs=("cache",) + (P0,) * 6 + (dspec,) + (P0,) * 3,
                donate=(1,))
            self._verify = jax.jit(self._verify_impl)
            self._propose_ref = jax.jit(
                lambda params, cache, ds, tok, pos, act:
                self._drafter.propose(params, self.cfg, cache, ds, tok,
                                      pos, act))
        self._prefix: Optional[PrefixCache] = None
        self._page: Optional[int] = None
        if serve_cfg.prefix_cache:
            if serve_cfg.prefix_page < 1:
                raise ValueError("prefix_page must be >= 1")
            if self._caps.prefix_mode == "checkpoints":
                # recurrent checkpoint pages: one pool row holds the
                # WHOLE conv/SSM state after the page's last token.
                # Pinning the page to the prefill chunk makes every
                # checkpoint exactly the inter-chunk carry the chunk
                # loop materializes anyway -- zero extra compute, and
                # warm restore is bit-identical to cold by construction
                page = self._chunk
            else:
                # pages must tile the ring exactly so a page never wraps
                # internally (position p % T stays page-contiguous)
                page = max(1, min(serve_cfg.prefix_page, self._T))
                while self._T % page:
                    page -= 1
            self._page = page
            cap = max(2, int(serve_cfg.prefix_bytes)
                      // self._state.page_bytes(page))
            self._prefix = PrefixCache(page, cap)
            self._pool = None           # device pool, allocated on 1st use
            self._prefix_scatter = jax.jit(self._prefix_scatter_impl,
                                           donate_argnums=(0,))
            self._prefix_insert = jax.jit(self._prefix_insert_impl,
                                          donate_argnums=(0,))
            if self._caps.prefix_mode == "checkpoints":
                self._state_scatter = jax.jit(self._state_scatter_impl,
                                              donate_argnums=(0,))
                self._state_insert = jax.jit(self._state_insert_impl,
                                             donate_argnums=(0,))
            # cross-engine page hand-off (export_kv_pages/import_kv_pages):
            # the same pool-copy programs, pointed at host memory
            self._pool_export = jax.jit(self._pool_export_impl)
            self._pool_import = jax.jit(self._pool_import_impl,
                                        donate_argnums=(0,))
        # (the group cache is NOT donated here: its (L,G,T,..) buffers can
        # never alias the (L,B,T,..) output, they'd just warn)
        self._admit_caches = jax.jit(self._admit_caches_impl,
                                     donate_argnums=(0,))
        P0 = jax.sharding.PartitionSpec()
        self._prefill_chunk = self._tp_jit(
            self._prefill_chunk_impl, rest_in=("cache",) + (P0,) * 5,
            out_specs=("cache", P0), donate=(1, 5))
        self._sample_first = jax.jit(self._sample_first_impl)
        self._bind_slots = jax.jit(self._bind_slots_impl)
        self._decode_chunk = self._tp_jit(
            self._decode_chunk_impl, rest_in=("cache",) + (P0,) * 6,
            out_specs=("cache",) + (P0,) * 6, donate=(1,))
        self._ref_step = jax.jit(self._ref_step_impl)
        self._cache = None
        self.stats: Dict[str, float] = {}
        self._reset()

    def _tp_jit(self, fn, rest_in, out_specs, donate=()):
        """jit, or jit(shard_map(...)) when a TP mesh is configured.

        ``fn`` must take ``params`` first; ``rest_in``/``out_specs`` are
        PartitionSpec pytrees for the remaining args/outputs, with the
        sentinel string "cache" standing for the decode-cache spec tree.
        Inside the shard the params pytree holds lane-local views
        (QTensor aux shapes relocalized to the lanes this shard owns) and
        the serve-TP plan is active, so layer code slices its local head
        counts and places the per-projection lane gathers."""
        if self._mesh is None:
            return jax.jit(fn, donate_argnums=donate)
        plan, pspecs = self._plan, self._pspecs
        sub = lambda s: self._cspecs if isinstance(s, str) else s
        rest_in = tuple(sub(s) for s in rest_in)
        out_specs = tuple(sub(s) for s in out_specs)

        def body(params, *rest):
            params = SH.localize_serve_params(params, pspecs, plan.size)
            with SH.serve_tp(plan):
                return fn(params, *rest)

        return jax.jit(
            _shard_map(body, mesh=self._mesh,
                       in_specs=(pspecs,) + rest_in,
                       out_specs=out_specs, check_rep=False),
            donate_argnums=donate)

    def _new_cache(self, B: int):
        """Fresh decode cache for ``B`` slots, placed with the TP cache
        sharding (KV payloads over kv_heads) when a mesh is configured so
        donation aliases shard-to-shard instead of warning."""
        cache = self._state.init(B, self._T)
        if self._mesh is not None:
            cache = jax.device_put(cache,
                                   SH.named(self._cspecs, self._mesh))
        return cache

    # -- jitted internals ----------------------------------------------------
    def _sample(self, logits, key):
        """logits (B,V) -> token ids (B,) int32."""
        if self.scfg.temperature > 0:
            return jax.random.categorical(
                key, logits / self.scfg.temperature).astype(jnp.int32)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def _admit_caches_impl(self, cache, group_cache, indices):
        return self._state.set_slots(cache, group_cache, indices)

    def _prefix_scatter_impl(self, gcache, pool, idx, rows, cols,
                             positions):
        """Copy pool pages ``idx`` (n,) into group-cache rows ``rows`` at
        ring slots ``cols`` (n, page), stamping ``positions``. Entries
        with cols >= T drop (batch padding / partial-page tails). Only
        ring-payload pool entries scatter here; a recurrent pool's
        conv/state checkpoints go through _state_scatter_impl instead
        (they are whole-state rows, not positional pages)."""
        keys = set(T._PAGE_KEYS)
        pages = {k: v[:, idx] for k, v in pool.items() if k in keys}
        return self._state.scatter_pages(gcache, pages, rows, cols,
                                         positions)

    def _prefix_insert_impl(self, pool, gcache, idx, rows, cols):
        """Copy freshly prefilled pages out of the group cache into pool
        rows ``idx`` (n,); idx >= capacity drops (batch padding). Pool
        entries the ring gather does not produce (a recurrent pool's
        conv/state checkpoints) pass through untouched."""
        pages = self._state.gather_pages(gcache, rows, cols)
        return {k: (pool[k].at[:, idx].set(pages[k], mode="drop")
                    if k in pages else pool[k])
                for k in pool}

    def _state_scatter_impl(self, gcache, pool, idx, rows):
        """Restore recurrent checkpoints: pool page rows ``idx`` (n,)
        into group-cache batch rows ``rows`` (n,); rows >= G drop."""
        return self._state.scatter_checkpoints(gcache, pool, idx, rows)

    def _state_insert_impl(self, pool, gcache, rows, idx):
        """Record recurrent checkpoints: group-cache batch rows ``rows``
        (n,) into pool page rows ``idx`` (n,); idx >= capacity drops."""
        return self._state.insert_checkpoints(pool, gcache, rows, idx)

    def _pool_export_impl(self, pool, idx):
        """Gather pool pages ``idx`` (n,) for a cross-engine hand-off --
        pure data movement, the export half of the disaggregation page
        migration (the host copy happens in export_kv_pages)."""
        return {k: v[:, idx] for k, v in pool.items()}

    def _pool_import_impl(self, pool, pages, idx):
        """Scatter imported pages into pool rows ``idx`` (n,) -- the
        cross-engine twin of _prefix_insert_impl, sourced from another
        engine's exported pages instead of a local group cache."""
        return {k: (pool[k].at[:, idx].set(pages[k].astype(pool[k].dtype))
                    if k in pages else pool[k])
                for k in pool}

    def _prefill_chunk_impl(self, params, gcache, tokens, start, lengths,
                            last_logits, cached):
        """One (G, C) prefill chunk + ragged last-token logit capture.

        ``start`` is traced, so every chunk index reuses one compilation.
        ``last_logits`` accumulates each row's logits at its true last
        prompt token (rows whose last token is not in this chunk pass
        through); the LM head runs on ONE gathered row per sequence, never
        on the full (G, C, V) block. ``cached`` (G,) marks each row's
        prefix-cache horizon: columns below it are already resident in
        the ring and are masked out of compute exactly like padding."""
        C = tokens.shape[1]
        h, gcache = T.prefill_chunk(params, self.cfg, gcache, tokens=tokens,
                                    start=start, lengths=lengths,
                                    cached_lengths=cached)
        last = lengths - 1
        off = jnp.clip(last - start, 0, C - 1)
        hr = jnp.take_along_axis(h, off[:, None, None], axis=1)[:, 0]
        logits = T.lm_logits(params, self.cfg, hr)          # (G, V) f32
        sel = (last >= start) & (last < start + C)
        return gcache, jnp.where(sel[:, None], logits, last_logits)

    def _sample_first_impl(self, last_logits, keys):
        """Per-row first-token sampling with per-request keys: row i uses
        the key the sequential path would have split for request i, so
        batched admission is token-identical to one-at-a-time admission."""
        samp = lambda lg, key: self._sample(lg[None], key)[0]
        return jax.vmap(samp)(last_logits, keys)

    def _bind_slots_impl(self, first, budgets, free_arr):
        """Device-side slot binding for a prefill group: rows that already
        finished at their first token (budget 1 / instant EOS; dummy rows
        carry budget 0) take NO slot, and survivors pack into ``free_arr``
        in group order -- the exact layout one-at-a-time admission yields
        (a slot's row index feeds the shared decode sampling key, so
        layout parity is what keeps batched admission token-identical
        under temperature). Returns scatter indices, out-of-range where
        unbound. On device so the cache scatter can be dispatched BEFORE
        the host syncs on the first tokens."""
        fin = budgets <= 1
        if self.scfg.eos_id is not None:
            fin = fin | (first == self.scfg.eos_id)
        alive = ~fin
        rank = jnp.cumsum(alive.astype(jnp.int32)) - 1
        nfree = free_arr.shape[0]
        return jnp.where(alive, free_arr[jnp.clip(rank, 0, nfree - 1)],
                         self._B)

    def _decode_chunk_impl(self, params, cache, tok, pos, live, n_gen,
                           budget, key):
        """Run up to ``decode_chunk`` decode steps on device.

        Carry: (step, cache, tok (B,), pos (B,), live (B,) bool,
        n_gen (B,), out (B,C), key).  Exits early once every slot is dead.
        ``out`` holds the tokens emitted this chunk, -1 where a slot was
        already dead at that step (so each row is a dense prefix).
        """
        C = self.scfg.decode_chunk
        B = tok.shape[0]
        out0 = jnp.full((B, C), -1, jnp.int32)

        def cond(st):
            step, _, _, _, live_, _, _, _ = st
            return (step < C) & jnp.any(live_)

        def body(st):
            step, cache_, tok_, pos_, live_, n_gen_, out_, key_ = st
            logits, cache_ = T.decode_step(params, self.cfg, cache_,
                                           tokens=tok_, position=pos_,
                                           live=live_)
            key_, sub = jax.random.split(key_)
            nxt = self._sample(logits, sub)
            nxt = jnp.where(live_, nxt, tok_)
            out_ = out_.at[:, step].set(jnp.where(live_, nxt, -1))
            n_gen_ = n_gen_ + live_.astype(jnp.int32)
            new_live = live_ & (n_gen_ < budget)
            if self.scfg.eos_id is not None:
                new_live = new_live & (nxt != self.scfg.eos_id)
            pos_ = pos_ + live_.astype(jnp.int32)
            return step + 1, cache_, nxt, pos_, new_live, n_gen_, out_, key_

        st = (jnp.zeros((), jnp.int32), cache, tok, pos, live, n_gen,
              out0, key)
        _, cache, tok, pos, live, n_gen, out, key = jax.lax.while_loop(
            cond, body, st)
        return cache, out, tok, pos, live, n_gen, key

    # -- speculative decode (draft -> verify -> accept -> rewind) ------------
    def _verify_impl(self, params, cache, tokens, positions, valid):
        """One verify pass over a (B, k+1) block -> (logits, cache).

        ``draft_verify="scan"`` (default) replays decode_step per column
        -- bit-identical numbers to plain decode, the basis of the greedy
        parity guarantee. ``"batched"`` scores the block in one masked
        prefill-style forward -- the throughput datapath, equal to within
        float rounding (a greedy argmax can flip on a near-tie)."""
        if self.scfg.draft_verify == "scan":
            return T.verify_scan(params, self.cfg, cache, tokens=tokens,
                                 positions=positions, valid=valid)
        h, cache = T.verify_chunk(params, self.cfg, cache, tokens=tokens,
                                  positions=positions, valid=valid)
        return T.lm_logits(params, self.cfg, h), cache

    def _accept_impl(self, logits, drafts, spec_eff, k_u, k_fin):
        """Per-slot draft acceptance. logits (B, k+1, V) scored over
        [cur_tok, d_1..d_k]; drafts (B, k); spec_eff (B,) marks slots that
        actually speculated this round (others accept 0 drafts and their
        "final" token is a plain col-0 sample/argmax).

        Greedy: accept the longest prefix where d_j == argmax; final token
        is the argmax after the last accepted draft (replacement on first
        mismatch, bonus when all k accepted) -- exactly the token chain
        plain greedy decode would emit, which is the parity guarantee.

        Temperature: rejection sampling against the point-mass draft
        distribution: accept d_j with prob p_j(d_j); on first rejection
        sample from p with the rejected draft's mass removed
        (renormalized); on full acceptance sample the bonus from p_k."""
        B, S, V = logits.shape
        k = S - 1
        if self.scfg.temperature > 0:
            lt = (logits / self.scfg.temperature).astype(jnp.float32)
            p = jax.nn.softmax(lt[:, :k], axis=-1)          # (B, k, V)
            pd = jnp.take_along_axis(p, drafts[:, :, None], 2)[..., 0]
            u = jax.random.uniform(k_u, (B, k))
            ok = (u < pd) & spec_eff[:, None]
            acc = jnp.sum(jnp.cumprod(ok.astype(jnp.int32), 1), 1)
            pl = jnp.take_along_axis(lt, acc[:, None, None], 1)[:, 0]
            pcol = jax.nn.softmax(pl, axis=-1)              # (B, V)
            dcol = jnp.take_along_axis(
                drafts, jnp.clip(acc, 0, k - 1)[:, None], 1)[:, 0]
            rejected = spec_eff & (acc < k)
            onehot = jnp.arange(V)[None] == dcol[:, None]
            resid = jnp.where(rejected[:, None] & onehot, 0.0, pcol)
            lr = jnp.where(resid > 0, jnp.log(resid), -jnp.inf)
            fin = jax.random.categorical(k_fin, lr).astype(jnp.int32)
            # degenerate guard: p put (numerically) ALL mass on the draft
            fin = jnp.where(jnp.any(resid > 0, -1), fin, dcol)
            return acc, fin
        g = jnp.argmax(logits, axis=-1).astype(jnp.int32)   # (B, S)
        ok = (drafts == g[:, :k]) & spec_eff[:, None]
        acc = jnp.sum(jnp.cumprod(ok.astype(jnp.int32), 1), 1)
        fin = jnp.take_along_axis(g, acc[:, None], 1)[:, 0]
        return acc, fin

    def _spec_chunk_impl(self, params, cache, tok, pos, live, spec, n_gen,
                         budget, key, dstate):
        """Speculative decode chunk: verify rounds inside one device loop.

        Each round drafts k tokens per speculating slot, snapshots the
        ring rows the draft block will write, scores [cur, d_1..d_k] in
        ONE masked verify forward, accepts a per-slot prefix, rewinds the
        rejected writes, and scatters the accepted tokens into the
        per-slot output at that slot's own cursor. Non-speculating live
        slots ride the same program as 1-column plain decode steps, so a
        continuous batch freely mixes speculative and plain sequences.
        The host still sees ONE sync per chunk.

        ``out`` rows are dense prefixes (-1 beyond each cursor); a slot
        pauses (stays live, stops emitting) when a whole verify round no
        longer fits its remaining chunk capacity."""
        C = self.scfg.decode_chunk
        k = self.scfg.draft_k
        S = k + 1
        B = tok.shape[0]
        Tring = self._T
        eos = self.scfg.eos_id
        cols = jnp.arange(S, dtype=jnp.int32)[None]
        bidx = jnp.arange(B)[:, None]
        out0 = jnp.full((B, C), -1, jnp.int32)
        zero = jnp.zeros((), jnp.int32)

        def spec_now(pos_):
            # full-attention archs must not let draft positions wrap the
            # ring (overwritten rows are still needed); slots within k of
            # the ring end fall back to plain steps for their last tokens
            return (spec if self.cfg.sliding_window
                    else spec & (pos_ + k < Tring))

        def active(live_, nout_, pos_):
            need = jnp.where(spec_now(pos_), S, 1)
            return live_ & (nout_ + need <= C)

        def cond(st):
            _, _, pos_, live_, _, _, nout_, _, _, _, _, _ = st
            return jnp.any(active(live_, nout_, pos_))

        def body(st):
            (cache_, tok_, pos_, live_, n_gen_, out_, nout_, key_, ds_,
             dtot_, dacc_, rounds_) = st
            act = active(live_, nout_, pos_)
            spec_ok = spec_now(pos_)
            spec_eff = act & spec_ok
            key_, k_u, k_fin = jax.random.split(key_, 3)
            drafts, ds_ = self._drafter.propose(
                params, self.cfg, cache_, ds_, tok_, pos_, spec_eff)
            x = jnp.concatenate([tok_[:, None], drafts], axis=1)   # (B,S)
            positions = pos_[:, None] + cols
            valid = act[:, None] & ((cols == 0) | spec_eff[:, None])
            slots = positions % Tring
            snap = self._state.ring_snapshot(cache_, slots)
            logits, cache_ = self._verify_impl(params, cache_, x,
                                               positions, valid)
            acc, fin = self._accept_impl(logits, drafts, spec_eff,
                                         k_u, k_fin)
            # emitted block: accepted drafts then the final token
            draftsp = jnp.concatenate([drafts, drafts[:, -1:]], 1)
            emit = jnp.where(cols < acc[:, None], draftsp, fin[:, None])
            e = jnp.minimum(acc + 1, budget - n_gen_)
            if eos is not None:
                hit = (emit == eos) & (cols < e[:, None])
                has = jnp.any(hit, axis=1)
                first = jnp.argmax(hit, axis=1).astype(jnp.int32)
                e = jnp.where(has, jnp.minimum(e, first + 1), e)
            e = jnp.where(act, e, 0)
            # per-slot scatter at each row's own cursor
            osel = jnp.where(cols < e[:, None], nout_[:, None] + cols, C)
            out_ = out_.at[bidx, osel].set(emit, mode="drop")
            # un-write rejected draft entries (t0 + acc accepted ones stay)
            keep = jnp.where(act, 1 + acc, 0)
            cache_ = self._state.ring_rewind(cache_, snap, slots, keep)
            n_gen_ = n_gen_ + e
            pos_ = pos_ + e
            last = jnp.take_along_axis(
                emit, jnp.clip(e - 1, 0, S - 1)[:, None], 1)[:, 0]
            tok_ = jnp.where(e > 0, last, tok_)
            died = n_gen_ >= budget
            if eos is not None:
                died = died | jnp.any((emit == eos) & (cols < e[:, None]),
                                      axis=1)
            live_ = jnp.where(act, live_ & ~died, live_)
            nout_ = nout_ + e
            ds_ = self._drafter.update(ds_, emit, e)
            dtot_ = dtot_ + jnp.sum(jnp.where(spec_eff, k, 0))
            dacc_ = dacc_ + jnp.sum(jnp.where(spec_eff, acc, 0))
            return (cache_, tok_, pos_, live_, n_gen_, out_, nout_, key_,
                    ds_, dtot_, dacc_, rounds_ + 1)

        st = (cache, tok, pos, live, n_gen, out0,
              jnp.zeros((B,), jnp.int32), key, dstate, zero, zero, zero)
        (cache, tok, pos, live, n_gen, out, _, key, dstate, dtot, dacc,
         rounds) = jax.lax.while_loop(cond, body, st)
        return (cache, out, tok, pos, live, n_gen, key, dstate, dtot,
                dacc, rounds)

    def _ref_step_impl(self, params, cache, tok, pos, live, key):
        """One host-driven decode step (reference path)."""
        logits, cache = T.decode_step(params, self.cfg, cache, tokens=tok,
                                      position=pos, live=live)
        nxt = self._sample(logits, key)
        return jnp.where(live, nxt, tok), cache

    # -- host-side scheduler -------------------------------------------------
    def _reset(self) -> None:
        B = self._B
        self._queue: collections.deque = collections.deque()
        self._slots: List[Optional[Request]] = [None] * B
        self._admitting: List[Request] = []
        self._results: Dict[int, Request] = {}
        self._next_id = 0
        self._key = jax.random.PRNGKey(self.scfg.seed)
        self._tok = np.zeros(B, np.int32)
        self._pos = np.zeros(B, np.int32)
        self._live = np.zeros(B, bool)
        self._ngen = np.zeros(B, np.int32)
        self._budget = np.full(B, self.scfg.max_new_tokens, np.int32)
        self._spec = np.zeros(B, bool)
        self._dstate: Dict[str, np.ndarray] = (
            self._drafter.init_state_np(B) if self._drafter else {})
        self._run_t0: Optional[float] = None
        self.stats = self._fresh_stats()

    @staticmethod
    def _fresh_stats() -> Dict[str, float]:
        return dict(prefill_s=0.0, decode_s=0.0, tokens=0, tok_per_s=0.0,
                    host_syncs=0, admissions=0, chunks=0,
                    requests=0, prefill_groups=0, prefill_tokens=0,
                    prefill_tok_per_s=0.0, ttft_s=0.0,
                    ttft_p50_s=0.0, ttft_p99_s=0.0, queue_wait_s=0.0,
                    deadline_misses=0, preemptions=0,
                    draft_tokens=0, draft_accepted=0, accept_rate=0.0,
                    spec_rounds=0, prefix_hits=0, prefix_tokens_reused=0,
                    prefix_evictions=0, prefix_insert_drops=0)

    def submit(self, prompt: List[int],
               max_new_tokens: Optional[int] = None,
               on_token: Optional[Callable[[int, int], None]] = None,
               speculate: Optional[bool] = None,
               priority: int = 0,
               deadline_s: Optional[float] = None,
               on_done: Optional[Callable[[Request], None]] = None,
               arrival_t: Optional[float] = None) -> int:
        """Queue a request; returns its id. Tokens stream via ``on_token``
        (called as on_token(request_id, token)) if given. ``speculate``
        toggles speculative decoding per request (default: on whenever the
        engine has a drafter configured).

        SLO fields: ``priority`` (higher drains first; strictly-higher
        priority may preempt under ServeConfig.preempt), ``deadline_s``
        (TTFT deadline relative to arrival -- orders the queue within a
        priority stratum and feeds the ``deadline_misses`` stat),
        ``on_done`` (called exactly once with the Request when it
        finishes, is cancelled, or is preempted). ``arrival_t`` overrides
        the arrival stamp (perf_counter clock) so a hand-off between
        engines -- disaggregated prefill->decode -- preserves the
        original arrival time instead of restarting the TTFT clock.
        Raises EngineSaturated when ServeConfig.max_queue > 0 and the
        queue (or the prefix-cache page pool) is saturated."""
        if not prompt:
            raise ValueError("empty prompt")
        budget = (self.scfg.max_new_tokens if max_new_tokens is None
                  else max_new_tokens)
        if budget < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got {budget}")
        if speculate is None:
            speculate = self._drafter is not None
        elif speculate and self._drafter is None:
            raise ValueError("speculate=True needs ServeConfig.drafter")
        if (self._caps.ring_bounded_context and not self.cfg.sliding_window
                and len(prompt) + budget > self._T):
            # full-attention archs must not wrap the KV ring (that would
            # silently truncate context); windowed archs wrap by design
            # (the ring IS the window) and take prompts of any length
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new_tokens ({budget}) "
                f"exceeds cache_len {self._T}; raise ServeConfig.cache_len")
        if self.scfg.max_queue > 0:
            if len(self._queue) >= self.scfg.max_queue:
                raise EngineSaturated(
                    "queue_full",
                    f"queue holds {len(self._queue)} requests "
                    f"(ServeConfig.max_queue={self.scfg.max_queue})")
            if self._prefix is not None:
                pages = lambda n: -(-n // self._page)
                demand = pages(len(prompt)) + sum(
                    pages(len(r.prompt)) for r in self._queue)
                if demand > self._prefix.capacity:
                    raise EngineSaturated(
                        "page_pool_saturated",
                        f"queued prompts need {demand} KV pages, pool "
                        f"capacity is {self._prefix.capacity} "
                        "(raise ServeConfig.prefix_bytes or shed load)")
        req = Request(id=self._next_id, prompt=list(prompt),
                      max_new_tokens=budget, on_token=on_token,
                      speculate=speculate, priority=int(priority),
                      deadline_s=deadline_s, on_done=on_done,
                      submit_t=(time.perf_counter() if arrival_t is None
                                else arrival_t))
        self._next_id += 1
        self._queue.append(req)
        return req.id

    def cancel(self, request_id: int) -> bool:
        """Cancel a request. Still queued: it never runs. Already in a
        slot: the slot is freed at the next chunk boundary and tokens
        emitted so far are kept. Either way the request shows up in this
        cycle's results with ``cancelled=True``. Returns False for ids
        that are unknown or already finished."""
        for req in self._queue:
            if req.id == request_id:
                self._queue.remove(req)
                self._finish(req, cancelled=True)
                return True
        for i, req in enumerate(self._slots):
            if req is not None and req.id == request_id:
                self._live[i] = False
                self._slots[i] = None
                self._finish(req, cancelled=True)
                return True
        # mid-admission: a group-mate's first-token callback cancels a
        # request whose prefill already ran but whose slot is not bound
        # yet -- it never binds and never emits (same observable result as
        # cancelling it while queued)
        for req in self._admitting:
            if req.id == request_id and not req.done:
                self._finish(req, cancelled=True)
                return True
        return False

    def _finish(self, req: Request, cancelled: bool = False) -> None:
        """Single completion point -- normal finish, cancel, and
        preemption all land here, so ``on_done`` fires exactly once."""
        if req.done:
            return
        req.done = True
        if cancelled:
            req.cancelled = True
        self._results[req.id] = req
        if req.on_done is not None:
            req.on_done(req)

    def _note_first_token(self, req: Request) -> None:
        # TTFT is measured from the request's ARRIVAL (submit_t), not from
        # run() entry: the old run()-entry stamp inflated every mid-cycle
        # arrival's TTFT by its queue position in the cycle and made
        # latency-under-load curves unmeasurable. _run_t0 remains only as
        # a fallback for requests that never went through submit().
        now = time.perf_counter()
        if req.submit_t is not None:
            req.ttft_s = now - req.submit_t
        elif self._run_t0 is not None:
            req.ttft_s = now - self._run_t0
        if (req.deadline_s is not None and req.submit_t is not None
                and now - req.submit_t > req.deadline_s):
            req.deadline_missed = True
            self.stats["deadline_misses"] += 1

    def _start_slot(self, slot: int, req: Request, first_tok: int,
                    prompt_len: int) -> bool:
        """Record a freshly prefilled request; returns True if the slot
        ended up free (finished at its first token, or cancelled from its
        own first-token callback). The slot is bound BEFORE the token is
        emitted so cancel() called inside on_token can find and free it."""
        self._note_first_token(req)
        self._slots[slot] = req
        self._tok[slot] = first_tok
        self._pos[slot] = prompt_len
        self._live[slot] = True
        self._ngen[slot] = 1
        self._budget[slot] = req.max_new_tokens
        self._spec[slot] = req.speculate
        if self._drafter is not None:
            # drafter history covers prompt + first token for EVERY slot
            # (cheap, and per-request speculation toggles stay honest)
            self._drafter.admit_np(self._dstate, slot,
                                   req.prompt + [first_tok])
        req._emit(first_tok)
        if self._slots[slot] is not req:        # cancelled during emit
            return True
        if req.max_new_tokens <= 1 or (
                self.scfg.eos_id is not None
                and first_tok == self.scfg.eos_id):
            self._live[slot] = False
            self._slots[slot] = None
            self._finish(req)
            return True
        return False

    # -- admission: batched chunked prefill (KV-cache families) --------------
    def _group_shape(self, lens: List[int]):
        """(padded len P, chunk len C, padded group size Gp).

        P is the group max rounded up to ``prefill_bucket`` (one compiled
        shape per bucket) and, past ``prefill_chunk``, to a multiple of the
        chunk length (ONE compiled shape covers every longer prompt).
        Group size pads to a power of two capped at ``prefill_batch``.

        Recurrent families never shrink the chunk to the bucket: their
        chunk grid is FIXED (self._chunk, a divisor of the ring) so every
        prompt -- batched or sequential, warm or cold -- sees the same
        absolute chunk boundaries, which the SSD scan's numerics (and the
        checkpoint page identity) depend on."""
        b = max(self.scfg.prefill_bucket, 1)
        maxb = max(-(-n // b) * b for n in lens)
        C = self._chunk
        if self._caps.recurrent or maxb > C:
            P = -(-maxb // C) * C
        else:
            P = C = maxb
        Gp = 1 << max(len(lens) - 1, 0).bit_length()
        return P, C, min(max(Gp, 1), max(self.scfg.prefill_batch, 1))

    def _match_prefixes(self, reqs: List[Request]):
        """Radix-match every request's longest cached prefix. Returns
        (per-request matched lengths, page-scatter jobs) where each job is
        (group_row, pool_idx, start_pos, take): rows [0, take) of that
        page land in the ring (take < page is a partial-page hit)."""
        matches, jobs = [], []
        for i, r in enumerate(reqs):
            m, pages = self._prefix.match(r.prompt)
            # insertion is gated at prompt <= ring length, so a match can
            # never exceed the ring: every matched position has a live
            # slot and the batched scatter's destinations stay distinct
            assert m <= self._T, (m, self._T)
            matches.append(m)
            if not m:
                continue
            self.stats["prefix_hits"] += 1
            self.stats["prefix_tokens_reused"] += m
            for pidx, p0, take in pages:
                jobs.append((i, pidx, p0, take))
        return matches, jobs

    def _scatter_prefix_pages(self, gcache, jobs):
        """One batched gather->scatter of matched pool pages into the
        group cache (the copy in copy-on-write: slot rings only ever hold
        page copies, so later suffix writes never touch the pool)."""
        self._ensure_pool()
        page = self._page
        n = 1 << max(len(jobs) - 1, 0).bit_length()     # bucketed shapes
        idx = np.full(n, self._prefix.capacity, np.int32)
        rows = np.zeros(n, np.int32)
        cols = np.full((n, page), self._T, np.int32)    # T = drop
        pos = np.zeros((n, page), np.int32)
        ar = np.arange(page)
        for j, (row, pidx, p0, take) in enumerate(jobs):
            idx[j], rows[j] = pidx, row
            cols[j] = np.where(ar < take, (p0 + ar) % self._T, self._T)
            pos[j] = p0 + ar
        return self._prefix_scatter(gcache, self._pool, jnp.asarray(idx),
                                    jnp.asarray(rows), jnp.asarray(cols),
                                    jnp.asarray(pos))

    def _insert_prefix_pages(self, gcache, reqs, lens) -> None:
        """Record every request's full prompt pages in the radix tree and
        copy newly allocated ones out of the freshly prefilled group
        cache (async dispatch -- no host sync). Prompts longer than the
        ring skip insertion: their early pages were already overwritten
        by ring wrap."""
        ev0 = self._prefix.evictions
        dr0 = self._prefix.insert_drops
        jobs = []
        protect: set = set()        # shared across the group: one request's
        for i, r in enumerate(reqs):  # eviction must not recycle a pool
            if lens[i] <= self._T:    # index a group-mate just allocated
                jobs += [(i, pidx, p0)
                         for pidx, p0 in self._prefix.insert(r.prompt,
                                                             protect)]
        self.stats["prefix_evictions"] += self._prefix.evictions - ev0
        # a pool too small for the workload drops page insertions
        # silently (no behavior change: matching just misses later);
        # surface the count so saturated-pool runs are diagnosable
        self.stats["prefix_insert_drops"] += (self._prefix.insert_drops
                                              - dr0)
        if not jobs:
            return
        self._ensure_pool()
        page = self._page
        n = 1 << max(len(jobs) - 1, 0).bit_length()
        idx = np.full(n, self._prefix.capacity, np.int32)   # cap = drop
        rows = np.zeros(n, np.int32)
        cols = np.zeros((n, page), np.int32)
        ar = np.arange(page)
        for j, (row, pidx, p0) in enumerate(jobs):
            idx[j], rows[j] = pidx, row
            cols[j] = p0 + ar           # full in-ring pages never wrap
        self._pool = self._prefix_insert(self._pool, gcache,
                                         jnp.asarray(idx),
                                         jnp.asarray(rows),
                                         jnp.asarray(cols))

    # -- prefix cache, recurrent families: checkpoint pages ------------------
    def _match_checkpoints(self, reqs: List[Request]):
        """Checkpoint matching (recurrent families): only FULL pages count
        (a checkpoint is the state after a whole page of tokens), and the
        group shares ONE reuse horizon s0 = min over rows' full-page
        matches -- the chunk grid is group-wide, so a single cold row pins
        s0 to 0 and the whole group runs cold (shared-prefix traffic
        tends to arrive in groups, so the common case still reuses).
        Returns (s0, per-row full-page match lengths, hybrid ring-page
        scatter jobs covering [0, s0), checkpoint restore jobs
        (row, pool_idx) for each row's page ending at s0)."""
        page = self._page
        raw = [self._prefix.match(r.prompt) for r in reqs]
        fulls = [(m // page) * page for m, _ in raw]
        s0 = min(fulls)
        if s0 == 0:
            return 0, fulls, [], []
        pjobs, ckpt_jobs = [], []
        # of the recurrent families only hybrid carries an attention ring
        # (the capability that also makes its context ring-bounded)
        has_ring = self._caps.ring_bounded_context
        for i, (m, pages) in enumerate(raw):
            self.stats["prefix_hits"] += 1
            self.stats["prefix_tokens_reused"] += s0
            for pidx, p0, take in pages:
                if take != page or p0 + page > s0:
                    continue            # partial page, or past the horizon
                if has_ring:
                    pjobs.append((i, pidx, p0, take))
                if p0 + page == s0:
                    ckpt_jobs.append((i, pidx))
        return s0, fulls, pjobs, ckpt_jobs

    def _scatter_checkpoints(self, gcache, jobs, Gp: int):
        """One batched device copy restoring each warm row's conv/SSM
        state from the checkpoint of the page ending at the group horizon
        (bucketed shapes; row pads point at Gp = drop)."""
        self._ensure_pool()
        n = 1 << max(len(jobs) - 1, 0).bit_length()
        idx = np.zeros(n, np.int32)
        rows = np.full(n, Gp, np.int32)
        for j, (row, pidx) in enumerate(jobs):
            idx[j], rows[j] = pidx, row
        return self._state_scatter(gcache, self._pool, jnp.asarray(idx),
                                   jnp.asarray(rows))

    def _plan_checkpoint_inserts(self, reqs, lens, fulls, s0: int):
        """Record the group's prompt pages in the radix tree BEFORE the
        chunk loop runs. A recurrent page's payload is an inter-chunk
        state snapshot that exists only transiently (the next chunk call
        donates the group cache), so each new page's checkpoint copy must
        be dispatched right after the chunk that produces it. Returns
        ({chunk index -> [(row, pool_idx)]}, hybrid ring-payload gather
        jobs (row, pool_idx, start_pos) for the same new pages)."""
        page = self._page
        ev0 = self._prefix.evictions
        dr0 = self._prefix.insert_drops
        protect: set = set()
        # protect pass: walk every row's matched chain into the shared
        # protect set first, so an earlier row's insert can never evict a
        # page a group-mate matched -- a re-inserted pre-horizon page
        # would have no checkpoint source in this run's chunk grid
        for i, r in enumerate(reqs):
            if fulls[i]:
                self._prefix.insert(r.prompt[:fulls[i]], protect)
        by_chunk: Dict[int, list] = {}
        kv_jobs: List = []
        has_ring = self._caps.ring_bounded_context
        for i, r in enumerate(reqs):
            if has_ring and lens[i] > self._T:
                continue    # hybrid: ring wrap clobbered the early pages
            for pidx, p0 in self._prefix.insert(r.prompt, protect):
                j = (p0 - s0) // page   # grid chunk whose output is the
                by_chunk.setdefault(j, []).append((i, pidx))  # checkpoint
                if has_ring:
                    kv_jobs.append((i, pidx, p0))
        self.stats["prefix_evictions"] += self._prefix.evictions - ev0
        self.stats["prefix_insert_drops"] += (self._prefix.insert_drops
                                              - dr0)
        return by_chunk, kv_jobs

    def _insert_checkpoints(self, gcache, jobs) -> None:
        """Copy inter-chunk conv/SSM state into pool checkpoint rows.
        Async dispatch that MUST precede the next chunk call (which
        donates the group cache the snapshot is read from)."""
        self._ensure_pool()
        n = 1 << max(len(jobs) - 1, 0).bit_length()
        idx = np.full(n, self._prefix.capacity, np.int32)   # cap = drop
        rows = np.zeros(n, np.int32)
        for j, (row, pidx) in enumerate(jobs):
            idx[j], rows[j] = pidx, row
        self._pool = self._state_insert(self._pool, gcache,
                                        jnp.asarray(rows),
                                        jnp.asarray(idx))

    def _insert_ring_pages(self, gcache, jobs) -> None:
        """Copy the ring payload of freshly recorded hybrid pages out of
        the prefilled group cache. The radix insert already ran in
        _plan_checkpoint_inserts -- this is only the KV half of each new
        page (its checkpoint half landed chunk by chunk)."""
        self._ensure_pool()
        page = self._page
        n = 1 << max(len(jobs) - 1, 0).bit_length()
        idx = np.full(n, self._prefix.capacity, np.int32)   # cap = drop
        rows = np.zeros(n, np.int32)
        cols = np.zeros((n, page), np.int32)
        ar = np.arange(page)
        for j, (row, pidx, p0) in enumerate(jobs):
            idx[j], rows[j] = pidx, row
            cols[j] = p0 + ar           # full in-ring pages never wrap
        self._pool = self._prefix_insert(self._pool, gcache,
                                         jnp.asarray(idx),
                                         jnp.asarray(rows),
                                         jnp.asarray(cols))

    def _ensure_pool(self) -> None:
        if self._pool is None:
            self._pool = self._state.page_pool(self._prefix.capacity,
                                               self._page)
            if self._mesh is not None:
                # page payloads co-shard with the ring (kv_heads axis) so
                # page gather/scatter stays collective-free under GSPMD
                pspec = SH.serve_cache_specs(self._pool, self._plan)
                self._pool = jax.device_put(
                    self._pool, SH.named(pspec, self._mesh))

    # -- cross-engine KV hand-off (disaggregated serving) --------------------
    @property
    def prefix_page(self) -> Optional[int]:
        """Positions per KV page (None when the prefix cache is off)."""
        return self._page if self._prefix is not None else None

    def prefix_match_len(self, tokens: List[int]) -> int:
        """Router probe: how many leading tokens of ``tokens`` this
        engine's radix tree already holds (0 with the cache off). Pure
        host state, no LRU side effects -- a KV-aware router scores every
        worker with this before routing (serving/router.py)."""
        if self._prefix is None:
            return 0
        return self._prefix.match_len(list(tokens))

    def export_kv_pages(self, tokens: List[int]) -> KVPages:
        """Copy the full KV pages this engine has cached for ``tokens``
        out to host memory, page-granular and bit-for-bit (int8-KV scales
        included). The chain covers whole pages from position 0 up to the
        first miss; a prompt this engine just prefilled (with the prefix
        cache on) exports every full page of itself. This is the sending
        half of the disaggregation hand-off: the pages land in another
        engine via ``import_kv_pages`` and are reused through its
        ordinary (parity-pinned) prefix-cache admission."""
        if self._prefix is None:
            raise RuntimeError(
                "export_kv_pages needs ServeConfig.prefix_cache=True: the "
                "page pool is the export source")
        tokens = list(tokens)
        chain = self._prefix.page_chain(tokens)
        if not chain:
            return KVPages(page=self._page, tokens=[], payload={})
        self._ensure_pool()
        idx = jnp.asarray(np.array([i for i, _ in chain], np.int32))
        got = jax.device_get(self._pool_export(self._pool, idx))
        return KVPages(page=self._page,
                       tokens=tokens[:len(chain) * self._page],
                       payload={k: np.asarray(v) for k, v in got.items()})

    def import_kv_pages(self, kv: KVPages) -> int:
        """Adopt another engine's exported pages: record their token
        chain in this engine's radix tree and copy the payloads into its
        page pool (one async device scatter -- no host sync). Returns the
        number of pages actually imported; pages whose chain prefix is
        already resident are deduplicated (their bits are identical by
        construction: same params, same tokens, same prefill math), and a
        saturated pool drops the tail exactly like a local insert (the
        drop count rides the ``prefix_insert_drops`` stat). After an
        import, admitting a request with that prompt hits the prefix
        cache as if this engine had prefilled it itself -- which is the
        disaggregation parity argument in one sentence."""
        if self._prefix is None:
            raise RuntimeError(
                "import_kv_pages needs ServeConfig.prefix_cache=True: the "
                "page pool is the import destination")
        if kv.page != self._page:
            raise ValueError(
                f"page geometry mismatch: exported pages hold {kv.page} "
                f"positions, this engine's pool holds {self._page}")
        n = kv.n_pages
        if n == 0 or len(kv.tokens) > self._T:
            # mirror of the local insertion gate: prompts longer than the
            # ring would have had their early pages overwritten by wrap
            return 0
        drops0 = self._prefix.insert_drops
        new = self._prefix.insert(list(kv.tokens[:n * self._page]))
        self.stats["prefix_insert_drops"] += (self._prefix.insert_drops
                                              - drops0)
        if not new:
            return 0
        self._ensure_pool()
        src = np.array([p0 // self._page for _, p0 in new], np.int32)
        dst = np.array([i for i, _ in new], np.int32)
        pages = {k: jnp.asarray(v[:, src]) for k, v in kv.payload.items()}
        self._pool = self._pool_import(self._pool, pages,
                                       jnp.asarray(dst))
        return len(new)

    def _admit_group(self, slots: List[int], reqs: List[Request]) -> None:
        """Prefill ``reqs`` as one right-padded batch and scatter all their
        caches into ``slots`` with a single cache_set_slots call.

        With the prefix cache enabled, each request's longest cached
        prefix is scattered into its group-cache row page by page and the
        chunked prefill covers only [min cached length, padded max): the
        chunk grid starts at the group-wide reuse horizon, rows whose own
        horizon lies further right mask the overlap columns out of
        compute (``cached_lengths``), and the suffix length (not the full
        prompt) picks the bucketed chunk shape -- so shared-prefix groups
        skip most of their MatMul work while still emitting bit-identical
        KV rows and logits.

        Recurrent families run the same batched path on their FIXED chunk
        grid: warm rows restore the conv/SSM checkpoint at the group's
        shared full-page horizon s0 (hybrid also scatters the ring pages
        below it), the chunk loop starts at s0, and freshly recorded
        pages capture their checkpoints chunk by chunk (the inter-chunk
        carry, copied before the next chunk donates it)."""
        t0 = time.perf_counter()
        for r in reqs:
            if r.submit_t is not None:
                r.queue_wait_s = t0 - r.submit_t
        G = len(reqs)
        lens = [len(r.prompt) for r in reqs]
        pjobs: List = []
        ckpt_jobs: List = []
        ins_by_chunk: Dict[int, List] = {}
        kv_ins_jobs: List = []
        if self._prefix is None:
            matches, s0 = [0] * G, 0
        elif self._kv_family:
            matches, pjobs = self._match_prefixes(reqs)
            s0 = min(matches)
        else:
            # recurrent: whole-state checkpoints, full pages only, one
            # shared horizon; the per-column cached mask stays 0 (the
            # restored checkpoint replaces masking -- the chunk GRID
            # starts at s0 instead)
            s0, fulls, pjobs, ckpt_jobs = self._match_checkpoints(reqs)
            matches = [0] * G
            ins_by_chunk, kv_ins_jobs = self._plan_checkpoint_inserts(
                reqs, lens, fulls, s0)
        P, C, Gp = self._group_shape([n - s0 for n in lens])
        toks = np.zeros((Gp, s0 + P), np.int32)
        lengths = np.zeros(Gp, np.int32)            # dummy rows: length 0
        cached = np.zeros(Gp, np.int32)
        for i, r in enumerate(reqs):
            toks[i, :lens[i]] = r.prompt
            lengths[i] = lens[i]
            cached[i] = matches[i]
        # split one key per request IN QUEUE ORDER -- exactly the stream a
        # sequential (prefill_batch=1) admission loop would consume, so the
        # two schedules sample identical first tokens
        subs = []
        for _ in range(G):
            self._key, sub = jax.random.split(self._key)
            subs.append(sub)
        subs += [subs[-1]] * (Gp - G)               # dummies: never emitted
        if self._cache is None:
            self._cache = self._new_cache(self._B)
        gcache = self._new_cache(Gp)
        if ckpt_jobs:
            gcache = self._scatter_checkpoints(gcache, ckpt_jobs, Gp)
        if pjobs:
            gcache = self._scatter_prefix_pages(gcache, pjobs)
        last_logits = jnp.zeros((Gp, self.cfg.vocab_size), jnp.float32)
        lengths_d = jnp.asarray(lengths)
        cached_d = jnp.asarray(cached)
        for j in range(P // C):
            start = s0 + j * C
            gcache, last_logits = self._prefill_chunk(
                self.params, gcache, jnp.asarray(toks[:, start:start + C]),
                jnp.asarray(start, jnp.int32), lengths_d, last_logits,
                cached_d)
            if j in ins_by_chunk:
                # checkpoint copies ride the device queue here, BEFORE
                # the next chunk call donates (and so invalidates) the
                # group-cache buffers they read from
                self._insert_checkpoints(gcache, ins_by_chunk[j])
        first_d = self._sample_first(last_logits, jnp.stack(subs))
        budgets = np.zeros(Gp, np.int32)            # dummies: 0 -> unbound
        budgets[:G] = [r.max_new_tokens for r in reqs]
        # free list padded to Gp so compiled shapes track the group
        # BUCKET, not the exact group size (pad entries are never read:
        # survivor ranks stay < G)
        free_arr = np.full(Gp, self._B, np.int32)
        free_arr[:G] = slots
        idx_d = self._bind_slots(first_d, jnp.asarray(budgets),
                                 jnp.asarray(free_arr))
        self._cache = self._admit_caches(self._cache, gcache, idx_d)
        if self._prefix is not None:
            if self._kv_family:
                # record this group's prompt pages (async dispatch, rides
                # the same device queue -- admission stays one host sync)
                self._insert_prefix_pages(gcache, reqs, lens)
            elif kv_ins_jobs:
                # hybrid: ring payload of the pages recorded pre-loop
                self._insert_ring_pages(gcache, kv_ins_jobs)
        firsts = np.asarray(jax.device_get(first_d))   # 1 sync / GROUP
        # host-side mirror of _bind_slots_impl for the bookkeeping below
        free_iter = iter(slots)
        bound = [None if (req.max_new_tokens <= 1
                          or (self.scfg.eos_id is not None
                              and int(firsts[i]) == self.scfg.eos_id))
                 else next(free_iter) for i, req in enumerate(reqs)]
        self.stats["host_syncs"] += 1
        self.stats["prefill_groups"] += 1
        self.stats["admissions"] += G
        self.stats["prefill_tokens"] += sum(lens)
        self.stats["prefill_s"] += time.perf_counter() - t0
        self._admitting = reqs
        for i, req in enumerate(reqs):
            if req.cancelled:
                # cancelled from a group-mate's on_token callback after
                # its prefill but before its slot bound: never binds,
                # never emits (its scattered cache row is inert garbage)
                continue
            if bound[i] is None:
                self._note_first_token(req)
                req._emit(int(firsts[i]))
                self._finish(req)
            else:
                self._start_slot(bound[i], req, int(firsts[i]), lens[i])
        self._admitting = []

    @staticmethod
    def _admit_key(req: Request):
        """Queue drain order: priority strata (higher first), earliest
        absolute TTFT deadline within a stratum, then submission order --
        a queue with uniform priority and no deadlines therefore drains
        exactly FIFO, which is what keeps the parity-pinned default
        schedule (and its PRNG key-split order) unchanged."""
        dl = (req.submit_t + req.deadline_s
              if req.deadline_s is not None and req.submit_t is not None
              else float("inf"))
        return (-req.priority, dl, req.id)

    def _pop_pending(self, n: int) -> List[Request]:
        picked = sorted(self._queue, key=self._admit_key)[:n]
        for r in picked:
            self._queue.remove(r)
        return picked

    def _preempt_for(self, head: Request) -> bool:
        """Free one slot for ``head`` by cancelling the lowest-priority
        (then youngest) running request -- only when head's priority is
        STRICTLY higher, so equal-priority work never preempts and the
        single-priority default can never trigger this. The victim keeps
        its emitted tokens and completes with cancelled=True,
        preempted=True (the ordinary cancel contract)."""
        victims = [(req.priority, -req.id, i)
                   for i, req in enumerate(self._slots) if req is not None]
        if not victims:
            return False
        prio, _, i = min(victims)
        if head.priority <= prio:
            return False
        victim = self._slots[i]
        self._live[i] = False
        self._slots[i] = None
        victim.preempted = True
        self.stats["preemptions"] += 1
        self._finish(victim, cancelled=True)
        return True

    def _admit_pending(self) -> None:
        while self._queue:
            free = [i for i in range(self._B) if self._slots[i] is None]
            if not free:
                if not self.scfg.preempt:
                    return
                head = min(self._queue, key=self._admit_key)
                if not self._preempt_for(head):
                    return
                free = [i for i in range(self._B)
                        if self._slots[i] is None]
            n = min(len(free), max(self.scfg.prefill_batch, 1),
                    len(self._queue))
            self._admit_group(free[:n], self._pop_pending(n))

    def _run_chunk(self) -> None:
        t0 = time.perf_counter()
        if self._drafter is not None:
            dstate_d = {k: jnp.asarray(v) for k, v in self._dstate.items()}
            (self._cache, out_d, tok_d, pos_d, live_d, ngen_d, self._key,
             ds_d, dtot_d, dacc_d, rounds_d) = self._spec_chunk(
                self.params, self._cache, jnp.asarray(self._tok),
                jnp.asarray(self._pos), jnp.asarray(self._live),
                jnp.asarray(self._spec), jnp.asarray(self._ngen),
                jnp.asarray(self._budget), self._key, dstate_d)
            out, tok, pos, live, ngen, ds, dtot, dacc, rounds = \
                jax.device_get((out_d, tok_d, pos_d, live_d, ngen_d, ds_d,
                                dtot_d, dacc_d, rounds_d))  # THE sync
            self._dstate = {k: np.array(v) for k, v in ds.items()}
            self.stats["draft_tokens"] += int(dtot)
            self.stats["draft_accepted"] += int(dacc)
            self.stats["spec_rounds"] += int(rounds)
        else:
            self._cache, out_d, tok_d, pos_d, live_d, ngen_d, self._key = \
                self._decode_chunk(self.params, self._cache,
                                   jnp.asarray(self._tok),
                                   jnp.asarray(self._pos),
                                   jnp.asarray(self._live),
                                   jnp.asarray(self._ngen),
                                   jnp.asarray(self._budget), self._key)
            out, tok, pos, live, ngen = jax.device_get(
                (out_d, tok_d, pos_d, live_d, ngen_d))  # THE chunk sync
        # device_get hands back read-only buffers; admission mutates these
        self._tok, self._pos = np.array(tok), np.array(pos)
        self._live, self._ngen = np.array(live), np.array(ngen)
        self.stats["host_syncs"] += 1
        self.stats["chunks"] += 1
        self.stats["decode_s"] += time.perf_counter() - t0
        self._emit_chunk(out)

    def _emit_chunk(self, out: np.ndarray) -> None:
        """Stream each slot's dense token prefix; free finished slots."""
        for i, req in enumerate(self._slots):
            if req is None:
                continue
            for tok in out[i][out[i] >= 0].tolist():
                req._emit(tok)
                if self._slots[i] is None:      # on_token cancelled us
                    break
            if self._slots[i] is not None and not self._live[i]:
                self._finish(req)
                self._slots[i] = None               # slot freed -> eviction

    def _finalize_stats(self, done: Dict[int, List[int]]) -> None:
        """Derive rate stats with explicit zero-denominator guards: a run
        whose every request is cancelled from an ``on_token`` callback at
        admission never decodes (decode_s == 0 with tokens > 0 -- the old
        ``max(x, 1e-9)`` guard reported absurd rates there), and
        spec_rounds == 0 leaves draft_tokens at 0. All rates report 0.0
        in those cases.

        ``requests`` counts admissions over the whole cycle, not the
        queue length at run() entry: a request submitted from an
        ``on_token`` callback mid-cycle is served by this cycle and must
        be counted by it (the old entry-time stamp missed every one of
        them); a request cancelled while still queued is never admitted
        and is not a served request."""
        self.stats["requests"] = self.stats["admissions"]
        ntok = sum(len(t) for t in done.values())
        self.stats["tokens"] = ntok
        self.stats["tok_per_s"] = (
            ntok / self.stats["decode_s"]
            if self.stats["decode_s"] > 0 else 0.0)
        self.stats["prefill_tok_per_s"] = (
            self.stats["prefill_tokens"] / self.stats["prefill_s"]
            if self.stats["prefill_s"] > 0 else 0.0)
        ttfts = [r.ttft_s for r in self._results.values()
                 if r.ttft_s is not None]
        self.stats["ttft_s"] = sum(ttfts) / len(ttfts) if ttfts else 0.0
        # tail latency is the contested serving metric -- a mean hides the
        # queue-position tail entirely (every depth>1 row used to look
        # identical at p50 and p99 because both were the same mean)
        self.stats["ttft_p50_s"] = (
            float(np.percentile(ttfts, 50)) if ttfts else 0.0)
        self.stats["ttft_p99_s"] = (
            float(np.percentile(ttfts, 99)) if ttfts else 0.0)
        waits = [r.queue_wait_s for r in self._results.values()
                 if r.queue_wait_s is not None]
        self.stats["queue_wait_s"] = (
            sum(waits) / len(waits) if waits else 0.0)
        self.stats["accept_rate"] = (
            self.stats["draft_accepted"] / self.stats["draft_tokens"]
            if self.stats["draft_tokens"] > 0 else 0.0)

    def run(self, poll: Optional[Callable[[], None]] = None
            ) -> Dict[int, List[int]]:
        """Drive batched admission + fused decode chunks until queue and
        slots are drained. Returns {request_id: tokens} for THIS cycle;
        stats cover this cycle only (slots are always empty between run()
        calls, so resetting the counters here is safe).

        ``poll``, when given, is called once per scheduler iteration
        (before the drain check): a front-end or trace-driven load
        generator injects mid-cycle submits/cancels there -- arrivals land
        between chunks without any engine-side threading."""
        self.stats = self._fresh_stats()
        self._run_t0 = time.perf_counter()
        while True:
            if poll is not None:
                poll()
            if not (self._queue or any(r is not None
                                       for r in self._slots)):
                break
            self._admit_pending()
            if not self._live.any():
                continue
            self._run_chunk()
        done = {rid: req.tokens for rid, req in self._results.items()}
        self._finalize_stats(done)
        self._results = {}                  # next submit/run cycle is fresh
        self._run_t0 = None
        return done

    # -- public API ----------------------------------------------------------
    def generate(self, prompts: List[List[int]]) -> List[List[int]]:
        """Generate completions for a batch of prompts. Prompts beyond
        ``max_slots`` are continuously batched into freed slots. Resets
        engine state (fresh PRNG seed) for call-to-call determinism."""
        if self._queue:
            raise RuntimeError(
                f"{len(self._queue)} submitted request(s) pending; call "
                "run() to drain them before generate() (which resets)")
        self._reset()
        ids = [self.submit(list(p)) for p in prompts]
        res = self.run()
        return [res[i] for i in ids]

    def generate_reference(self,
                           prompts: List[List[int]]) -> List[List[int]]:
        """Pre-rewrite reference: same admission/prefill/sampling math but
        one host round-trip per token. O(tokens) syncs -- parity oracle
        for the on-device loop, not a serving path."""
        if len(prompts) > self._B:
            raise ValueError("reference path has no queue; "
                             f"need <= {self._B} prompts")
        if self._queue:
            raise RuntimeError(
                f"{len(self._queue)} submitted request(s) pending; call "
                "run() to drain them before generate_reference()")
        self._reset()
        ids = [self.submit(list(p)) for p in prompts]
        self._run_t0 = time.perf_counter()
        self._admit_pending()
        t0 = time.perf_counter()
        while self._live.any():
            self._key, sub = jax.random.split(self._key)
            nxt_d, self._cache = self._ref_step(
                self.params, self._cache, jnp.asarray(self._tok),
                jnp.asarray(self._pos), jnp.asarray(self._live), sub)
            nxt = np.asarray(jax.device_get(nxt_d))
            self.stats["host_syncs"] += 1
            for i, req in enumerate(self._slots):
                if req is None or not self._live[i]:
                    continue
                tok = int(nxt[i])
                req._emit(tok)
                self._ngen[i] += 1
                self._pos[i] += 1
                self._tok[i] = tok
                if (self._ngen[i] >= self._budget[i]
                        or (self.scfg.eos_id is not None
                            and tok == self.scfg.eos_id)):
                    self._live[i] = False
                    self._finish(req)
                    self._slots[i] = None
        self.stats["decode_s"] += time.perf_counter() - t0
        res = {rid: req.tokens for rid, req in self._results.items()}
        self._finalize_stats(res)
        self._results = {}
        self._run_t0 = None
        return [res[i] for i in ids]

    def generate_spec_reference(self,
                                prompts: List[List[int]]) -> List[List[int]]:
        """Host-driven speculative oracle: one verify ROUND per host trip,
        with acceptance, rejection sampling, truncation and rollback
        bookkeeping re-implemented in numpy against the raw logits. Same
        key-split discipline as the fused loop, so the two must agree
        token-for-token -- this is the validation target for temperature
        mode, where plain decode is no longer a token-level oracle.
        O(tokens) syncs; a parity tool, not a serving path."""
        if self._drafter is None:
            raise RuntimeError("generate_spec_reference needs a drafter")
        if len(prompts) > self._B:
            raise ValueError("reference path has no queue; "
                             f"need <= {self._B} prompts")
        if self._queue:
            raise RuntimeError(
                f"{len(self._queue)} submitted request(s) pending; call "
                "run() to drain them before generate_spec_reference()")
        self._reset()
        ids = [self.submit(list(p)) for p in prompts]
        self._run_t0 = time.perf_counter()
        self._admit_pending()
        C = self.scfg.decode_chunk
        k = self.scfg.draft_k
        S = k + 1
        B = self._B
        eos = self.scfg.eos_id
        temp = self.scfg.temperature
        cols = np.arange(S)[None]
        t0 = time.perf_counter()
        while self._live.any():
            nout = np.zeros(B, np.int32)            # fresh chunk capacity
            progressed = False
            while True:
                spec_ok = (self._spec if self.cfg.sliding_window
                           else self._spec & (self._pos + k < self._T))
                need = np.where(spec_ok, S, 1)
                act = self._live & (nout + need <= C)
                if not act.any():
                    break
                progressed = True
                spec_eff = act & spec_ok
                self._key, k_u, k_fin = jax.random.split(self._key, 3)
                ds_d = {kk: jnp.asarray(v)
                        for kk, v in self._dstate.items()}
                drafts_d, ds_d = self._propose_ref(
                    self.params, self._cache, ds_d,
                    jnp.asarray(self._tok), jnp.asarray(self._pos),
                    jnp.asarray(spec_eff))
                drafts = np.asarray(jax.device_get(drafts_d))
                x = np.concatenate([self._tok[:, None], drafts], axis=1)
                positions = self._pos[:, None] + cols
                valid = act[:, None] & ((cols == 0) | spec_eff[:, None])
                slots_d = jnp.asarray(positions % self._T)
                snap = T.cache_ring_snapshot(self._cache, slots_d)
                logits_d, self._cache = self._verify(
                    self.params, self._cache, jnp.asarray(x),
                    jnp.asarray(positions), jnp.asarray(valid))
                logits = np.asarray(jax.device_get(logits_d), np.float32)
                self.stats["host_syncs"] += 1
                # -- host acceptance (independent numpy re-implementation)
                if temp > 0:
                    lt = logits / temp
                    pm = np.exp(lt[:, :k]
                                - lt[:, :k].max(-1, keepdims=True))
                    pm = pm / pm.sum(-1, keepdims=True)
                    pd = np.take_along_axis(
                        pm, drafts[:, :, None], 2)[..., 0]
                    u = np.asarray(jax.random.uniform(k_u, (B, k)))
                    ok = (u < pd) & spec_eff[:, None]
                    acc = np.cumprod(ok, axis=1).sum(axis=1).astype(np.int32)
                    pl = np.take_along_axis(lt, acc[:, None, None], 1)[:, 0]
                    pcol = np.exp(pl - pl.max(-1, keepdims=True))
                    pcol = pcol / pcol.sum(-1, keepdims=True)
                    dcol = np.take_along_axis(
                        drafts, np.clip(acc, 0, k - 1)[:, None], 1)[:, 0]
                    rejected = spec_eff & (acc < k)
                    resid = pcol.copy()
                    resid[np.arange(B), dcol] = np.where(
                        rejected, 0.0, resid[np.arange(B), dcol])
                    with np.errstate(divide="ignore"):
                        lr = np.where(resid > 0, np.log(resid), -np.inf)
                    fin = np.asarray(jax.random.categorical(
                        k_fin, jnp.asarray(lr))).astype(np.int32)
                    fin = np.where((resid > 0).any(-1), fin, dcol)
                else:
                    g = logits.argmax(-1).astype(np.int32)
                    ok = (drafts == g[:, :k]) & spec_eff[:, None]
                    acc = np.cumprod(ok, axis=1).sum(axis=1).astype(np.int32)
                    fin = np.take_along_axis(g, acc[:, None], 1)[:, 0]
                draftsp = np.concatenate([drafts, drafts[:, -1:]], axis=1)
                emit = np.where(cols < acc[:, None], draftsp, fin[:, None])
                e = np.minimum(acc + 1, self._budget - self._ngen)
                if eos is not None:
                    hit = (emit == eos) & (cols < e[:, None])
                    has = hit.any(1)
                    first = hit.argmax(1).astype(np.int32)
                    e = np.where(has, np.minimum(e, first + 1), e)
                e = np.where(act, e, 0)
                keep = np.where(act, 1 + acc, 0)
                self._cache = T.cache_ring_rewind(
                    self._cache, snap, slots_d, jnp.asarray(keep))
                ds_d = self._drafter.update(ds_d, jnp.asarray(emit),
                                            jnp.asarray(e))
                self._dstate = {kk: np.array(v) for kk, v in
                                jax.device_get(ds_d).items()}
                self.stats["draft_tokens"] += int(spec_eff.sum()) * k
                self.stats["draft_accepted"] += int(acc[spec_eff].sum())
                self.stats["spec_rounds"] += 1
                for i, req in enumerate(self._slots):
                    if req is None or e[i] == 0:
                        continue
                    for t in emit[i, :e[i]].tolist():
                        req._emit(int(t))
                    self._ngen[i] += int(e[i])
                    self._pos[i] += int(e[i])
                    self._tok[i] = int(emit[i, e[i] - 1])
                    died = self._ngen[i] >= self._budget[i]
                    if eos is not None:
                        died = died or eos in emit[i, :e[i]].tolist()
                    if died:
                        self._live[i] = False
                        self._finish(req)
                        self._slots[i] = None
                nout = nout + e
            if not progressed:
                break
        self.stats["decode_s"] += time.perf_counter() - t0
        res = {rid: req.tokens for rid, req in self._results.items()}
        self._finalize_stats(res)
        self._results = {}
        self._run_t0 = None
        return [res[i] for i in ids]
