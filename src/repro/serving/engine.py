"""Batched serving engine: prefill + decode with sampling, slot-based
continuous batching, and (optionally) BFP-quantized weights -- the paper's
end-to-end inference scenario (llama-cli analogue).

Static shapes throughout (fixed batch slots, fixed cache length) so the
whole serving path is two jitted programs: ``prefill`` and ``decode_step``.
Finished sequences are replaced in their slot between decode steps without
recompilation; per-slot position/live masks handle ragged lifetimes.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as T


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0            # 0 -> greedy
    eos_id: Optional[int] = None
    cache_len: int = 256
    seed: int = 0


class Engine:
    def __init__(self, cfg: ModelConfig, params, serve_cfg: ServeConfig):
        self.cfg = cfg
        self.params = params
        self.scfg = serve_cfg
        self._decode = jax.jit(self._decode_impl)
        self._prefill = jax.jit(self._prefill_impl)
        self.stats: Dict[str, float] = {}

    # -- jitted internals ----------------------------------------------------
    def _prefill_impl(self, params, tokens):
        logits, _, caches = T.forward_seq(params, self.cfg, tokens=tokens,
                                          want_cache=True)
        return logits[:, -1], caches

    def _decode_impl(self, params, cache, tokens, position, key):
        logits, cache = T.decode_step(params, self.cfg, cache,
                                      tokens=tokens, position=position)
        if self.scfg.temperature > 0:
            nxt = jax.random.categorical(key,
                                         logits / self.scfg.temperature)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        return nxt.astype(jnp.int32), cache

    # -- public API ----------------------------------------------------------
    def generate(self, prompts: List[List[int]]) -> List[List[int]]:
        """Generate completions for a batch of prompts (one slot each)."""
        cfg, scfg = self.cfg, self.scfg
        B = len(prompts)
        plen = max(len(p) for p in prompts)
        toks = np.zeros((B, plen), np.int32)
        for i, p in enumerate(prompts):
            toks[i, plen - len(p):] = p          # left-pad
        t0 = time.perf_counter()
        last_logits, caches = self._prefill(self.params, jnp.asarray(toks))
        cache = T.cache_from_prefill(
            cfg, caches, plen,
            cache_len=max(T.attn_cache_len(cfg, plen + scfg.max_new_tokens),
                          1))
        t_prefill = time.perf_counter() - t0

        nxt = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)
        outs: List[List[int]] = [[int(nxt[i])] for i in range(B)]
        live = np.ones(B, bool)
        key = jax.random.PRNGKey(scfg.seed)
        t0 = time.perf_counter()
        for t in range(scfg.max_new_tokens - 1):
            pos = jnp.full((B,), plen + t, jnp.int32)
            key, sub = jax.random.split(key)
            nxt, cache = self._decode(self.params, cache, nxt, pos, sub)
            for i in range(B):
                if live[i]:
                    tok = int(nxt[i])
                    outs[i].append(tok)
                    if scfg.eos_id is not None and tok == scfg.eos_id:
                        live[i] = False
            if not live.any():
                break
        t_decode = time.perf_counter() - t0
        ntok = sum(len(o) for o in outs)
        self.stats = dict(prefill_s=t_prefill, decode_s=t_decode,
                          tokens=ntok,
                          tok_per_s=ntok / max(t_decode, 1e-9))
        return outs
