"""Explicit compressed gradient all-reduce (shard_map) with error feedback.

Under plain pjit the data-parallel gradient all-reduce happens inside the
backward pass at the accumulation dtype XLA chooses. For bandwidth-bound
scale-out, this module gives explicit control: gradients are cast to
``wire_dtype`` (bf16 halves DP traffic), psum'ed over the dp axes via
shard_map, and the quantization residual is carried to the next step
(error feedback), which keeps SGD unbiased in expectation.

Used by the train driver when ``--grad-compress`` is set; exercised by
tests/test_distributed.py on a multi-device host mesh.
"""
from __future__ import annotations

import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.sharding import dp_axes

# jax.shard_map only exists as a top-level API in newer jax releases
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _shard_map


def compressed_psum(grads: Any, mesh: Mesh, wire_dtype=jnp.bfloat16,
                    error: Optional[Any] = None) -> Tuple[Any, Any]:
    """All-reduce-mean ``grads`` over the dp axes at ``wire_dtype``.

    grads are per-device *local* gradients (e.g. from a shard_map'd or
    per-host loss). Returns (reduced fp32 grads, new error-feedback state).
    """
    axes = dp_axes(mesh)
    n = 1
    for a in axes:
        n *= mesh.shape[a]

    if error is None:
        error = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                             grads)

    def reduce_one(g, e):
        corrected = g.astype(jnp.float32) + e
        wire = corrected.astype(wire_dtype)
        new_e = corrected - wire.astype(jnp.float32)     # residual feedback
        summed = jax.lax.psum(wire, axes)
        return summed.astype(jnp.float32) / n, new_e

    spec = jax.tree.map(lambda _: P(), grads)

    def inner(g, e):
        out = jax.tree.map(reduce_one, g, e)
        flat, treedef = jax.tree.flatten(
            out, is_leaf=lambda x: isinstance(x, tuple))
        red = treedef.unflatten([t[0] for t in flat])
        err = treedef.unflatten([t[1] for t in flat])
        return red, err

    fn = _shard_map(inner, mesh=mesh, in_specs=(spec, spec),
                    out_specs=(spec, spec))
    return fn(grads, error)


def wire_bytes(grads, wire_dtype=jnp.bfloat16) -> int:
    """DP traffic per step at the compressed wire dtype."""
    import numpy as np
    return sum(int(np.prod(g.shape)) * jnp.dtype(wire_dtype).itemsize
               for g in jax.tree.leaves(grads))
