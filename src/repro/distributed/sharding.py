"""Sharding rules: params (FSDP x TP), optimizer state, KV caches, batches.

Conventions (DESIGN.md §5):
  * data-parallel axes: ("data",) single-pod, ("pod", "data") multi-pod --
    the pod axis composes with data parallelism, which is what the
    multi-pod dry-run proves out.
  * TP axis: "model". Weights: last dim over model, second-to-last over dp
    (FSDP; GSPMD all-gathers at use). MoE experts: EP over model when
    E % |model| == 0 (olmoe), else per-expert FFN TP (granite).
  * Quantized (serve) weights: packed payload arrays shard over model on
    lanes only (TP); the packed K rows stay whole per shard so super-block
    boundaries never straddle devices.
  * KV caches: batch over dp, then kv_heads over model when divisible,
    else head_dim over model, else sequence (see serve shardings).

Every rule checks divisibility and degrades to replication, so any mesh
shape compiles (elastic meshes; see launch/mesh.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.formats import get_format
from repro.core.quantize import QTensor


# --------------------------------------------------------------------------
# axis roles: by default "model" is the TP axis; tp_off() retargets it as
# extra data parallelism (pure FSDP) -- the right regime for small dense
# models where TP all-reduces dominate (see EXPERIMENTS.md §Perf H3). The
# physical production mesh is unchanged; only the role mapping moves.
#
# Context state is an explicit frame STACK, not a saved-and-restored module
# dict: each __enter__ pushes a frame and remembers its depth, each
# __exit__ pops back to that depth. That makes the contexts reentrant (the
# same context object can be entered while already active -- the old
# per-instance ``_saved`` slot was silently clobbered on re-entry, leaving
# the outer exit to "restore" the inner snapshot) and keeps nested or
# interleaved enters from corrupting each other's saved state.
# --------------------------------------------------------------------------
_TP_STACK = [False]


class _StackedContext:
    """Reentrant context manager over a module-level frame stack."""

    _stack: list          # subclasses point this at their frame stack

    def __init__(self):
        self._depths = []

    def _frame(self):
        raise NotImplementedError

    def __enter__(self):
        self._stack.append(self._frame())
        self._depths.append(len(self._stack))
        return self

    def __exit__(self, *exc):
        if not self._depths:
            raise RuntimeError(
                f"{type(self).__name__}.__exit__ without matching __enter__")
        depth = self._depths.pop()
        # pop back to this enter's depth; an out-of-order (interleaved)
        # exit also drops the frames stacked above it, restoring a
        # coherent state instead of resurrecting a stale snapshot
        del self._stack[depth - 1:]
        return False


class tp_off(_StackedContext):
    def __init__(self):
        super().__init__()
        self._stack = _TP_STACK

    def _frame(self):
        return True


def _tp_is_off() -> bool:
    return _TP_STACK[-1]


def model_axis(mesh: Mesh):
    if _tp_is_off() or "model" not in mesh.axis_names:
        return None
    return "model"


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    if _tp_is_off() and "model" in mesh.axis_names:
        axes.append("model")
    return tuple(axes)


def axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _div(n: int, mesh: Mesh, axes) -> bool:
    return n % axis_size(mesh, axes) == 0


# down/out projections are row-parallel (K over model): their input is
# already model-sharded (ff/heads), so the forward emits one small
# d_model-sized all-reduce instead of gathering the ff-sized hidden --
# standard Megatron TP pairing with the column-parallel up/gate/qkv.
_ROW_PARALLEL = ("w_down", "wo", "c_proj", "out_proj", "proj_out")


def _is_row_parallel(path: str) -> bool:
    return path.split("/")[-1] in _ROW_PARALLEL


def _spec_for_matrix(shape, mesh, path: str, *, fsdp: bool) -> P:
    """(lead..., K, N) weight: column-parallel (N over model, K over dp)
    by default; row-parallel (K over model, N over dp) for down/out."""
    nd = len(shape)
    dp = dp_axes(mesh)
    m = model_axis(mesh)
    dims = [None] * nd
    if _is_row_parallel(path):
        if m and _div(shape[-2], mesh, m):
            dims[-2] = m
        if fsdp and _div(shape[-1], mesh, dp):
            dims[-1] = dp
    else:
        if m and _div(shape[-1], mesh, m):
            dims[-1] = m
        if fsdp and _div(shape[-2], mesh, dp):
            dims[-2] = dp
    return P(*dims)


def _spec_for_experts(shape, mesh, path: str, *, fsdp: bool) -> P:
    """(lead..., E, K, N): EP over model if divisible, else FFN-TP."""
    nd = len(shape)
    dp = dp_axes(mesh)
    m = model_axis(mesh)
    dims = [None] * nd
    if m and _div(shape[-3], mesh, m):
        dims[-3] = m                             # EP
        if fsdp and _div(shape[-2], mesh, dp):
            dims[-2] = dp
    else:                                        # per-expert FFN TP
        if _is_row_parallel(path):
            if m and _div(shape[-2], mesh, m):
                dims[-2] = m
            if fsdp and _div(shape[-1], mesh, dp):
                dims[-1] = dp
        else:
            if m and _div(shape[-1], mesh, m):
                dims[-1] = m
            if fsdp and _div(shape[-2], mesh, dp):
                dims[-2] = dp
    return P(*dims)


def param_specs(params, mesh: Mesh, *, fsdp: bool = True) -> Any:
    """Pytree of PartitionSpec matching ``params`` (arrays, specs or
    QTensors)."""

    def walk(node, prefix=""):
        if isinstance(node, dict):
            return {k: walk(v, f"{prefix}{k}/") for k, v in node.items()}
        path = prefix[:-1]
        if isinstance(node, QTensor):
            # packed payloads: column-parallel = lanes over model;
            # row-parallel = packed K rows over model, but only when every
            # shard holds whole super-blocks (K % (|model| * 256) == 0) --
            # otherwise replicate (cheap: these are 2.6-3.6 bit tensors)
            K = node.shape[0]
            m = model_axis(mesh)
            row = (_is_row_parallel(path) and "moe/" not in path)
            sb_aligned = m is not None and K % (axis_size(mesh, m)
                                                * 256) == 0

            def qspec(arr):
                nd = len(arr.shape)
                dims = [None] * nd
                if row:
                    if sb_aligned:
                        dims[-2] = m
                elif m and _div(arr.shape[-1], mesh, m):
                    dims[-1] = m
                return P(*dims)
            return QTensor(node.variant, node.shape,
                           {k: qspec(v) for k, v in node.data.items()})
        shape = node.shape
        parts = path.split("/")
        leaf = parts[-1]
        is_norm = (any(p.startswith("ln") or "norm" in p for p in parts)
                   or "norm" in leaf)
        if (len(shape) <= 1 or is_norm
                or leaf.startswith(("conv", "A_log", "D", "dt_bias",
                                    "b_", "bias"))):
            return P()                           # replicated (incl. stacked
            # norm scales: their leading dim is the layer-scan axis)
        if "moe/w_" in path and len(shape) >= 3:
            return _spec_for_experts(shape, mesh, path, fsdp=fsdp)
        if leaf in ("wte", "wpe"):
            # embeddings: vocab over model (so a tied head emits V-sharded
            # logits with no vocab-sized all-reduce), features over dp
            dp = dp_axes(mesh)
            m = model_axis(mesh)
            row = m if (m and _div(shape[0], mesh, m)) else None
            col = dp if fsdp and _div(shape[1], mesh, dp) else None
            return P(*([None] * (len(shape) - 2) + [row, col]))
        return _spec_for_matrix(shape, mesh, path, fsdp=fsdp)

    return walk(params)


def opt_state_specs(pspecs) -> Dict[str, Any]:
    return dict(m=pspecs, v=pspecs, step=P())


def batch_specs(batch: Dict[str, Any], mesh: Mesh) -> Dict[str, P]:
    dp = dp_axes(mesh)
    out = {}
    for k, v in batch.items():
        nd = len(v.shape)
        if k == "positions" and nd == 3:         # (3, B, S) M-RoPE
            bdp = dp if _div(v.shape[1], mesh, dp) else None
            out[k] = P(None, bdp, None)
        elif nd >= 1:
            bdp = dp if _div(v.shape[0], mesh, dp) else None
            out[k] = P(*((bdp,) + (None,) * (nd - 1)))
        else:
            out[k] = P()
    return out


def cache_specs(cache: Dict[str, Any], mesh: Mesh,
                kv_shard: str = "auto") -> Dict[str, Any]:
    """Decode-cache shardings. kv_shard: auto | heads | head_dim | seq |
    replicated -- 'seq' is the flash-decode-style partial-softmax layout
    (see EXPERIMENTS.md §Perf)."""
    dp = dp_axes(mesh)
    m = model_axis(mesh)
    out: Dict[str, Any] = {}

    def bdp(B, T=None):
        """Batch over dp when divisible; else (long-context B=1) shard the
        cache sequence over dp -- flash-decoding-style partial softmax."""
        if _div(B, mesh, dp):
            return dp, None
        if T is not None and _div(T, mesh, dp):
            return None, dp
        return None, None

    # resolve the kv mode once so k/v and their int8 scales co-shard.
    # auto prefers heads, then sequence (flash-decoding partial softmax).
    # head_dim sharding is only used when explicitly requested: GSPMD
    # resolves GQA q-heads x Dh-sharded cache by re-gathering the whole
    # cache every step (see EXPERIMENTS.md §Perf H1).
    kv_mode = "replicated"
    if "k" in cache and m:
        ks = cache["k"].shape
        kv_mode = kv_shard
        if kv_mode == "auto":
            if _div(ks[3], mesh, m):
                kv_mode = "heads"
            elif _div(ks[2], mesh, m):
                kv_mode = "seq"
            else:
                kv_mode = "replicated"

    for k, v in cache.items():
        shape = v.shape
        if k in ("k", "v"):                      # (L|napp, B, T, KH, Dh)
            b_ax, t_ax = bdp(shape[1], shape[2])
            dims = [None, b_ax, t_ax, None, None]
            if kv_mode == "heads":
                dims[3] = m
            elif kv_mode == "head_dim":
                dims[4] = m
            elif kv_mode == "seq" and t_ax is None:
                dims[2] = m
            out[k] = P(*dims)
        elif k in ("k_scale", "v_scale"):        # (L, B, T, KH)
            b_ax, t_ax = bdp(shape[1], shape[2])
            dims = [None, b_ax, t_ax, None]
            if kv_mode == "heads":
                dims[3] = m
            elif kv_mode == "seq" and t_ax is None:
                dims[2] = m
            out[k] = P(*dims)
        elif k == "pos":                         # (B, T)
            b_ax, t_ax = bdp(shape[0], shape[1])
            out[k] = P(b_ax, t_ax)
        elif k == "state":                       # (L, B, H, Pdim, N)
            b_ax, _ = bdp(shape[1])
            dims = [None, b_ax, None, None, None]
            if m and _div(shape[2], mesh, m):
                dims[2] = m
            out[k] = P(*dims)
        elif k == "conv":                        # (L, B, W-1, C)
            b_ax, _ = bdp(shape[1])
            dims = [None, b_ax, None,
                    m if (m and _div(shape[3], mesh, m)) else None]
            out[k] = P(*dims)
        else:
            out[k] = P()
    return out


def named(tree_specs, mesh: Mesh):
    """PartitionSpec pytree -> NamedSharding pytree."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree_specs, is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# activation sharding constraints (GSPMD guidance inside model code)
#
# Model code calls constrain(x, "dp", None, "model") with symbolic axes;
# the launcher activates them for the current mesh via activation_axes().
# Without activation, constrain() is the identity, so single-device smoke
# tests and interpret-mode kernels are unaffected.
# ---------------------------------------------------------------------------

_ACT_STACK = [
    {"enabled": False, "dp": None, "model": None,
     "dp_size": 1, "model_size": 1},
]


class activation_axes(_StackedContext):
    def __init__(self, mesh: Mesh):
        super().__init__()
        self._stack = _ACT_STACK
        self.dp = dp_axes(mesh)
        self.model = model_axis(mesh)
        self.dp_size = axis_size(mesh, self.dp)
        self.model_size = axis_size(mesh, self.model) if self.model else 1

    def _frame(self):
        return {"enabled": True, "dp": self.dp, "model": self.model,
                "dp_size": self.dp_size, "model_size": self.model_size}


def constrain(x, *dims):
    """with_sharding_constraint with symbolic 'dp'/'model' axis names.
    Identity unless a launcher activated axes; non-divisible dims degrade
    to unsharded."""
    act = _ACT_STACK[-1]
    if not act["enabled"]:
        return x
    resolved = []
    for size, d in zip(x.shape, dims):
        if d == "dp" and act["dp"] and size % act["dp_size"] == 0:
            resolved.append(act["dp"])
        elif d == "model" and act["model"] and size % act["model_size"] == 0:
            resolved.append(act["model"])
        else:
            resolved.append(None)
    return jax.lax.with_sharding_constraint(x, P(*resolved))


# ---------------------------------------------------------------------------
# tensor-parallel serving (shard_map; engine.ServeConfig.tp)
#
# The serving engine runs its jitted programs through shard_map over a
# 1-axis ("model",) mesh. Unlike the Megatron row/column pairing above
# (psum of PARTIAL sums -- fast, but a different f32 accumulation order
# than the single-device program), serving TP is *lane-only*: every
# weight keeps its K rows whole per shard and shards only its lane (last,
# N) axis, so each shard owns whole output columns and ONE collective
# per projection (a tiled lane all-gather, kernels/ops.tp_gather_lanes)
# assembles the replicated output. Shards are disjoint contiguous
# blocks, so that gather is pure data movement (exact) -- and with the
# "padded" matmul datapath (same-shaped gemm per shard, see
# ServeTPPlan.matmul) the whole TP forward is bit-identical to the
# single-device program,
# which is what lets the parity suite pin greedy serving output
# token-identical across mesh shapes {1, 2, 4}. For packed QTensors
# lane-only sharding is also the layout rule: payload lanes slice freely
# (packing runs along K), while K rows stay whole so super-block
# boundaries never straddle devices.
#
# A ServeTPPlan decides, per weight block, shard-vs-replicate:
#   * attn: q/k/v/o projections shard over heads (the KV cache co-shards
#     over kv_heads) when n_heads, n_kv_heads and d_model all divide the
#     mesh -- fused-qkv layouts interleave q/k/v lanes and stay
#     replicated.
#   * mlp:  gate/up/fc shard the ffn hidden, down/proj the d_model
#     output, when d_ff and d_model divide.
#   * moe_ep: MoE expert stacks shard their EXPERT axis over the model
#     mesh axis when n_experts divides it (serving-side expert
#     parallelism): each shard computes only its experts' gemms and one
#     tiled all-gather of the (B, E_local, C, d) output buffers assembles
#     the global buffer -- pure data movement, and per-expert gemms batch
#     over the expert dim, so the EP forward is bit-identical to the
#     replicated path (pinned by tests/test_moe_ep.py). Packed QTensor
#     expert stacks keep a replicated payload (their E*K packing cannot
#     slice per-expert without super-block alignment); the EP compute
#     path then slices each shard's experts out of the dequantized stack.
# Everything else (embeddings, norms, biases past a gather point) is
# replicated. Every fallback degrades to replication, so any config
# compiles at any tp degree -- it just stops saving work.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ServeTPPlan:
    size: int
    axis: str = "model"
    attn: bool = False          # shard heads + KV cache over kv_heads
    mlp: bool = False           # shard the ffn hidden / down output
    # projection datapath (see layers.tp_lane_dense):
    #   "padded" -- zero-embed the local lanes into a full-width weight
    #     and run the SAME-shaped gemm as the single-device program.
    #     CPU gemms round shape-dependently (a lane-sliced dot differs
    #     from the full dot's columns by an f32 ulp -- pinned by
    #     test_tp_serving), so same-shape is the only way to a
    #     GUARANTEED bit-identical forward: weights/cache stay sharded
    #     (the memory win), matmul FLOPs are replicated. The parity
    #     default.
    #   "sliced" -- true lane-sliced gemm: per-shard FLOPs and packed
    #     HBM traffic scale 1/size (the throughput datapath), output
    #     equal to within an f32 ulp of the tp=1 accumulation.
    #   "sliced_row" -- "sliced" plus row-parallel o-/down-projections
    #     (attn_row / mlp_row below): HALF the collectives per layer at
    #     narrower wire width. Splitting the K reduction across shards
    #     cannot bit-match a full-K dot once activations round to bf16
    #     at layer boundaries, so this datapath promises agreement only
    #     to ~a few ULPS OF THE ACTIVATION DTYPE (exactly the f32-ulp
    #     envelope when activations are f32); its own tolerance tests
    #     pin both regimes.
    matmul: str = "padded"
    # row-parallel projections ("sliced_row" only; "" = off, keep the
    # lane-only gather dataflow). When set, the down-proj (mlp_row) and
    # o-proj (attn_row) take their input DIRECTLY from this shard's local
    # lanes (the ffn hidden / this shard's head outputs), compute a
    # partial-K product, and assemble the replicated output with ONE
    # ``psum`` -- the classic Megatron column/row pairing. This halves
    # the collectives per layer (2 instead of 4) and removes the widest
    # gather (the d_ff-sized hidden). Modes:
    #   "packed"  -- the weight's packed K rows co-shard with its input
    #     (K % (size * super_block) == 0, so every shard holds whole
    #     super-blocks; plain arrays only need K % size == 0).
    #   "dequant" -- the packed payload stays REPLICATED (these are
    #     2.6-3.6 bit tensors) and each shard slices its K rows out of
    #     the dequantized weight: per-shard gemm FLOPs still 1/size,
    #     dequant replicated. The fallback when super-block alignment
    #     fails (e.g. the reduced bench model's wo at K = 256, tp 2).
    attn_row: str = ""
    mlp_row: str = ""
    # serving-side expert parallelism: plain MoE expert stacks shard
    # their expert axis over ``axis`` and each shard computes only its
    # own experts (see the module comment and models/moe.moe_block)
    moe_ep: bool = False


def _row_mode(leaf, size: int) -> str:
    """Row-parallel mode for one down/o-proj weight leaf (see
    ServeTPPlan.attn_row): "packed" when its K rows shard into whole
    super-blocks, "dequant" for packed tensors that cannot, "" when even
    a plain array's K does not divide."""
    if isinstance(leaf, QTensor):
        K = leaf.shape[0]
        sb = get_format(leaf.variant).super_block
        return "packed" if K % (size * sb) == 0 else "dequant"
    K = leaf.shape[-2]
    return "packed" if K % size == 0 else ""


def make_serve_tp_plan(cfg, size: int, axis: str = "model",
                       matmul: str = "padded",
                       params=None, ep: bool = True) -> ServeTPPlan:
    """Shard-vs-replicate decisions for serving ``cfg`` at tp degree
    ``size`` (divisibility checks; see module comment).

    ``params`` (optional, the serve-time parameter pytree) enables the
    "sliced_row" datapath's row-parallel down/o-projections: whether a
    packed weight's K rows can shard depends on its variant's
    super-block, so the decision is per-leaf and needs the real tensors.
    Without params (or under "padded"/"sliced") the plan keeps the
    lane-only dataflow.

    ``ep`` opts MoE expert stacks into expert-axis sharding when the
    expert count divides the mesh (non-divisible counts fall back to
    replication like every other rule)."""
    if matmul not in ("padded", "sliced", "sliced_row"):
        raise ValueError(f"tp matmul must be 'padded', 'sliced' or "
                         f"'sliced_row', got {matmul!r}")
    if size <= 1:
        return ServeTPPlan(size=1, axis=axis, matmul=matmul)
    attn = (not cfg.fused_qkv
            and cfg.n_heads % size == 0
            and cfg.n_kv_heads % size == 0
            and cfg.d_model % size == 0)
    mlp = (cfg.family != "moe"
           and cfg.d_ff % size == 0
           and cfg.d_model % size == 0)
    moe_ep = (ep and cfg.family == "moe" and cfg.n_experts % size == 0)
    attn_row = mlp_row = ""
    if matmul == "sliced_row" and isinstance(params, dict):
        layers = params.get("layers")
        if attn and isinstance(layers, dict) \
                and isinstance(layers.get("attn"), dict) \
                and "wo" in layers["attn"]:
            attn_row = _row_mode(layers["attn"]["wo"], size)
        if mlp and isinstance(layers, dict) \
                and isinstance(layers.get("mlp"), dict):
            mp = layers["mlp"]
            down = mp.get("w_down", mp.get("c_proj"))
            if down is not None:
                mlp_row = _row_mode(down, size)
    return ServeTPPlan(size=size, axis=axis, attn=attn, mlp=mlp,
                       matmul=matmul, attn_row=attn_row, mlp_row=mlp_row,
                       moe_ep=moe_ep)


_SERVE_TP_STACK: list = [None]


class serve_tp(_StackedContext):
    """Activates a ServeTPPlan for model code traced inside a shard_map
    body: layers/transformer consult serve_tp_plan() to slice local head
    counts and place the per-projection lane gathers."""

    def __init__(self, plan: ServeTPPlan):
        super().__init__()
        self._stack = _SERVE_TP_STACK
        self.plan = plan

    def _frame(self):
        return self.plan


def serve_tp_plan() -> Optional[ServeTPPlan]:
    return _SERVE_TP_STACK[-1]


# serve-weight leaves that shard their lane (last) axis, by block
_SERVE_ATTN_LANES = ("wq", "wk", "wv", "wo")
_SERVE_MLP_LANES = ("w_gate", "w_up", "w_down", "c_fc", "c_proj", "b_fc")


def _serve_lane_sharded(path: str, plan: ServeTPPlan) -> bool:
    parts = path.split("/")
    leaf = parts[-1]
    block = parts[-2] if len(parts) >= 2 else ""
    if block == "attn" and leaf in _SERVE_ATTN_LANES:
        return plan.attn
    # b_fc rides the mlp flag: it adds to the still-local ffn hidden
    # (b_proj adds AFTER the output gather and stays replicated)
    if block == "mlp" and leaf in _SERVE_MLP_LANES:
        return plan.mlp
    return False


def _serve_row_mode(path: str, plan: ServeTPPlan) -> str:
    """Row-parallel mode ("" | "packed" | "dequant") for this leaf: the
    o-proj and down-proj leave the lane group and shard (or replicate,
    for "dequant") their K rows instead when the plan enables the
    psum-assembled sliced dataflow (see ServeTPPlan.attn_row)."""
    parts = path.split("/")
    leaf = parts[-1]
    block = parts[-2] if len(parts) >= 2 else ""
    if block == "attn" and leaf == "wo" and plan.attn:
        return plan.attn_row
    if block == "mlp" and leaf in ("w_down", "c_proj") and plan.mlp:
        return plan.mlp_row
    return ""


def serve_param_specs(params, plan: ServeTPPlan) -> Any:
    """Pytree of PartitionSpec for serve-mode params: lane-only TP.

    QTensor payloads shard their lane (last) axis -- K rows whole per
    shard, so no super-block ever straddles devices; plain weights shard
    the same way. Under a row-parallel plan the o-/down-proj instead
    shard packed K rows (mode "packed": whole super-blocks per shard) or
    replicate their payload (mode "dequant"). Under an EP plan plain MoE
    expert stacks shard their expert axis (the router and packed QTensor
    stacks replicate). Embeddings, norms, biases-after-gather and every
    non-divisible block replicate."""

    def walk(node, prefix=""):
        if isinstance(node, dict):
            return {k: walk(v, f"{prefix}{k}/") for k, v in node.items()}
        path = prefix[:-1]
        parts = path.split("/")
        if (plan.moe_ep and plan.size > 1 and len(parts) >= 2
                and parts[-2] == "moe"
                and parts[-1] in ("w_gate", "w_up", "w_down")
                and not isinstance(node, QTensor)):
            # expert parallelism: (Lc, E, K, N) stacks shard E; the
            # shard_map body then sees only its own experts' weights
            return P(*([None] * (len(node.shape) - 3)
                       + [plan.axis, None, None]))
        row = _serve_row_mode(path, plan) if plan.size > 1 else ""
        shard = (not row and plan.size > 1
                 and _serve_lane_sharded(path, plan))
        if isinstance(node, QTensor):
            def qspec(arr):
                if row == "packed" and len(arr.shape) >= 2:
                    return P(*([None] * (len(arr.shape) - 2)
                               + [plan.axis, None]))
                if not shard or row:
                    return P()
                return P(*([None] * (len(arr.shape) - 1) + [plan.axis]))
            return QTensor(node.variant, node.shape,
                           {k: qspec(v) for k, v in node.data.items()})
        if row == "packed" and len(node.shape) >= 2:
            return P(*([None] * (len(node.shape) - 2)
                       + [plan.axis, None]))
        if not shard or row or len(node.shape) < 2:
            return P()
        return P(*([None] * (len(node.shape) - 1) + [plan.axis]))

    return walk(params)


def serve_cache_specs(cache: Dict[str, Any],
                      plan: ServeTPPlan) -> Dict[str, P]:
    """Decode-cache / page-pool specs for TP serving: KV payloads (and
    their int8 scales) shard over the kv_heads axis (always axis 3:
    k/v are (L, B|n_pages, T|page, KH, Dh), scales (L, B, T, KH)) when
    the plan shards attention; the position ring and recurrent entries
    replicate."""
    out: Dict[str, P] = {}
    for k, v in cache.items():
        if (plan.size > 1 and plan.attn
                and k in ("k", "v", "k_scale", "v_scale")):
            dims = [None] * len(v.shape)
            dims[3] = plan.axis
            out[k] = P(*dims)
        else:
            out[k] = P()
    return out


def lane_shard_qtensor(t: QTensor, index: int, n_shards: int) -> QTensor:
    """The ``index``-th of ``n_shards`` lane shards of a packed QTensor:
    every payload array sliced on its lane (last) axis, K rows whole.
    This is exactly the local view a shard_map body sees under
    serve_param_specs -- and, because packing runs along K, dequantizing
    a shard is bit-identical to the matching columns of the unsharded
    dequant (pinned by the test_kernels property suite)."""
    K, N = t.shape
    if N % n_shards:
        raise ValueError(f"N={N} lanes not divisible into {n_shards} "
                         "shards; lane-only TP requires N % shards == 0")
    n = N // n_shards
    lo = index * n
    return QTensor(t.variant, (K, n),
                   {k: v[..., lo:lo + n] for k, v in t.data.items()})


def row_shard_qtensor(t: QTensor, index: int, n_shards: int) -> QTensor:
    """The ``index``-th of ``n_shards`` K-row shards of a packed QTensor:
    every payload array sliced on its packed-row (second-to-last) axis.
    Legal only when K splits into whole super-blocks per shard
    (K % (n_shards * super_block) == 0) -- then each shard's dequant is
    bit-identical to the matching K rows of the unsharded dequant, which
    is what lets the row-parallel "packed" datapath feed local rows
    straight into the fused gemm."""
    K, N = t.shape
    sb = get_format(t.variant).super_block
    if K % (n_shards * sb):
        raise ValueError(
            f"K={K} rows not divisible into {n_shards} shards of whole "
            f"{sb}-row super-blocks; use the 'dequant' row fallback")

    def cut(v):
        rows = v.shape[-2]
        r = rows // n_shards
        lo = index * r
        return v[..., lo:lo + r, :]

    return QTensor(t.variant, (K // n_shards, N),
                   {k: cut(v) for k, v in t.data.items()})


def localize_serve_params(params, specs, size: int):
    """Fix up QTensor aux shapes for the local views inside a shard_map
    body: payload arrays arrive already sliced to N/size lanes (or, for
    row-parallel "packed" leaves, K/size packed rows), but the static
    (K, N) aux rides in globally -- dequantize would reshape against the
    wrong extent. Plain arrays need nothing (shard_map hands them over
    with local shapes)."""
    if size <= 1:
        return params

    def fix(p, s):
        if not isinstance(p, QTensor):
            return p
        lane = any(len(sp) > 0 and sp[-1] is not None
                   for sp in s.data.values())
        rows = any(len(sp) > 1 and sp[-2] is not None
                   for sp in s.data.values())
        if not (lane or rows):
            return p
        K, N = p.shape
        return QTensor(p.variant,
                       (K // size if rows else K,
                        N // size if lane else N), p.data)

    return jax.tree.map(fix, params, specs,
                        is_leaf=lambda x: isinstance(x, QTensor))
