"""AdamW + cosine schedule + global-norm clipping (pure JAX, pytree-based).

Weight decay is masked off 1-D parameters (norm scales, biases). Optimizer
state dtype is configurable: fp32 master moments by default; bf16 moments
(``moment_dtype``) halve optimizer HBM as a large-scale option.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    moment_dtype: str = "float32"


def cosine_lr(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init_state(cfg: AdamWConfig, params) -> Dict[str, Any]:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return dict(m=jax.tree.map(zeros, params),
                v=jax.tree.map(zeros, params),
                step=jnp.zeros((), jnp.int32))


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def apply_updates(cfg: AdamWConfig, params, grads, state,
                  lr: Optional[jnp.ndarray] = None):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = cosine_lr(cfg, step) if lr is None else lr
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2 and cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m32.astype(mdt), v32.astype(mdt))

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    # out is a pytree of 3-tuples at array leaves; unzip it
    flat, treedef = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
    new_p = treedef.unflatten([t[0] for t in flat])
    new_m = treedef.unflatten([t[1] for t in flat])
    new_v = treedef.unflatten([t[2] for t in flat])
    new_state = dict(m=new_m, v=new_v, step=step)
    return new_p, new_state, dict(grad_norm=gnorm, lr=lr)
