"""Deterministic token data pipeline: synthetic LM stream + file-backed.

Synthetic mode generates a structured pseudo-language (Zipf-ish unigram with
short-range bigram structure) so tiny models have something learnable --
loss decreases measurably within a few hundred steps (used by the e2e
example and convergence tests).

File mode memory-maps a flat uint16/uint32 token file and serves
fixed-length windows. Batches are a pure function of (seed, step) so a
restart resumes bit-identically from a checkpointed step -- the data side
of fault tolerance. Multi-host: each process slices its local rows by
``jax.process_index()``; on a single-process CPU run that is a no-op.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    path: Optional[str] = None       # file-backed if set
    dtype: str = "int32"


class DataPipeline:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._mm = None
        if cfg.path:
            raw = np.memmap(cfg.path, dtype=np.uint16, mode="r")
            self._mm = raw
        # bigram transition structure for the synthetic language
        rng = np.random.default_rng(cfg.seed)
        V = cfg.vocab_size
        self._uni = (1.0 / (np.arange(V) + 10.0))
        self._uni /= self._uni.sum()
        self._shift = rng.integers(1, max(2, V // 2), size=16)

    def _synthetic(self, step: int) -> np.ndarray:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed * 1_000_003 + step) & 0x7FFFFFFF)
        B, S = cfg.global_batch, cfg.seq_len
        toks = rng.choice(cfg.vocab_size, size=(B, S + 1), p=self._uni)
        # inject learnable bigram structure: with p=0.5, next token is a
        # deterministic function of the current one
        mask = rng.random((B, S)) < 0.5
        nxt = (toks[:, :-1] + self._shift[toks[:, :-1] % 16]) % cfg.vocab_size
        toks[:, 1:][mask] = nxt[mask]
        return toks.astype(np.int32)

    def _from_file(self, step: int) -> np.ndarray:
        cfg = self.cfg
        B, S = cfg.global_batch, cfg.seq_len
        n = len(self._mm) - (S + 1)
        rng = np.random.default_rng((cfg.seed * 1_000_003 + step) & 0x7FFFFFFF)
        starts = rng.integers(0, n, size=B)
        return np.stack([self._mm[s:s + S + 1] for s in starts]).astype(np.int32)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        toks = self._from_file(step) if self._mm is not None \
            else self._synthetic(step)
        # multi-host: serve only this process's rows
        nproc = jax.process_count()
        if nproc > 1:
            per = toks.shape[0] // nproc
            i = jax.process_index()
            toks = toks[i * per:(i + 1) * per]
        return dict(tokens=toks[:, :-1], labels=toks[:, 1:])
