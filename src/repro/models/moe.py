"""Top-k capacity-based MoE (GShard-style), vmapped per batch row.

Dispatch is scatter-based with per-row capacity C = ceil(S*k*cf / E): no
(T, E, C) one-hot tensor ever materializes, and keeping the dispatch local
to each batch row means the only cross-device movement under pjit is the
expert-dim resharding of the (B, E, C, d) buffers -- the all-to-all of real
expert parallelism. Overflowed token-choices are dropped (standard GShard
semantics); an aux load-balance loss encourages uniform routing.

Expert weights may be a stacked ``QTensor`` packed along E*K (see
``core/qlinear.stack_expert_qtensor``); they are dequantized per use.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import calibrate as CAL
from repro.core.quantize import QTensor, dequantize
from repro.distributed.sharding import constrain, serve_tp_plan


def expert_weights(w, E: int) -> jnp.ndarray:
    """(E, K, N) from either a plain array or an E*K-stacked QTensor."""
    if isinstance(w, QTensor):
        EK, N = w.shape
        return dequantize(w, dtype=jnp.bfloat16).reshape(E, EK // E, N)
    return w


def _capacity(S: int, k: int, E: int, cf: float) -> int:
    c = int(S * k * cf / E) + 1
    return max(4, min(c, S * k))


def moe_block(x: jnp.ndarray, p: Dict, cfg, *, impl="auto",
              interpret: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar)."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.n_experts_active
    C = _capacity(S, k, E, cfg.capacity_factor)

    router = p["router"]
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(logits, k)                   # (B,S,k)
    gates = jax.nn.softmax(topv, axis=-1).astype(jnp.float32)

    # aux load-balance loss (Switch-style): E * sum_e f_e * P_e
    me = jnp.mean(probs, axis=(0, 1))                       # (E,)
    onehot_top1 = jax.nn.one_hot(topi[..., 0], E)
    ce = jnp.mean(onehot_top1, axis=(0, 1))
    aux = E * jnp.sum(me * ce)

    wg = expert_weights(p["w_gate"], E)                     # (E,d,fe)
    wu = expert_weights(p["w_up"], E)
    wd = expert_weights(p["w_down"], E)

    def row(xr, er, gr):
        """xr (S,d), er (S,k) int, gr (S,k) -> (S,d)."""
        e_flat = er.reshape(S * k)
        g_flat = gr.reshape(S * k)
        oh = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)     # (S*k, E)
        ranks = jnp.cumsum(oh, axis=0) - oh
        myrank = jnp.take_along_axis(ranks, e_flat[:, None], 1)[:, 0]
        keep = myrank < C
        slot = jnp.where(keep, myrank, 0)
        xr_rep = jnp.repeat(xr, k, axis=0)                  # (S*k, d)
        contrib = jnp.where(keep[:, None], xr_rep, 0)
        buf = jnp.zeros((E, C, d), xr.dtype).at[e_flat, slot].add(contrib)
        return buf, (e_flat, slot, keep, g_flat)

    bufs, meta = jax.vmap(row)(x, topi, gates)              # (B,E,C,d)
    # EP: dispatch buffers resharded expert-major -> the all-to-all
    bufs = constrain(bufs, "dp", "model", None, None)

    # serving-side expert parallelism (shard_map; ServeTPPlan.moe_ep):
    # routing/dispatch/combine run replicated on the full E (the router
    # is replicated), but each shard's expert gemms cover only its own
    # E/size experts; one tiled all-gather of the output buffers -- pure
    # data movement -- assembles the global (B,E,C,d). Per-expert gemms
    # batch over the expert dim, so the EP output is bit-identical to the
    # replicated path (pinned by tests/test_moe_ep.py).
    plan = serve_tp_plan()
    ep = (plan is not None and plan.moe_ep and plan.size > 1
          and E % plan.size == 0)
    if ep:
        sidx = jax.lax.axis_index(plan.axis)
        Eloc = E // plan.size
        if wg.shape[0] == E:
            # replicated stack (packed QTensors dequantize to full E):
            # slice this shard's experts; plain sharded stacks already
            # arrive local under serve_param_specs
            wg = jax.lax.dynamic_slice_in_dim(wg, sidx * Eloc, Eloc, 0)
            wu = jax.lax.dynamic_slice_in_dim(wu, sidx * Eloc, Eloc, 0)
            wd = jax.lax.dynamic_slice_in_dim(wd, sidx * Eloc, Eloc, 0)
        bufs_c = jax.lax.dynamic_slice_in_dim(bufs, sidx * Eloc, Eloc, 1)
    else:
        bufs_c = bufs

    CAL.tap(("moe/w_gate", "moe/w_up"), bufs_c)
    hg = jnp.einsum("becd,edf->becf", bufs_c.astype(jnp.bfloat16),
                    wg.astype(jnp.bfloat16))
    hu = jnp.einsum("becd,edf->becf", bufs_c.astype(jnp.bfloat16),
                    wu.astype(jnp.bfloat16))
    hidden = jax.nn.silu(hg) * hu
    CAL.tap("moe/w_down", hidden)
    out_buf = jnp.einsum("becf,efd->becd", hidden,
                         wd.astype(jnp.bfloat16))           # (B,E,C,d)
    if ep:
        out_buf = jax.lax.all_gather(out_buf, plan.axis, axis=1,
                                     tiled=True)

    def combine(ob, m):
        e_flat, slot, keep, g_flat = m
        vals = ob[e_flat, slot].astype(jnp.float32)         # (S*k, d)
        vals = vals * (keep[:, None] * g_flat[:, None])
        return vals.reshape(S, k, d).sum(axis=1)

    y = jax.vmap(combine)(out_buf, meta)
    return y.astype(x.dtype), aux.astype(jnp.float32)
