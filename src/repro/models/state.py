"""Family adapters: the engine <-> model contract as an explicit object.

The serving engine used to know which model families support which
features through four scattered ``raise ValueError(... needs a KV-ring
family ...)`` sites plus ad-hoc ``cfg.family`` branches. This module makes
that contract explicit:

* ``FamilyCaps`` -- a per-family capability row (KV ring vs recurrent
  state, speculation, prefix caching mode, TP/EP) consulted by ONE
  validation pass (``validate_serve_features``) at engine construction.
* ``DecodeState`` -- the adapter the engine drives the model's decode
  cache through: init / slot-scatter / ring snapshot-rewind / page and
  checkpoint export-import. Every method delegates to
  ``models.transformer`` so the numerical contracts (bit-for-bit page
  copies, drop-mode padding scatters) stay in one place.

Capability semantics:

* ``kv_ring``: the decode cache is a position-addressed KV ring --
  pages, speculation rollback, and attention-head TP all key off this.
* ``recurrent``: the decode cache carries dense conv/SSM state. Such
  state is positional (token t's state folds in every token before it),
  so prefix caching stores whole-state CHECKPOINTS at page boundaries
  instead of per-position pages, and speculation is impossible (no ring
  rewind can un-write a dense state).
* ``prefix_mode``: "pages" (per-position ring payload, partial-page
  copy-on-write reuse) or "checkpoints" (full pages only, page size
  pinned to the prefill chunk so checkpoints are the inter-chunk state
  the scheduler already materializes -- warm admission is bit-identical
  to cold by construction).
* ``ring_bounded_context``: prompt + budget must fit the ring (the ssm
  family has no ring and decodes unbounded contexts).
* ``expert_parallel``: MoE expert stacks may shard over the model axis
  when the expert count divides the mesh (see distributed/sharding.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax.numpy as jnp

from repro.configs.base import FAMILIES, ModelConfig
from repro.models import transformer as T


@dataclasses.dataclass(frozen=True)
class FamilyCaps:
    """One row of the family capability table."""
    family: str
    kv_ring: bool                 # position-addressed KV ring cache
    recurrent: bool               # dense conv/SSM state in the cache
    chunked_prefill: bool = True  # batched masked (B, C) prefill chunks
    speculative: bool = False     # draft/verify with ring rewind
    prefix_cache: bool = False    # shared-prefix reuse supported
    prefix_mode: str = "none"     # "pages" | "checkpoints" | "none"
    tensor_parallel: bool = False  # serve-TP over attention heads
    expert_parallel: bool = False  # experts shardable over the model axis
    ring_bounded_context: bool = True  # prompt+budget must fit the ring


_KV = dict(kv_ring=True, recurrent=False, speculative=True,
           prefix_cache=True, prefix_mode="pages", tensor_parallel=True)
_RECURRENT = dict(kv_ring=False, recurrent=True, speculative=False,
                  prefix_cache=True, prefix_mode="checkpoints",
                  tensor_parallel=False)

CAPS: Dict[str, FamilyCaps] = {
    "dense": FamilyCaps(family="dense", **_KV),
    "gpt2": FamilyCaps(family="gpt2", **_KV),
    "vlm": FamilyCaps(family="vlm", **_KV),
    "audio": FamilyCaps(family="audio", **_KV),
    "moe": FamilyCaps(family="moe", expert_parallel=True, **_KV),
    # ssm has no attention ring at all: context is unbounded
    "ssm": FamilyCaps(family="ssm", ring_bounded_context=False,
                      **_RECURRENT),
    # hybrid's shared-attention ring bounds its context like a KV family
    "hybrid": FamilyCaps(family="hybrid", **_RECURRENT),
}

# every registered family must carry a capability row: a family added to
# configs/base.FAMILIES without one fails here at import, not at runtime
assert set(CAPS) == set(FAMILIES), \
    f"capability table out of sync with FAMILIES: {set(CAPS) ^ set(FAMILIES)}"

KV_FAMILIES: Tuple[str, ...] = tuple(f for f, c in CAPS.items() if c.kv_ring)


def family_caps(cfg: ModelConfig) -> FamilyCaps:
    caps = CAPS.get(cfg.family)
    if caps is None:
        raise ValueError(f"unknown model family {cfg.family!r}")
    return caps


# feature -> (FamilyCaps attribute, reason an unsupported family raises).
# Every reason mentions the recurrent state: the only families outside
# the KV-ring set are the recurrent ones, and each feature fails for a
# feature-specific positional/rollback reason worth surfacing.
FEATURES: Dict[str, Tuple[str, str]] = {
    "tensor-parallel serving": (
        "tensor_parallel",
        "recurrent state sharding is a training-side concern"),
    "speculative decoding": (
        "speculative",
        "a dense recurrent state cannot be rolled back when drafts are "
        "rejected"),
    # every current family supports prefix caching (KV families page the
    # ring, recurrent families checkpoint state at chunk boundaries);
    # the row keeps the validation pass total over the feature matrix
    "prefix caching": (
        "prefix_cache",
        "the decode cache has no page- or checkpoint-granular export"),
}


def validate_serve_features(cfg: ModelConfig, *, tp: int = 1,
                            drafter: bool = False,
                            prefix_cache: bool = False) -> FamilyCaps:
    """ONE validation pass over the family x feature matrix.

    Raises ValueError with a single consistent shape --
    ``"<feature> needs a KV-ring family (got <family>): <why>"`` -- for
    any requested feature the family's capability row does not support.
    Returns the capability row so callers can branch on it afterwards."""
    caps = family_caps(cfg)
    requested = {"tensor-parallel serving": tp > 1,
                 "speculative decoding": drafter,
                 "prefix caching": prefix_cache}
    for feature, (attr, why) in FEATURES.items():
        if requested.get(feature) and not getattr(caps, attr):
            raise ValueError(
                f"{feature} needs a KV-ring family (got {cfg.family!r}); "
                f"{why}")
    return caps


class DecodeState:
    """Adapter the engine drives a family's decode cache through.

    Stateless (the cache pytrees live with the engine so they can ride
    donated jit arguments); this object carries the config, the
    capability row, and the per-family dispatch. Methods that only make
    sense for one side of the kv_ring/recurrent split assert on the
    capability row rather than on ``cfg.family`` strings."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.caps = family_caps(cfg)

    # -- lifecycle ---------------------------------------------------------
    def init(self, B: int, seq_len: int,
             dtype=jnp.bfloat16) -> Dict[str, Any]:
        return T.init_cache(self.cfg, B, seq_len, dtype=dtype)

    def set_slots(self, cache, group_cache, indices) -> Dict[str, Any]:
        return T.cache_set_slots(cache, group_cache, indices)

    # -- speculation (KV ring only) ----------------------------------------
    def ring_snapshot(self, cache, slots) -> Dict[str, Any]:
        assert self.caps.speculative, self.caps.family
        return T.cache_ring_snapshot(cache, slots)

    def ring_rewind(self, cache, snapshot, slots, keep) -> Dict[str, Any]:
        assert self.caps.speculative, self.caps.family
        return T.cache_ring_rewind(cache, snapshot, slots, keep)

    # -- prefix cache pages / checkpoints ----------------------------------
    def page_keys(self) -> Tuple[str, ...]:
        return T.cache_page_keys(self.cfg)

    def page_pool(self, n_pages: int, page: int,
                  dtype=jnp.bfloat16) -> Dict[str, Any]:
        assert self.caps.prefix_cache, self.caps.family
        return T.cache_page_pool(self.cfg, n_pages, page, dtype=dtype)

    def page_bytes(self, page: int) -> int:
        return T.cache_page_bytes(self.cfg, page)

    def gather_pages(self, cache, rows, cols) -> Dict[str, Any]:
        return T.cache_gather_pages(cache, rows, cols)

    def scatter_pages(self, cache, pages, rows, cols,
                      positions) -> Dict[str, Any]:
        return T.cache_scatter_pages(cache, pages, rows, cols, positions)

    def scatter_checkpoints(self, cache, pool, idx, rows) -> Dict[str, Any]:
        assert self.caps.prefix_mode == "checkpoints", self.caps.family
        return T.cache_scatter_checkpoints(cache, pool, idx, rows)

    def insert_checkpoints(self, pool, cache, rows, idx) -> Dict[str, Any]:
        assert self.caps.prefix_mode == "checkpoints", self.caps.family
        return T.cache_insert_checkpoints(pool, cache, rows, idx)
