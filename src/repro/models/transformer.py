"""Model assembly: init + train/prefill/decode for every supported family.

Families (see configs/base.py): dense (llama-style), gpt2 (fused-qkv,
LayerNorm/GELU, learned positions), moe (GShard-style top-k capacity
routing), ssm (Mamba2), hybrid (Zamba2: Mamba2 backbone + shared attention
block every k layers), vlm (dense + M-RoPE + stub patch embeddings), audio
(dense + sincos positions + stub frame embeddings).

All stacks scan over layers with stacked params (HLO size O(1) in depth).
Serve-mode params may contain packed ``QTensor`` leaves (mixed per-layer
variants -- the paper's flexible BFP execution); ``layers.dense`` dispatches
them to the fused kernel path.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import calibrate as CAL
from repro.core.quantize import QTensor, dequantize
from repro.distributed import sharding as SH
from repro.kernels import ops as kops
from repro.models import layers as L
from repro.models import mamba2 as M2
from repro.models import moe as MOE


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _dense_init(key, shape, fan_in, dtype):
    return (jax.random.normal(key, shape, jnp.float32)
            / jnp.sqrt(jnp.asarray(fan_in, jnp.float32))).astype(dtype)


def init_params(cfg: ModelConfig, key, dtype=jnp.float32) -> Dict[str, Any]:
    keys = iter(jax.random.split(key, 64))
    nk = lambda: next(keys)
    d, Lc, V = cfg.d_model, cfg.n_layers, cfg.vocab_size
    H, KH, Dh, f = cfg.n_heads, cfg.n_kv_heads, cfg.d_head, cfg.d_ff
    p: Dict[str, Any] = {}

    def norm_p(width, stacked=True):
        shape = (Lc, width) if stacked else (width,)
        out = {"w": jnp.ones(shape, dtype)}
        if cfg.norm_type == "layernorm":
            out["b"] = jnp.zeros(shape, dtype)
        return out

    if cfg.embed_input:
        # 1/sqrt(d) scale keeps tied-head logits O(1)
        p["wte"] = _dense_init(nk(), (V, d), d, dtype)
    if cfg.pos_emb == "learned":
        p["wpe"] = _dense_init(nk(), (cfg.max_position, d), 1.0, dtype) * 0.02

    if cfg.family in ("dense", "vlm", "audio", "moe", "gpt2"):
        attn: Dict[str, Any] = {}
        if cfg.fused_qkv:
            attn["c_attn"] = _dense_init(nk(), (Lc, d, 3 * d), d, dtype)
            attn["b_attn"] = jnp.zeros((Lc, 3 * d), dtype)
            attn["c_proj"] = _dense_init(nk(), (Lc, d, d), d, dtype)
            attn["b_proj"] = jnp.zeros((Lc, d), dtype)
        else:
            attn["wq"] = _dense_init(nk(), (Lc, d, H * Dh), d, dtype)
            attn["wk"] = _dense_init(nk(), (Lc, d, KH * Dh), d, dtype)
            attn["wv"] = _dense_init(nk(), (Lc, d, KH * Dh), d, dtype)
            attn["wo"] = _dense_init(nk(), (Lc, H * Dh, d), H * Dh, dtype)
            if cfg.qk_norm:
                attn["q_norm"] = jnp.ones((Lc, Dh), dtype)
                attn["k_norm"] = jnp.ones((Lc, Dh), dtype)
        blk: Dict[str, Any] = {"ln1": norm_p(d), "ln2": norm_p(d),
                               "attn": attn}
        if cfg.family == "moe":
            fe = cfg.moe_d_ff
            E = cfg.n_experts
            blk["moe"] = {
                "router": _dense_init(nk(), (Lc, d, E), d, dtype),
                "w_gate": _dense_init(nk(), (Lc, E, d, fe), d, dtype),
                "w_up": _dense_init(nk(), (Lc, E, d, fe), d, dtype),
                "w_down": _dense_init(nk(), (Lc, E, fe, d), fe, dtype),
            }
        elif cfg.act == "gelu":
            blk["mlp"] = {
                "c_fc": _dense_init(nk(), (Lc, d, f), d, dtype),
                "b_fc": jnp.zeros((Lc, f), dtype),
                "c_proj": _dense_init(nk(), (Lc, f, d), f, dtype),
                "b_proj": jnp.zeros((Lc, d), dtype),
            }
        else:
            blk["mlp"] = {
                "w_gate": _dense_init(nk(), (Lc, d, f), d, dtype),
                "w_up": _dense_init(nk(), (Lc, d, f), d, dtype),
                "w_down": _dense_init(nk(), (Lc, f, d), f, dtype),
            }
        p["layers"] = blk

    elif cfg.family in ("ssm", "hybrid"):
        dd = M2.ssm_dims(cfg)
        p["layers"] = {
            "ln1": norm_p(d),
            "ssm": {
                "in_proj": _dense_init(nk(), (Lc, d, dd["d_proj"]), d, dtype),
                "out_proj": _dense_init(nk(), (Lc, dd["d_inner"], d),
                                        dd["d_inner"], dtype),
                "conv_w": _dense_init(nk(), (Lc, cfg.ssm_conv_width,
                                             dd["conv_ch"]), 4.0, dtype),
                "conv_b": jnp.zeros((Lc, dd["conv_ch"]), dtype),
                "A_log": jnp.zeros((Lc, dd["n_heads"]), jnp.float32),
                "D": jnp.ones((Lc, dd["n_heads"]), jnp.float32),
                "dt_bias": jnp.zeros((Lc, dd["n_heads"]), jnp.float32),
                "norm_w": jnp.ones((Lc, dd["d_inner"]), dtype),
            },
        }
        if cfg.family == "hybrid":
            d2 = 2 * d
            fh = cfg.hybrid_attn_d_ff or cfg.d_ff
            Dh2 = d2 // cfg.n_heads
            p["shared"] = {
                "ln1": {"w": jnp.ones((d2,), dtype)},
                "ln2": {"w": jnp.ones((d2,), dtype)},
                "attn": {
                    "wq": _dense_init(nk(), (d2, H * Dh2), d2, dtype),
                    "wk": _dense_init(nk(), (d2, KH * Dh2), d2, dtype),
                    "wv": _dense_init(nk(), (d2, KH * Dh2), d2, dtype),
                    "wo": _dense_init(nk(), (H * Dh2, d2), H * Dh2, dtype),
                },
                "mlp": {
                    "w_gate": _dense_init(nk(), (d2, fh), d2, dtype),
                    "w_up": _dense_init(nk(), (d2, fh), d2, dtype),
                    "w_down": _dense_init(nk(), (fh, d2), fh, dtype),
                },
                "proj_out": _dense_init(nk(), (d2, d), d2, dtype),
            }
    else:
        raise ValueError(cfg.family)

    p["ln_f"] = norm_p(d, stacked=False)
    if not cfg.tie_embeddings:
        p["lm_head"] = _dense_init(nk(), (d, V), d, dtype)
    return p


# ---------------------------------------------------------------------------
# shared pieces
# ---------------------------------------------------------------------------

def _maybe_dequant(w):
    return dequantize(w, dtype=jnp.bfloat16) if isinstance(w, QTensor) else w


def _embed(params, cfg: ModelConfig, tokens=None, embeds=None,
           positions=None):
    if embeds is not None:
        h = embeds
    else:
        wte = _maybe_dequant(params["wte"])
        h = wte[tokens].astype(jnp.dtype(cfg.dtype))
    if cfg.pos_emb == "learned":
        wpe = params["wpe"]
        h = h + wpe[positions].astype(h.dtype)
    elif cfg.pos_emb == "sincos":
        h = h + L.sincos_pos_emb(positions, cfg.d_model).astype(h.dtype)
    return h


def _logits(params, cfg: ModelConfig, h, impl="auto", interpret=False):
    if cfg.tie_embeddings:
        wte = _maybe_dequant(params["wte"])
        return jnp.einsum("...d,vd->...v", h.astype(jnp.float32),
                          wte.astype(jnp.float32))
    CAL.tap("lm_head", h)
    out = L.dense(h, params["lm_head"], impl=impl, interpret=interpret)
    return out.astype(jnp.float32)


def _tp_attn_shards(cfg: ModelConfig) -> int:
    """Serve-TP shard count over attention heads (1 when inactive)."""
    plan = SH.serve_tp_plan()
    return plan.size if (plan is not None and plan.attn) else 1


def _qkv(a_in, lp, cfg: ModelConfig, impl, interpret):
    B, S, _ = a_in.shape
    H, KH, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    # serve TP (shard_map): wq/wk/wv are lane-sharded, so this shard's
    # projection output block IS its contiguous run of whole heads -- no
    # collective here; the KV cache co-shards over kv_heads and attention
    # below runs shape-generically on the local head counts (slicing the
    # head BATCH dim keeps each head's sub-problem the same shape, so
    # per-head attention math is bit-identical across tp degrees)
    s = _tp_attn_shards(cfg)
    H, KH = H // s, KH // s
    attn = lp["attn"]
    if cfg.fused_qkv:
        CAL.tap("attn/c_attn", a_in)
        qkv = L.dense(a_in, attn["c_attn"], impl=impl, interpret=interpret)
        qkv = qkv + attn["b_attn"].astype(qkv.dtype)
        q, k, v = jnp.split(qkv, 3, axis=-1)
    elif s > 1:
        q = L.tp_lane_dense(a_in, attn["wq"], "local", impl=impl,
                            interpret=interpret)
        k = L.tp_lane_dense(a_in, attn["wk"], "local", impl=impl,
                            interpret=interpret)
        v = L.tp_lane_dense(a_in, attn["wv"], "local", impl=impl,
                            interpret=interpret)
    else:
        CAL.tap(("attn/wq", "attn/wk", "attn/wv"), a_in)
        q = L.dense(a_in, attn["wq"], impl=impl, interpret=interpret)
        k = L.dense(a_in, attn["wk"], impl=impl, interpret=interpret)
        v = L.dense(a_in, attn["wv"], impl=impl, interpret=interpret)
    q = SH.constrain(q.reshape(B, S, H, Dh), "dp", None, "model", None)
    k = SH.constrain(k.reshape(B, S, KH, Dh), "dp", None, "model", None)
    v = SH.constrain(v.reshape(B, S, KH, Dh), "dp", None, "model", None)
    if cfg.qk_norm:
        q = L.rmsnorm(q, attn["q_norm"], cfg.norm_eps)
        k = L.rmsnorm(k, attn["k_norm"], cfg.norm_eps)
    return q, k, v


def _attn_out(o, lp, cfg, impl, interpret):
    B, S = o.shape[:2]
    o = SH.constrain(o, "dp", None, "model", None)
    o = o.reshape(B, S, o.shape[2] * o.shape[3])    # local heads * Dh
    attn = lp["attn"]
    CAL.tap("attn/c_proj" if cfg.fused_qkv else "attn/wo", o)
    if _tp_attn_shards(cfg) > 1:
        plan = SH.serve_tp_plan()
        if plan is not None and plan.attn_row:
            # row-parallel sliced path: this shard's contiguous heads ARE
            # a contiguous K-row slice of wo, so the local head outputs
            # feed wo's partial gemm directly and ONE psum assembles the
            # d_model output -- no head gather, no lane gather
            out = L.tp_row_dense(o, attn["wo"], plan.attn_row, impl=impl,
                                 interpret=interpret)
            return SH.constrain(out, "dp", None, None)
        if plan is not None and plan.matmul == "sliced_row":
            # no row layout for wo (plan built without params): ring
            # collective-matmul hides the head gather behind the chunked
            # o-proj gemms
            out = L.tp_ring_dense(o, attn["wo"], impl=impl,
                                  interpret=interpret)
            return SH.constrain(out, "dp", None, None)
        # lane path: wo keeps its K rows (all heads) whole per shard, so
        # gather the head outputs (exact tiled all-gather), then one
        # more gather assembles wo's d_model lanes
        o = kops.tp_gather_lanes(o)
        out = L.tp_lane_dense(o, attn["wo"], "full", impl=impl,
                              interpret=interpret)
        return SH.constrain(out, "dp", None, None)
    if cfg.fused_qkv:
        out = L.dense(o, attn["c_proj"], impl=impl, interpret=interpret)
        out = SH.constrain(out, "dp", None, None)
        return out + attn["b_proj"].astype(out.dtype)
    out = L.dense(o, attn["wo"], impl=impl, interpret=interpret)
    return SH.constrain(out, "dp", None, None)


def _seq_attention(q, k, v, cfg: ModelConfig, S: int,
                   interpret: bool = False):
    impl = cfg.attn_impl
    if impl == "auto":
        impl = "naive" if S <= 2048 else "blockwise"
    if impl == "naive":
        return L.naive_attention(q, k, v, causal=True,
                                 window=cfg.sliding_window,
                                 softcap=cfg.attn_logit_softcap)
    if impl == "fused":
        B, S2 = q.shape[0], q.shape[1]
        pos = jnp.broadcast_to(jnp.arange(S2, dtype=jnp.int32)[None],
                               (B, S2))
        return L.prefill_attn_fused(q, k, v, pos, pos,
                                    window=cfg.sliding_window,
                                    softcap=cfg.attn_logit_softcap,
                                    interpret=interpret)
    return L.blockwise_attention(q, k, v, causal=True,
                                 window=cfg.sliding_window,
                                 softcap=cfg.attn_logit_softcap,
                                 q_chunk=cfg.attn_q_chunk,
                                 kv_chunk=cfg.attn_kv_chunk,
                                 unroll=_unroll(cfg))


# ---------------------------------------------------------------------------
# full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------

def _attn_layer_seq(h, lp, cfg: ModelConfig, cos_sin, *, impl, interpret,
                    want_cache: bool):
    B, S, _ = h.shape
    a_in = L.norm(h, lp["ln1"], cfg.norm_type, cfg.norm_eps)
    q, k, v = _qkv(a_in, lp, cfg, impl, interpret)
    if cos_sin is not None:
        cos, sin = cos_sin
        q = L.apply_rope(q, cos, sin)
        k = L.apply_rope(k, cos, sin)
    o = _seq_attention(q, k, v, cfg, S, interpret)
    h = h + _attn_out(o, lp, cfg, impl, interpret)
    m_in = L.norm(h, lp["ln2"], cfg.norm_type, cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "moe":
        mo, aux = MOE.moe_block(m_in, lp["moe"], cfg, impl=impl,
                                interpret=interpret)
        h = h + mo
    elif cfg.act == "gelu":
        h = h + L.gelu_mlp(m_in, lp["mlp"], impl=impl, interpret=interpret)
    else:
        h = h + L.swiglu_mlp(m_in, lp["mlp"], impl=impl, interpret=interpret)
    kv = (k, v) if want_cache else None
    return h, aux, kv


def _unroll(cfg):
    return True if cfg.scan_unroll else 1


def forward_seq(params, cfg: ModelConfig, *, tokens=None, embeds=None,
                positions=None, want_cache: bool = False,
                return_hidden: bool = False,
                interpret: bool = False):
    """Full-sequence forward. Returns (logits f32 (B,S,V), aux_loss, kv_list).

    return_hidden: return final-norm hidden states instead of logits (the
    chunked vocab-sharded loss computes its own head matmul; see
    training/steps.py).
    kv_list (if want_cache): per-family cache payload of the whole sequence.
    """
    impl = cfg.kernel_impl
    if tokens is not None:
        B, S = tokens.shape
    else:
        B, S = embeds.shape[:2]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        if cfg.pos_emb == "mrope":
            positions = jnp.broadcast_to(positions[None], (3, B, S))
    pos2d = positions[0] if positions.ndim == 3 else positions
    h = _embed(params, cfg, tokens=tokens, embeds=embeds, positions=pos2d)

    cos_sin = None
    if cfg.pos_emb in ("rope", "mrope"):
        cos_sin = L.rope_cos_sin(
            positions if cfg.pos_emb == "mrope" else pos2d,
            cfg.d_head, cfg.rope_theta,
            cfg.mrope_sections if cfg.pos_emb == "mrope" else None)

    aux_total = jnp.zeros((), jnp.float32)
    caches: Any = None

    if cfg.family in ("dense", "vlm", "audio", "moe", "gpt2"):
        def body(carry, lp):
            hh, aux = carry
            hh, a, kv = _attn_layer_seq(hh, lp, cfg, cos_sin, impl=impl,
                                        interpret=interpret,
                                        want_cache=want_cache)
            return (hh, aux + a), kv
        body_fn = jax.checkpoint(body) if cfg.remat else body
        (h, aux_total), kvs = jax.lax.scan(body_fn, (h, aux_total),
                                           params["layers"],
                                           unroll=_unroll(cfg))
        caches = kvs                     # (k (L,B,S,KH,Dh), v (...)) or None

    elif cfg.family == "ssm":
        def body(carry, lp):
            hh = carry
            a_in = L.norm(hh, lp["ln1"], cfg.norm_type, cfg.norm_eps)
            out, (cstate, sstate) = M2.mamba2_forward(
                a_in, lp["ssm"], cfg, impl=impl, interpret=interpret)
            return hh + out, (cstate, sstate)
        body_fn = jax.checkpoint(body) if cfg.remat else body
        h, states = jax.lax.scan(body_fn, h, params["layers"],
                                 unroll=_unroll(cfg))
        caches = states                  # (conv (L,B,W-1,C), ssm (L,B,H,P,N))

    elif cfg.family == "hybrid":
        h, caches = _hybrid_forward_seq(params, cfg, h, want_cache,
                                        impl, interpret)
    else:
        raise ValueError(cfg.family)

    h = L.norm(h, params["ln_f"], cfg.norm_type, cfg.norm_eps)
    if return_hidden:
        return h, aux_total, caches
    logits = _logits(params, cfg, h, impl=impl, interpret=interpret)
    return logits, aux_total, caches


def _shared_block_seq(h, emb0, sp, cfg: ModelConfig, *, impl, interpret,
                      want_cache):
    """Zamba2 shared attention block over (h ++ initial-embedding)."""
    B, S, d = h.shape
    u = jnp.concatenate([h, emb0], axis=-1)                 # (B,S,2d)
    a_in = L.rmsnorm(u, sp["ln1"]["w"], cfg.norm_eps)
    Dh2 = 2 * d // cfg.n_heads
    q = L.dense(a_in, sp["attn"]["wq"], impl=impl, interpret=interpret)
    k = L.dense(a_in, sp["attn"]["wk"], impl=impl, interpret=interpret)
    v = L.dense(a_in, sp["attn"]["wv"], impl=impl, interpret=interpret)
    q = q.reshape(B, S, cfg.n_heads, Dh2)
    k = k.reshape(B, S, cfg.n_kv_heads, Dh2)
    v = v.reshape(B, S, cfg.n_kv_heads, Dh2)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    cos, sin = L.rope_cos_sin(pos, Dh2, cfg.rope_theta)
    q = L.apply_rope(q, cos, sin)
    k = L.apply_rope(k, cos, sin)
    o = _seq_attention(q, k, v, cfg, S, interpret)
    o = o.reshape(B, S, cfg.n_heads * Dh2)
    u = u + L.dense(o, sp["attn"]["wo"], impl=impl, interpret=interpret)
    m_in = L.rmsnorm(u, sp["ln2"]["w"], cfg.norm_eps)
    u = u + L.swiglu_mlp(m_in, sp["mlp"], impl=impl, interpret=interpret)
    out = L.dense(u, sp["proj_out"], impl=impl, interpret=interpret)
    kv = (k, v) if want_cache else None
    return h + out, kv


def _hybrid_groups(cfg: ModelConfig):
    """Layer-group sizes between shared-block applications."""
    k = cfg.hybrid_attn_every
    n = cfg.n_layers
    groups = []
    while n > 0:
        groups.append(min(k, n))
        n -= k
    return groups


def _hybrid_forward_seq(params, cfg, h, want_cache, impl, interpret):
    emb0 = h
    groups = _hybrid_groups(cfg)
    conv_states, ssm_states, shared_kvs = [], [], []
    i0 = 0
    for gi, g in enumerate(groups):
        lp = jax.tree.map(lambda a: a[i0:i0 + g], params["layers"])
        i0 += g

        def body(carry, lpl):
            hh = carry
            a_in = L.norm(hh, lpl["ln1"], cfg.norm_type, cfg.norm_eps)
            out, (cs, ss) = M2.mamba2_forward(a_in, lpl["ssm"], cfg,
                                              impl=impl, interpret=interpret)
            return hh + out, (cs, ss)
        body_fn = jax.checkpoint(body) if cfg.remat else body
        h, (cs, ss) = jax.lax.scan(body_fn, h, lp, unroll=_unroll(cfg))
        conv_states.append(cs)
        ssm_states.append(ss)
        if g == cfg.hybrid_attn_every:    # full group -> shared block
            h, kv = _shared_block_seq(h, emb0, params["shared"], cfg,
                                      impl=impl, interpret=interpret,
                                      want_cache=want_cache)
            if want_cache:
                shared_kvs.append(kv)
    caches = (jnp.concatenate(conv_states, 0),
              jnp.concatenate(ssm_states, 0),
              (jnp.stack([k for k, _ in shared_kvs]),
               jnp.stack([v for _, v in shared_kvs])) if shared_kvs and
              want_cache else None)
    return h, caches


# ---------------------------------------------------------------------------
# decode (single new token against a cache)
# ---------------------------------------------------------------------------

def attn_cache_len(cfg: ModelConfig, seq_len: int) -> int:
    """Ring-buffer length: sliding-window archs only keep the window."""
    if cfg.sliding_window:
        return min(cfg.sliding_window, seq_len)
    return seq_len


def init_cache(cfg: ModelConfig, B: int, seq_len: int,
               dtype=jnp.bfloat16) -> Dict[str, Any]:
    """Zero/empty decode cache sized for contexts up to ``seq_len``."""
    Lc = cfg.n_layers
    cache: Dict[str, Any] = {}
    if cfg.family in ("dense", "vlm", "audio", "moe", "gpt2"):
        T = attn_cache_len(cfg, seq_len)
        KH, Dh = cfg.n_kv_heads, cfg.d_head
        kdt = jnp.int8 if cfg.kv_cache_quant else dtype
        cache["k"] = jnp.zeros((Lc, B, T, KH, Dh), kdt)
        cache["v"] = jnp.zeros((Lc, B, T, KH, Dh), kdt)
        if cfg.kv_cache_quant:
            cache["k_scale"] = jnp.zeros((Lc, B, T, KH), jnp.float32)
            cache["v_scale"] = jnp.zeros((Lc, B, T, KH), jnp.float32)
        cache["pos"] = jnp.full((B, T), -1, jnp.int32)
    elif cfg.family == "ssm":
        dd = M2.ssm_dims(cfg)
        cache["conv"] = jnp.zeros((Lc, B, cfg.ssm_conv_width - 1,
                                   dd["conv_ch"]), dtype)
        cache["state"] = jnp.zeros((Lc, B, dd["n_heads"], dd["head_dim"],
                                    dd["state"]), jnp.float32)
    elif cfg.family == "hybrid":
        dd = M2.ssm_dims(cfg)
        cache["conv"] = jnp.zeros((Lc, B, cfg.ssm_conv_width - 1,
                                   dd["conv_ch"]), dtype)
        cache["state"] = jnp.zeros((Lc, B, dd["n_heads"], dd["head_dim"],
                                    dd["state"]), jnp.float32)
        napp = sum(1 for g in _hybrid_groups(cfg)
                   if g == cfg.hybrid_attn_every)
        T = attn_cache_len(cfg, seq_len)
        Dh2 = 2 * cfg.d_model // cfg.n_heads
        cache["k"] = jnp.zeros((napp, B, T, cfg.n_kv_heads, Dh2), dtype)
        cache["v"] = jnp.zeros((napp, B, T, cfg.n_kv_heads, Dh2), dtype)
        cache["pos"] = jnp.full((B, T), -1, jnp.int32)
    else:
        raise ValueError(cfg.family)
    return cache


def _quantize_kv(x):
    """x: (B, KH, Dh) -> (int8 values, per-(B,KH) scale)."""
    amax = jnp.abs(x.astype(jnp.float32)).max(axis=-1)
    scale = amax / 127.0
    inv = jnp.where(scale > 0, 1.0 / jnp.where(scale > 0, scale, 1.0), 0.0)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) * inv[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def _attn_layer_decode(h, lp, kc, vc, slot_pos, position, slot, cfg,
                       cos_sin, impl, interpret, ks=None, vs=None,
                       live=None):
    """h: (B,1,d); kc/vc: (B,T,KH,Dh); position/slot: (B,).
    ks/vs: (B,T,KH) int8-cache scales when cfg.kv_cache_quant.
    live: (B,) bool -- dead slots leave the cache untouched (their logits
    are garbage and must be ignored by the caller)."""
    B = h.shape[0]
    a_in = L.norm(h, lp["ln1"], cfg.norm_type, cfg.norm_eps)
    q, k, v = _qkv(a_in, lp, cfg, impl, interpret)
    if cos_sin is not None:
        cos, sin = cos_sin
        q = L.apply_rope(q, cos, sin)
        k = L.apply_rope(k, cos, sin)
    bidx = jnp.arange(B)

    def sel(new, old, extra_dims):
        if live is None:
            return new
        return jnp.where(live.reshape((B,) + (1,) * extra_dims), new, old)

    if cfg.kv_cache_quant:
        kq, kscale = _quantize_kv(k[:, 0])
        vq, vscale = _quantize_kv(v[:, 0])
        kc = kc.at[bidx, slot].set(sel(kq, kc[bidx, slot], 2))
        vc = vc.at[bidx, slot].set(sel(vq, vc[bidx, slot], 2))
        ks = ks.at[bidx, slot].set(sel(kscale, ks[bidx, slot], 1))
        vs = vs.at[bidx, slot].set(sel(vscale, vs[bidx, slot], 1))
        k_eff = kc.astype(jnp.float32) * ks[..., None]
        v_eff = vc.astype(jnp.float32) * vs[..., None]
    else:
        kc = kc.at[bidx, slot].set(
            sel(k[:, 0].astype(kc.dtype), kc[bidx, slot], 2))
        vc = vc.at[bidx, slot].set(
            sel(v[:, 0].astype(vc.dtype), vc[bidx, slot], 2))
        k_eff, v_eff = kc, vc
    o = L.decode_attention(q, k_eff, v_eff, slot_pos, position,
                           window=cfg.sliding_window,
                           softcap=cfg.attn_logit_softcap)
    h = h + _attn_out(o, lp, cfg, impl, interpret)
    m_in = L.norm(h, lp["ln2"], cfg.norm_type, cfg.norm_eps)
    if cfg.family == "moe":
        mo, _ = MOE.moe_block(m_in, lp["moe"], cfg, impl=impl,
                              interpret=interpret)
        h = h + mo
    elif cfg.act == "gelu":
        h = h + L.gelu_mlp(m_in, lp["mlp"], impl=impl, interpret=interpret)
    else:
        h = h + L.swiglu_mlp(m_in, lp["mlp"], impl=impl, interpret=interpret)
    return h, kc, vc, ks, vs


def decode_step(params, cfg: ModelConfig, cache: Dict[str, Any], *,
                tokens=None, embeds=None, position=None, live=None,
                interpret: bool = False):
    """One decode step. tokens: (B,) int32 or embeds: (B, d); position: (B,)
    absolute per-slot position of the new token. Returns
    (logits (B,V) f32, cache).

    live: optional (B,) bool slot mask for continuous batching -- dead
    slots run the math (static shapes) but do NOT mutate their cache or
    position book-keeping, so a freed slot can be re-admitted later
    without stale-state leakage. Logits of dead slots are undefined."""
    impl = cfg.kernel_impl
    B = tokens.shape[0] if tokens is not None else embeds.shape[0]
    h = _embed(params, cfg, tokens=tokens, embeds=embeds, positions=position)
    h = h[:, None, :] if h.ndim == 2 else h                 # (B,1,d)

    cos_sin = None
    if cfg.pos_emb in ("rope", "mrope"):
        pos_r = position[:, None]                           # (B,1)
        if cfg.pos_emb == "mrope":
            pos_r = jnp.broadcast_to(pos_r[None], (3, B, 1))
        cos_sin = L.rope_cos_sin(
            pos_r, cfg.d_head, cfg.rope_theta,
            cfg.mrope_sections if cfg.pos_emb == "mrope" else None)

    new_cache = dict(cache)
    Lc = cfg.n_layers
    lidx = jnp.arange(Lc)
    if cfg.family in ("dense", "vlm", "audio", "moe", "gpt2"):
        T = cache["k"].shape[2]
        slot = position % T
        pos_new = position if live is None else jnp.where(
            live, position, cache["pos"][jnp.arange(B), slot])
        slot_pos = cache["pos"].at[jnp.arange(B), slot].set(pos_new)
        new_cache["pos"] = slot_pos

        quant = cfg.kv_cache_quant

        # caches ride the scan *carry* and are updated in place with
        # dynamic_update_index so XLA can alias the buffers step-to-step
        def body(carry, xs):
            hh, kall, vall, ksall, vsall = carry
            lp, li = xs
            idx = lambda a: jax.lax.dynamic_index_in_dim(a, li, 0,
                                                         keepdims=False)
            upd = lambda a, x: jax.lax.dynamic_update_index_in_dim(a, x,
                                                                   li, 0)
            ks = idx(ksall) if quant else None
            vs = idx(vsall) if quant else None
            hh, kc, vc, ks, vs = _attn_layer_decode(
                hh, lp, idx(kall), idx(vall), slot_pos, position, slot,
                cfg, cos_sin, impl, interpret, ks=ks, vs=vs, live=live)
            kall, vall = upd(kall, kc), upd(vall, vc)
            if quant:
                ksall, vsall = upd(ksall, ks), upd(vsall, vs)
            return (hh, kall, vall, ksall, vsall), None

        zero = jnp.zeros((), jnp.float32)
        (h, knew, vnew, ksnew, vsnew), _ = jax.lax.scan(
            body, (h, cache["k"], cache["v"],
                   cache.get("k_scale", zero), cache.get("v_scale", zero)),
            (params["layers"], lidx), unroll=_unroll(cfg))
        new_cache["k"], new_cache["v"] = knew, vnew
        if quant:
            new_cache["k_scale"], new_cache["v_scale"] = ksnew, vsnew

    elif cfg.family == "ssm":
        def body(carry, xs):
            hh, call, sall = carry
            lp, li = xs
            cs = jax.lax.dynamic_index_in_dim(call, li, 0, keepdims=False)
            ss = jax.lax.dynamic_index_in_dim(sall, li, 0, keepdims=False)
            a_in = L.norm(hh, lp["ln1"], cfg.norm_type, cfg.norm_eps)
            out, (cs2, ss2) = M2.mamba2_decode(a_in[:, 0], lp["ssm"], cfg,
                                               cs, ss, impl=impl,
                                               interpret=interpret)
            if live is not None:
                cs2 = jnp.where(live[:, None, None], cs2, cs)
                ss2 = jnp.where(live[:, None, None, None], ss2, ss)
            call = jax.lax.dynamic_update_index_in_dim(call, cs2.astype(
                call.dtype), li, 0)
            sall = jax.lax.dynamic_update_index_in_dim(sall, ss2, li, 0)
            return (hh + out[:, None], call, sall), None

        (h, cnew, snew), _ = jax.lax.scan(
            body, (h, cache["conv"], cache["state"]),
            (params["layers"], lidx), unroll=_unroll(cfg))
        new_cache["conv"], new_cache["state"] = cnew, snew

    elif cfg.family == "hybrid":
        h, new_cache = _hybrid_decode(params, cfg, h, cache, position,
                                      impl, interpret, live=live)
    else:
        raise ValueError(cfg.family)

    h = L.norm(h, params["ln_f"], cfg.norm_type, cfg.norm_eps)
    logits = _logits(params, cfg, h[:, 0], impl=impl, interpret=interpret)
    return logits, new_cache


def lm_logits(params, cfg: ModelConfig, h, *, interpret: bool = False):
    """LM head on final-norm hidden states h (..., d) -> logits f32.

    Public so schedulers can gather the few hidden rows they need (e.g.
    each sequence's last prompt token) and run the vocab matmul on just
    those, instead of materializing (B, S, V) logits."""
    return _logits(params, cfg, h, impl=cfg.kernel_impl, interpret=interpret)


def prefill_chunk(params, cfg: ModelConfig, cache: Dict[str, Any], *,
                  tokens, start, lengths, cached_lengths=None,
                  interpret: bool = False):
    """One batched prefill chunk against a decode cache (attention families).

    tokens: (B, C) int32, right-padded; start: () int32 absolute position of
    column 0 (same for every row -- the scheduler pads the batch to a shared
    bucketed length); lengths: (B,) true prompt lengths. Columns at
    positions >= lengths are padding: they run the math (static shapes) but
    never write the KV ring and never win attention (write index driven out
    of range -> scatter drop). A row with length 0 is a group-padding dummy.

    cached_lengths: optional (B,) -- row ``b``'s positions below
    ``cached_lengths[b]`` are ALREADY resident in the ring (scattered from
    a prefix cache, bit-for-bit the values a cold prefill would have
    written). Those columns are masked out exactly like padding: they
    neither rewrite the ring nor act as in-chunk keys, while the suffix's
    queries still attend them through the ring -- the same dataflow a
    later chunk of a cold multi-chunk prefill uses for earlier chunks'
    keys, which is what keeps warm prefill token-identical to cold.

    Feeding a prompt through successive chunks is exact: each chunk's
    queries attend the pre-chunk ring plus the chunk's own keys (see
    ``layers.prefill_attention``), then the chunk's K/V land in the ring at
    ``position % T`` -- identical semantics to running ``decode_step`` once
    per token, but with MatMul-shaped batches. Requires C <= ring length
    (in-chunk positions must map to distinct slots).

    Recurrent families (ssm / hybrid) run the same masked-chunk contract
    through ``_recurrent_chunk``: invalid columns are identity on the
    conv/SSM state (dt zeroed, conv tail gathered at each row's last valid
    column), so trailing pads never pollute recurrent state and one
    compiled (B, C) program serves every prompt length. ``cached_lengths``
    is ignored there: recurrent state is positional, so a warm prefix
    admission restores a checkpoint and starts the chunk GRID at the
    cached horizon instead of masking per-row.

    Returns (final-norm hidden (B, C, d), new cache). Callers that only
    need logits for some rows/offsets should gather from the hidden states
    and apply ``lm_logits`` there.
    """
    B, C = tokens.shape
    positions = jnp.broadcast_to(
        start + jnp.arange(C, dtype=jnp.int32)[None], (B, C))
    valid = positions < lengths[:, None]
    if cfg.family in ("ssm", "hybrid"):
        return _recurrent_chunk(params, cfg, cache, tokens, positions,
                                valid, interpret)
    if cached_lengths is not None:
        valid = valid & (positions >= cached_lengths[:, None])
    attn_fn = L.prefill_attention
    if cfg.attn_impl == "fused":
        # flash-style Pallas kernel for the chunk-vs-ring attention
        # (interpret mode runs it on CPU); verify_chunk keeps its scan
        attn_fn = functools.partial(L.prefill_attention, impl="fused",
                                    interpret=interpret)
    return _masked_chunk(params, cfg, cache, tokens, positions, valid,
                         attn_fn, interpret)


def verify_chunk(params, cfg: ModelConfig, cache: Dict[str, Any], *,
                 tokens, positions, valid, interpret: bool = False):
    """Score a per-slot block of tokens against the decode cache in ONE
    batched forward (the speculative-decoding verify pass).

    Same masked program shape as ``prefill_chunk`` but ``positions``
    (B, C) is explicit and per-row: slot b's block starts at its own
    absolute position (its draft block), so a continuous batch can verify
    k drafted tokens per speculating slot while plain slots run a 1-column
    decode step through the same program. ``valid`` (B, C) masks the
    columns that really run; invalid columns never write the KV ring and
    never win attention. Writes for positions that later turn out to be
    rejected drafts are un-done by ``cache_ring_rewind``."""
    return _masked_chunk(params, cfg, cache, tokens, positions, valid,
                         L.verify_attention, interpret)


def verify_scan(params, cfg: ModelConfig, cache: Dict[str, Any], *,
                tokens, positions, valid, interpret: bool = False):
    """Bit-exact verify: scan ``decode_step`` over the block's columns.

    Same signature/semantics as ``verify_chunk`` but returns per-column
    LOGITS (B, S, V) directly and guarantees each column's numbers are
    BIT-identical to plain decode's: every column runs the very same
    (B,)-shaped decode_step graph plain decode runs, so XLA makes the
    same fusion/rounding choices. The batched ``verify_chunk`` scores the
    whole block in one masked forward -- higher arithmetic intensity, but
    a differently-shaped program whose logits can differ from decode's by
    a float ulp and flip a greedy argmax on a near-tie. Scan mode is what
    backs the engine's greedy-parity guarantee; batched mode is the
    throughput path. Both stay inside one jitted program per chunk."""
    def body(c, xs):
        tk, po, ok = xs
        logits, c = decode_step(params, cfg, c, tokens=tk, position=po,
                                live=ok, interpret=interpret)
        return c, logits

    cache, lgs = jax.lax.scan(body, cache,
                              (tokens.T, positions.T, valid.T))
    return jnp.moveaxis(lgs, 0, 1), cache


def _masked_chunk(params, cfg: ModelConfig, cache, tokens, positions,
                  valid, attn_fn, interpret):
    """Shared body of prefill_chunk / verify_chunk: one (B, C) masked
    chunk forward against the ring, writing valid columns at
    ``positions % T``."""
    if cfg.family not in ("dense", "vlm", "audio", "moe", "gpt2"):
        raise NotImplementedError(
            f"the ring-masked chunk body is KV-cache-only; family "
            f"{cfg.family!r} prefills through _recurrent_chunk and cannot "
            f"verify drafts (a dense recurrent state has no ring rewind)")
    impl = cfg.kernel_impl
    B, C = tokens.shape
    T = cache["k"].shape[2]
    assert C <= T, (C, T)
    h = _embed(params, cfg, tokens=tokens, positions=positions)

    cos_sin = None
    if cfg.pos_emb in ("rope", "mrope"):
        pos_r = positions
        if cfg.pos_emb == "mrope":
            pos_r = jnp.broadcast_to(positions[None], (3, B, C))
        cos_sin = L.rope_cos_sin(
            pos_r, cfg.d_head, cfg.rope_theta,
            cfg.mrope_sections if cfg.pos_emb == "mrope" else None)

    bidx = jnp.arange(B)[:, None]
    slot_w = jnp.where(valid, positions % T, T)     # T = out of range: drop
    old_pos = cache["pos"]
    new_cache = dict(cache)
    new_cache["pos"] = old_pos.at[bidx, slot_w].set(positions, mode="drop")
    quant = cfg.kv_cache_quant
    lidx = jnp.arange(cfg.n_layers)

    def body(carry, xs):
        hh, kall, vall, ksall, vsall = carry
        lp, li = xs
        idx = lambda a: jax.lax.dynamic_index_in_dim(a, li, 0, keepdims=False)
        upd = lambda a, x: jax.lax.dynamic_update_index_in_dim(a, x, li, 0)
        kc, vc = idx(kall), idx(vall)
        a_in = L.norm(hh, lp["ln1"], cfg.norm_type, cfg.norm_eps)
        q, k, v = _qkv(a_in, lp, cfg, impl, interpret)
        if cos_sin is not None:
            cos, sin = cos_sin
            q = L.apply_rope(q, cos, sin)
            k = L.apply_rope(k, cos, sin)
        if quant:
            ks, vs = idx(ksall), idx(vsall)
            kq, kscale = _quantize_kv(k)            # (B,C,KH,Dh)/(B,C,KH)
            vq, vscale = _quantize_kv(v)
            kc_eff = kc.astype(jnp.float32) * ks[..., None]
            vc_eff = vc.astype(jnp.float32) * vs[..., None]
            # attend the quantized reconstruction of the chunk's own keys
            # so results do not depend on where chunk boundaries fall
            k_chunk = kq.astype(jnp.float32) * kscale[..., None]
            v_chunk = vq.astype(jnp.float32) * vscale[..., None]
        else:
            kc_eff, vc_eff = kc, vc
            k_chunk = k.astype(kc.dtype)            # ring-dtype rounding,
            v_chunk = v.astype(vc.dtype)            # same reason as above
        o = attn_fn(q, kc_eff, vc_eff, old_pos, k_chunk,
                    v_chunk, positions, valid,
                    window=cfg.sliding_window,
                    softcap=cfg.attn_logit_softcap)
        if quant:
            kall = upd(kall, kc.at[bidx, slot_w].set(kq, mode="drop"))
            vall = upd(vall, vc.at[bidx, slot_w].set(vq, mode="drop"))
            ksall = upd(ksall, ks.at[bidx, slot_w].set(kscale, mode="drop"))
            vsall = upd(vsall, vs.at[bidx, slot_w].set(vscale, mode="drop"))
        else:
            kall = upd(kall, kc.at[bidx, slot_w].set(k_chunk, mode="drop"))
            vall = upd(vall, vc.at[bidx, slot_w].set(v_chunk, mode="drop"))
        hh = hh + _attn_out(o, lp, cfg, impl, interpret)
        m_in = L.norm(hh, lp["ln2"], cfg.norm_type, cfg.norm_eps)
        if cfg.family == "moe":
            mo, _ = MOE.moe_block(m_in, lp["moe"], cfg, impl=impl,
                                  interpret=interpret)
            hh = hh + mo
        elif cfg.act == "gelu":
            hh = hh + L.gelu_mlp(m_in, lp["mlp"], impl=impl,
                                 interpret=interpret)
        else:
            hh = hh + L.swiglu_mlp(m_in, lp["mlp"], impl=impl,
                                   interpret=interpret)
        return (hh, kall, vall, ksall, vsall), None

    zero = jnp.zeros((), jnp.float32)
    (h, knew, vnew, ksnew, vsnew), _ = jax.lax.scan(
        body, (h, cache["k"], cache["v"],
               cache.get("k_scale", zero), cache.get("v_scale", zero)),
        (params["layers"], lidx), unroll=_unroll(cfg))
    new_cache["k"], new_cache["v"] = knew, vnew
    if quant:
        new_cache["k_scale"], new_cache["v_scale"] = ksnew, vsnew
    h = L.norm(h, params["ln_f"], cfg.norm_type, cfg.norm_eps)
    return h, new_cache


def _recurrent_chunk(params, cfg: ModelConfig, cache, tokens, positions,
                     valid, interpret):
    """Masked (B, C) prefill chunk for the recurrent families (ssm /
    hybrid): the batched, length-bucketed counterpart of the KV families'
    ``_masked_chunk``.

    ``valid`` is a contiguous per-row prefix (positions < lengths).
    Invalid columns run the math (static shapes) but are IDENTITY on the
    recurrent state: ``mamba2_forward(valid=...)`` zeroes dt post-softplus
    (decay exp(0)=1, zero input contribution) and gathers the conv tail at
    each row's last valid column, so a row whose prompt ended mid-chunk --
    or a group-padding dummy with length 0 -- carries exactly the state of
    an exact-length run. Because every per-position op is row-independent
    and the scheduler keeps the chunk grid at fixed absolute boundaries,
    batched prefill is token-identical to sequential admission.

    Hybrid additionally runs its shared attention block with the KV-ring
    chunk semantics of ``_masked_chunk``: queries attend the pre-chunk
    ring plus the chunk's own (ring-dtype-rounded) keys, then valid
    columns land in the ring at ``position % T``."""
    impl = cfg.kernel_impl
    B, C = tokens.shape
    h = _embed(params, cfg, tokens=tokens, positions=positions)
    new_cache = dict(cache)

    if cfg.family == "ssm":
        lidx = jnp.arange(cfg.n_layers)

        def body(carry, xs):
            hh, call, sall = carry
            lp, li = xs
            cs = jax.lax.dynamic_index_in_dim(call, li, 0, keepdims=False)
            ss = jax.lax.dynamic_index_in_dim(sall, li, 0, keepdims=False)
            a_in = L.norm(hh, lp["ln1"], cfg.norm_type, cfg.norm_eps)
            out, (cs2, ss2) = M2.mamba2_forward(
                a_in, lp["ssm"], cfg, conv_state=cs, ssm_state=ss,
                valid=valid, impl=impl, interpret=interpret)
            call = jax.lax.dynamic_update_index_in_dim(
                call, cs2.astype(call.dtype), li, 0)
            sall = jax.lax.dynamic_update_index_in_dim(sall, ss2, li, 0)
            return (hh + out, call, sall), None

        (h, cnew, snew), _ = jax.lax.scan(
            body, (h, cache["conv"], cache["state"]),
            (params["layers"], lidx), unroll=_unroll(cfg))
        new_cache["conv"], new_cache["state"] = cnew, snew

    else:                                                    # hybrid
        emb0 = h
        T = cache["k"].shape[2]
        assert C <= T, (C, T)
        bidx = jnp.arange(B)[:, None]
        slot_w = jnp.where(valid, positions % T, T)  # T = out of range: drop
        old_pos = cache["pos"]
        new_cache["pos"] = old_pos.at[bidx, slot_w].set(positions,
                                                        mode="drop")
        groups = _hybrid_groups(cfg)
        conv_parts, state_parts = [], []
        knew, vnew = cache["k"], cache["v"]
        i0 = 0
        app = 0
        for g in groups:
            lp = jax.tree.map(lambda a: a[i0:i0 + g], params["layers"])
            cs = cache["conv"][i0:i0 + g]
            ss = cache["state"][i0:i0 + g]
            i0 += g

            def body(hh, xs):
                lpl, c1, s1 = xs
                a_in = L.norm(hh, lpl["ln1"], cfg.norm_type, cfg.norm_eps)
                out, (c2, s2) = M2.mamba2_forward(
                    a_in, lpl["ssm"], cfg, conv_state=c1, ssm_state=s1,
                    valid=valid, impl=impl, interpret=interpret)
                return hh + out, (c2.astype(c1.dtype), s2)

            h, (cn, sn) = jax.lax.scan(body, h, (lp, cs, ss),
                                       unroll=_unroll(cfg))
            conv_parts.append(cn)
            state_parts.append(sn)
            if g == cfg.hybrid_attn_every:
                h, kc, vc = _shared_block_chunk(
                    h, emb0, params["shared"], cfg, knew[app], vnew[app],
                    old_pos, positions, valid, slot_w, impl, interpret)
                knew = knew.at[app].set(kc)
                vnew = vnew.at[app].set(vc)
                app += 1
        new_cache["conv"] = jnp.concatenate(conv_parts, 0)
        new_cache["state"] = jnp.concatenate(state_parts, 0)
        new_cache["k"], new_cache["v"] = knew, vnew

    h = L.norm(h, params["ln_f"], cfg.norm_type, cfg.norm_eps)
    return h, new_cache


def _shared_block_chunk(h, emb0, sp, cfg, kc, vc, old_pos, positions, valid,
                        slot_w, impl, interpret):
    """Chunked-prefill counterpart of ``_shared_block_decode``: the
    chunk's queries attend the pre-chunk ring plus the chunk's own masked
    keys, then valid columns' K/V land in the ring at ``position % T``
    (same dataflow as the KV families' chunk body)."""
    B, C, d = h.shape
    u = jnp.concatenate([h, emb0], axis=-1)                 # (B,C,2d)
    a_in = L.rmsnorm(u, sp["ln1"]["w"], cfg.norm_eps)
    Dh2 = 2 * d // cfg.n_heads
    q = L.dense(a_in, sp["attn"]["wq"], impl=impl, interpret=interpret)
    k = L.dense(a_in, sp["attn"]["wk"], impl=impl, interpret=interpret)
    v = L.dense(a_in, sp["attn"]["wv"], impl=impl, interpret=interpret)
    q = q.reshape(B, C, cfg.n_heads, Dh2)
    k = k.reshape(B, C, cfg.n_kv_heads, Dh2)
    v = v.reshape(B, C, cfg.n_kv_heads, Dh2)
    cos, sin = L.rope_cos_sin(positions, Dh2, cfg.rope_theta)
    q = L.apply_rope(q, cos, sin)
    k = L.apply_rope(k, cos, sin)
    k_chunk = k.astype(kc.dtype)        # ring-dtype rounding: results do
    v_chunk = v.astype(vc.dtype)        # not depend on chunk boundaries
    o = L.prefill_attention(q, kc, vc, old_pos, k_chunk, v_chunk,
                            positions, valid, window=cfg.sliding_window)
    bidx = jnp.arange(B)[:, None]
    kc = kc.at[bidx, slot_w].set(k_chunk, mode="drop")
    vc = vc.at[bidx, slot_w].set(v_chunk, mode="drop")
    o = o.reshape(B, C, cfg.n_heads * Dh2)
    u = u + L.dense(o, sp["attn"]["wo"], impl=impl, interpret=interpret)
    m_in = L.rmsnorm(u, sp["ln2"]["w"], cfg.norm_eps)
    u = u + L.swiglu_mlp(m_in, sp["mlp"], impl=impl, interpret=interpret)
    out = L.dense(u, sp["proj_out"], impl=impl, interpret=interpret)
    return h + out, kc, vc


def _shared_block_decode(h, emb0, sp, cfg, kc, vc, slot_pos, position, slot,
                         impl, interpret, live=None):
    """h/emb0: (B,1,d); kc/vc: (B,T,KH,Dh2)."""
    B, _, d = h.shape
    u = jnp.concatenate([h, emb0], axis=-1)
    a_in = L.rmsnorm(u, sp["ln1"]["w"], cfg.norm_eps)
    Dh2 = 2 * d // cfg.n_heads
    q = L.dense(a_in, sp["attn"]["wq"], impl=impl, interpret=interpret)
    k = L.dense(a_in, sp["attn"]["wk"], impl=impl, interpret=interpret)
    v = L.dense(a_in, sp["attn"]["wv"], impl=impl, interpret=interpret)
    q = q.reshape(B, 1, cfg.n_heads, Dh2)
    k = k.reshape(B, 1, cfg.n_kv_heads, Dh2)
    v = v.reshape(B, 1, cfg.n_kv_heads, Dh2)
    cos, sin = L.rope_cos_sin(position[:, None], Dh2, cfg.rope_theta)
    q = L.apply_rope(q, cos, sin)
    k = L.apply_rope(k, cos, sin)
    bidx = jnp.arange(B)
    k_new, v_new = k[:, 0].astype(kc.dtype), v[:, 0].astype(vc.dtype)
    if live is not None:
        k_new = jnp.where(live[:, None, None], k_new, kc[bidx, slot])
        v_new = jnp.where(live[:, None, None], v_new, vc[bidx, slot])
    kc = kc.at[bidx, slot].set(k_new)
    vc = vc.at[bidx, slot].set(v_new)
    o = L.decode_attention(q, kc, vc, slot_pos, position,
                           window=cfg.sliding_window)
    o = o.reshape(B, 1, cfg.n_heads * Dh2)
    u = u + L.dense(o, sp["attn"]["wo"], impl=impl, interpret=interpret)
    m_in = L.rmsnorm(u, sp["ln2"]["w"], cfg.norm_eps)
    u = u + L.swiglu_mlp(m_in, sp["mlp"], impl=impl, interpret=interpret)
    out = L.dense(u, sp["proj_out"], impl=impl, interpret=interpret)
    return h + out, kc, vc


def _hybrid_decode(params, cfg, h, cache, position, impl, interpret,
                   live=None):
    emb0 = h
    B = h.shape[0]
    T = cache["k"].shape[2]
    slot = position % T
    pos_new = position if live is None else jnp.where(
        live, position, cache["pos"][jnp.arange(B), slot])
    slot_pos = cache["pos"].at[jnp.arange(B), slot].set(pos_new)
    new_cache = dict(cache)
    new_cache["pos"] = slot_pos
    groups = _hybrid_groups(cfg)
    conv_parts, state_parts = [], []
    knew = cache["k"]
    vnew = cache["v"]
    i0 = 0
    app = 0
    for g in groups:
        lp = jax.tree.map(lambda a: a[i0:i0 + g], params["layers"])
        cs = cache["conv"][i0:i0 + g]
        ss = cache["state"][i0:i0 + g]
        i0 += g

        def body(hh, xs):
            lpl, c1, s1 = xs
            a_in = L.norm(hh, lpl["ln1"], cfg.norm_type, cfg.norm_eps)
            out, (c2, s2) = M2.mamba2_decode(a_in[:, 0], lpl["ssm"], cfg,
                                             c1, s1, impl=impl,
                                             interpret=interpret)
            if live is not None:
                c2 = jnp.where(live[:, None, None], c2, c1)
                s2 = jnp.where(live[:, None, None, None], s2, s1)
            return hh + out[:, None], (c2, s2)

        h, (cn, sn) = jax.lax.scan(body, h, (lp, cs, ss),
                                   unroll=_unroll(cfg))
        conv_parts.append(cn)
        state_parts.append(sn)
        if g == cfg.hybrid_attn_every:
            h, kc, vc = _shared_block_decode(
                h, emb0, params["shared"], cfg, knew[app], vnew[app],
                slot_pos, position, slot, impl, interpret, live=live)
            knew = knew.at[app].set(kc)
            vnew = vnew.at[app].set(vc)
            app += 1
    new_cache["conv"] = jnp.concatenate(conv_parts, 0)
    new_cache["state"] = jnp.concatenate(state_parts, 0)
    new_cache["k"], new_cache["v"] = knew, vnew
    return h, new_cache


def cache_from_prefill(cfg: ModelConfig, caches, seq_len: int,
                       cache_len: Optional[int] = None,
                       dtype=jnp.bfloat16) -> Dict[str, Any]:
    """Convert forward_seq(want_cache=True) payload into a decode cache."""
    T = cache_len or attn_cache_len(cfg, seq_len)
    if cfg.family in ("dense", "vlm", "audio", "moe", "gpt2"):
        k, v = caches                                       # (L,B,S,KH,Dh)
        Lc, B, S = k.shape[:3]
        if S >= T:                                          # keep last T
            k, v = k[:, :, S - T:], v[:, :, S - T:]
            pos = jnp.broadcast_to(jnp.arange(S - T, S)[None], (B, T))
            # ring alignment: slot for position p is p % T
            roll = -((S - T) % T)
            k = jnp.roll(k, roll, axis=2)
            v = jnp.roll(v, roll, axis=2)
            pos = jnp.roll(pos, roll, axis=1)
        else:
            pad = T - S
            k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            pos = jnp.concatenate(
                [jnp.broadcast_to(jnp.arange(S)[None], (B, S)),
                 jnp.full((B, pad), -1, jnp.int32)], axis=1)
        if cfg.kv_cache_quant:
            def qfull(x):
                amax = jnp.abs(x.astype(jnp.float32)).max(axis=-1)
                scale = amax / 127.0
                inv = jnp.where(scale > 0,
                                1.0 / jnp.where(scale > 0, scale, 1.0), 0.0)
                q = jnp.clip(jnp.round(x.astype(jnp.float32)
                                       * inv[..., None]), -127, 127)
                return q.astype(jnp.int8), scale
            kq, ksc = qfull(k)
            vq, vsc = qfull(v)
            return {"k": kq, "v": vq, "k_scale": ksc, "v_scale": vsc,
                    "pos": pos.astype(jnp.int32)}
        return {"k": k.astype(dtype), "v": v.astype(dtype),
                "pos": pos.astype(jnp.int32)}
    if cfg.family == "ssm":
        conv, state = caches
        return {"conv": conv.astype(dtype), "state": state}
    if cfg.family == "hybrid":
        conv, state, kv = caches
        k, v = kv                                           # (napp,B,S,KH,Dh2)
        napp, B, S = k.shape[:3]
        pad = T - S
        assert pad >= 0, "hybrid prefill longer than cache"
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        pos = jnp.concatenate(
            [jnp.broadcast_to(jnp.arange(S)[None], (B, S)),
             jnp.full((B, pad), -1, jnp.int32)], axis=1)
        return {"conv": conv.astype(dtype), "state": state,
                "k": k.astype(dtype), "v": v.astype(dtype), "pos": pos}
    raise ValueError(cfg.family)


def cache_batch_axis(key: str) -> int:
    """Axis of the batch-slot dimension for each decode-cache entry.

    Every family stacks layers (or shared-block applications) at axis 0
    except the per-slot position ring ``pos`` which is (B, T)."""
    return 0 if key == "pos" else 1


def cache_set_slots(cache: Dict[str, Any], group_cache: Dict[str, Any],
                    indices) -> Dict[str, Any]:
    """Scatter a G-request cache batch into batch slots ``indices`` (G,)
    of a multi-slot decode cache in ONE program. ``indices`` may be traced,
    so a single compilation serves every slot assignment (batched
    continuous-batching admission). An index >= B drops that row -- the
    scheduler pads admission groups to a bucketed size with dummy rows and
    points them out of range instead of wasting a real slot on them."""
    out = {}
    for k, v in cache.items():
        upd = group_cache[k].astype(v.dtype)
        if cache_batch_axis(k) == 0:
            out[k] = v.at[indices].set(upd, mode="drop")
        else:
            out[k] = v.at[:, indices].set(upd, mode="drop")
    return out


def cache_set_slot(cache: Dict[str, Any], slot_cache: Dict[str, Any],
                   index) -> Dict[str, Any]:
    """Single-request admission: scatter a batch-dim-1 cache into slot
    ``index``. Thin wrapper over ``cache_set_slots`` (kept for the
    recurrent-family exact-length prefill path and external callers)."""
    return cache_set_slots(cache, slot_cache,
                           jnp.asarray(index, jnp.int32)[None])


def _ring_axis(key: str) -> int:
    """Axis of the ring (cache position) dimension per cache entry: the
    position ring ``pos`` is (B, T); every KV payload stacks layers first
    (L, B, T, ...)."""
    return 1 if key == "pos" else 2


def cache_ring_snapshot(cache: Dict[str, Any],
                        slots: jnp.ndarray) -> Dict[str, Any]:
    """Snapshot ring rows ``slots`` (B, S) of every ring-indexed cache
    entry (k/v, int8 scales, pos) before a speculative verify pass writes
    them. Recurrent entries (conv/state) have no ring and are excluded --
    speculation is a KV-cache-family feature (a dense recurrent state
    cannot be rolled back by re-pointing positions)."""
    return {k: kops.ring_gather(v, slots, ring_axis=_ring_axis(k))
            for k, v in cache.items() if k not in ("conv", "state")}


# ---------------------------------------------------------------------------
# page-granular cache copy (prefix cache)
# ---------------------------------------------------------------------------

# ring-payload entries a KV page carries (``pos`` is derived from the
# page's start position at scatter time, never stored)
_PAGE_KEYS = ("k", "v", "k_scale", "v_scale")
# recurrent checkpoint payload: one pool row holds the WHOLE conv/SSM
# state after the page's last token (not per-position data), so a warm
# admission restores it and recomputes only the suffix
_STATE_KEYS = ("conv", "state")


def cache_page_keys(cfg: ModelConfig) -> Tuple[str, ...]:
    """Pool entries a prefix-cache page carries for this family: ring
    payloads for KV families, plus whole-state checkpoints for the
    recurrent ones (hybrid pages both its shared-block KV ring and its
    conv/SSM checkpoints)."""
    keys = _PAGE_KEYS
    if cfg.family in ("ssm", "hybrid"):
        keys = keys + _STATE_KEYS
    return keys


def cache_page_pool(cfg: ModelConfig, n_pages: int, page: int,
                    dtype=jnp.bfloat16) -> Dict[str, Any]:
    """Device page pool for the prefix cache: every ring-payload cache
    entry with the batch-slot axis reinterpreted as a page index and the
    ring axis shortened to ``page`` rows -- e.g. ``k``:
    (L, n_pages, page, KH, Dh). Same dtypes as the live ring (int8 + f32
    scales under kv_cache_quant), so page copies are bit-for-bit.
    Recurrent families add per-page checkpoint entries ``conv``
    (L, n_pages, W-1, C) / ``state`` (L, n_pages, H, P, N): the state
    AFTER the page's last token, indexed by page like a one-row batch."""
    tmpl = init_cache(cfg, n_pages, page, dtype=dtype)
    keys = cache_page_keys(cfg)
    return {k: v for k, v in tmpl.items() if k in keys}


def cache_page_bytes(cfg: ModelConfig, page: int) -> int:
    """Device bytes one KV page occupies (all payload arrays, all layers)."""
    shapes = jax.eval_shape(lambda: cache_page_pool(cfg, 1, page))
    return sum(int(np.prod(v.shape)) * jnp.dtype(v.dtype).itemsize
               for v in shapes.values())


def cache_gather_pages(cache: Dict[str, Any], rows: jnp.ndarray,
                       cols: jnp.ndarray) -> Dict[str, Any]:
    """Copy page-shaped row blocks out of a decode cache: ``rows`` (n,)
    batch slots, ``cols`` (n, page) ring slots (position % T, so pages
    sitting across a sliding-window wrap read their true rows). Returns
    pool-layout payloads (the per-entry (batch, ring) dims become
    (n, page))."""
    return {k: kops.page_gather(cache[k], rows, cols,
                                ring_axis=_ring_axis(k))
            for k in _PAGE_KEYS if k in cache}


def cache_scatter_pages(cache: Dict[str, Any], pages: Dict[str, Any],
                        rows: jnp.ndarray, cols: jnp.ndarray,
                        positions: jnp.ndarray) -> Dict[str, Any]:
    """Scatter pool pages into a decode cache and stamp their absolute
    positions into the ``pos`` ring. ``cols`` entries >= T drop that
    element -- batch padding, and the copy-on-write path: a partial-page
    hit scatters only its matched leading rows, the suffix prefill then
    recomputes (overwrites) the divergent tail in the ring while the
    source pool page stays intact. Exact through ring wrap and int8-KV
    scale payloads (all entries are copied bit-for-bit)."""
    new = dict(cache)
    for k, pg in pages.items():
        if k in cache:
            new[k] = kops.page_scatter(cache[k], pg, rows, cols,
                                       ring_axis=_ring_axis(k))
    new["pos"] = kops.page_scatter(cache["pos"], positions, rows, cols,
                                   ring_axis=1)
    return new


def cache_scatter_checkpoints(cache: Dict[str, Any], pool: Dict[str, Any],
                              idx: jnp.ndarray,
                              rows: jnp.ndarray) -> Dict[str, Any]:
    """Restore recurrent checkpoints: copy pool page rows ``idx`` (n,)
    into batch rows ``rows`` (n,) of a decode cache's conv/state entries
    (whole-state row copies -- checkpoints are not positional pages). A
    row >= B drops that element (bucketed-job padding); the corresponding
    pad ``idx`` may be out of range (the gather clamps, the scatter
    drops)."""
    new = dict(cache)
    for k in _STATE_KEYS:
        if k in cache:
            new[k] = cache[k].at[:, rows].set(
                pool[k][:, idx].astype(cache[k].dtype), mode="drop")
    return new


def cache_insert_checkpoints(pool: Dict[str, Any], cache: Dict[str, Any],
                             rows: jnp.ndarray,
                             idx: jnp.ndarray) -> Dict[str, Any]:
    """Record recurrent checkpoints: copy decode-cache batch rows ``rows``
    (n,) conv/state into pool page rows ``idx`` (n,). The source is the
    inter-chunk state the scheduler's chunk loop already materializes, so
    a checkpoint is bit-for-bit the state a cold run carries at that page
    boundary -- zero extra compute. ``idx`` >= n_pages drops (padding)."""
    new = dict(pool)
    for k in _STATE_KEYS:
        if k in pool:
            new[k] = pool[k].at[:, idx].set(
                cache[k][:, rows].astype(pool[k].dtype), mode="drop")
    return new


def cache_ring_rewind(cache: Dict[str, Any], snapshot: Dict[str, Any],
                      slots: jnp.ndarray, keep) -> Dict[str, Any]:
    """Un-write rejected speculative entries: restore snapshot column j
    into ring row ``slots[b, j]`` for every j >= keep[b] (columns below
    ``keep`` hold accepted tokens and stay). ``keep`` (B,) is traced, so
    one compiled program serves every acceptance pattern. Exact for ring
    wrap too: a rejected draft that overwrote a still-in-window entry gets
    that entry back, so sliding-window decode after a rollback is
    bit-identical to never having speculated."""
    new = dict(cache)
    for k, snap in snapshot.items():
        new[k] = kops.ring_restore(cache[k], snap, slots, keep,
                                   ring_axis=_ring_axis(k))
    return new
