"""Model building blocks: norms, rotary embeddings, attention, MLPs.

Everything is functional (params are pytrees) and serve-aware: any weight
matrix may be a packed ``QTensor``, in which case the matmul dispatches to
the fused BFP kernel path (``kernels/ops.bfp_matmul``) -- the per-layer
variant switch that is the paper's headline feature.

Attention implementations:
  * ``naive``      -- materializes (…, S, T) scores; tiny shapes/tests only.
  * ``blockwise``  -- exact online-softmax over KV chunks with a python loop
    over Q chunks and a ``lax.scan`` over exactly the causally-needed KV
    chunks (static per Q chunk), so HLO FLOPs stay ~triangular and peak
    memory is one (cq x ck) score block. This is the dry-run/long-seq path.
  * decode         -- single-token query against a (possibly ring-buffer)
    cache with per-slot absolute positions.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import calibrate as CAL
from repro.core.quantize import QTensor, dequantize
from repro.distributed.sharding import constrain, serve_tp_plan
from repro.kernels import ops as kops
from repro.kernels.prefill_attn import prefill_attn_fused

NEG_INF = -1e30


def dense(x: jnp.ndarray, w, *, impl: str = "auto",
          interpret: bool = False) -> jnp.ndarray:
    """MatMul against either a plain array or a packed QTensor."""
    if isinstance(w, QTensor):
        return kops.bfp_matmul(x, w, impl=impl, interpret=interpret)
    return jnp.dot(x, w.astype(x.dtype))


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * w.astype(jnp.float32)).astype(dt)


def layernorm(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
              eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


def norm(x, p: Dict, kind: str, eps: float):
    if kind == "rmsnorm":
        return rmsnorm(x, p["w"], eps)
    return layernorm(x, p["w"], p["b"], eps)


# ---------------------------------------------------------------------------
# position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(d_head: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32)
                            / d_head))


def rope_cos_sin(positions: jnp.ndarray, d_head: int, theta: float,
                 mrope_sections: Optional[Tuple[int, int, int]] = None):
    """positions: (B, S) or (3, B, S) for M-RoPE. Returns cos/sin (B, S, D/2).

    M-RoPE (Qwen2-VL): the D/2 rotary frequencies are split into
    (temporal, height, width) sections; each section rotates by its own
    position stream. For text tokens the three streams coincide.
    """
    inv = rope_freqs(d_head, theta)                       # (D/2,)
    if positions.ndim == 2:
        ang = positions[..., None].astype(jnp.float32) * inv  # (B,S,D/2)
    else:
        assert mrope_sections is not None
        ang3 = positions[..., None].astype(jnp.float32) * inv  # (3,B,S,D/2)
        sect = []
        for i, n in enumerate(mrope_sections):
            sect.append(jnp.full((n,), i, jnp.int32))
        sel = jnp.concatenate(sect)                        # (D/2,)
        ang = jnp.take_along_axis(
            jnp.moveaxis(ang3, 0, -1), sel[None, None, :, None], axis=-1
        )[..., 0]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray):
    """x: (B, S, H, D); cos/sin: (B, S, D/2). Split-half (llama) convention."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    d2 = x.shape[-1] // 2
    x1, x2 = xf[..., :d2], xf[..., d2:]
    c = cos[:, :, None, :]
    s = sin[:, :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s],
                           axis=-1).astype(dt)


def sincos_pos_emb(positions: jnp.ndarray, d_model: int) -> jnp.ndarray:
    """(B, S) -> (B, S, d_model) fixed sinusoidal embedding (musicgen)."""
    half = d_model // 2
    freq = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32)
                   / half)
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def _split_gqa(q, n_kv: int):
    """(B, S, H, D) -> (B, S, KH, G, D)."""
    B, S, H, D = q.shape
    return q.reshape(B, S, n_kv, H // n_kv, D)


def naive_attention(q, k, v, *, causal=True, window=None, scale=None,
                    softcap=None, q_positions=None, kv_positions=None):
    """q: (B,S,H,D), k/v: (B,T,KH,D) -> (B,S,H,D). Materializes scores."""
    B, S, H, D = q.shape
    T, KH = k.shape[1], k.shape[2]
    scale = scale or (1.0 / math.sqrt(D))
    qg = _split_gqa(q, KH)
    s = jnp.einsum("bqkgd,btkd->bkgqt", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    qp = q_positions if q_positions is not None else jnp.arange(S)[None]
    kp = kv_positions if kv_positions is not None else jnp.arange(T)[None]
    mask = jnp.ones((B, S, T), bool)
    if causal:
        mask &= kp[:, None, :] <= qp[:, :, None]
    if window:
        mask &= kp[:, None, :] > qp[:, :, None] - window
    mask &= kp[:, None, :] >= 0              # invalid cache slots carry -1
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqt,btkd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, D).astype(q.dtype)


def blockwise_attention(q, k, v, *, causal=True, window=None, scale=None,
                        softcap=None, q_chunk=1024, kv_chunk=1024,
                        unroll=1):
    """Exact chunked online-softmax attention, triangular FLOPs.

    Requires S % q_chunk == 0 and T % kv_chunk == 0 (callers pad); assumes
    q/k positions are 0..S-1 aligned (self-attention over a full sequence).
    """
    B, S, H, D = q.shape
    T, KH = k.shape[1], k.shape[2]
    G = H // KH
    scale = scale or (1.0 / math.sqrt(D))
    cq = min(q_chunk, S)
    ck = min(kv_chunk, T)
    assert S % cq == 0 and T % ck == 0, (S, cq, T, ck)
    nq = S // cq
    out = []
    for i in range(nq):
        q0 = i * cq
        qi = _split_gqa(q[:, q0:q0 + cq], KH).astype(jnp.float32)  # (B,cq,KH,G,D)
        # causally-needed kv chunk range for this q chunk (static)
        hi = (q0 + cq + ck - 1) // ck if causal else T // ck
        lo = 0
        if window is not None:
            lo = max(0, (q0 - window + 1) // ck)
        nkv = hi - lo
        ks = jax.lax.slice_in_dim(k, lo * ck, hi * ck, axis=1)
        vs = jax.lax.slice_in_dim(v, lo * ck, hi * ck, axis=1)
        ks = ks.reshape(B, nkv, ck, KH, D)
        vs = vs.reshape(B, nkv, ck, KH, D)
        qpos = q0 + jnp.arange(cq)

        def step(carry, inp):
            m, l, acc = carry
            kc, vc, j = inp
            s = jnp.einsum("bqkgd,btkd->bkgqt", qi, kc.astype(jnp.float32))
            s = s * scale
            if softcap:
                s = jnp.tanh(s / softcap) * softcap
            kpos = (lo + j) * ck + jnp.arange(ck)
            msk = jnp.ones((cq, ck), bool)
            if causal:
                msk &= kpos[None, :] <= qpos[:, None]
            if window:
                msk &= kpos[None, :] > qpos[:, None] - window
            s = jnp.where(msk[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqt,btkd->bkgqd", p, vc.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KH, G, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KH, G, cq), jnp.float32)
        a0 = jnp.zeros((B, KH, G, cq, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            step, (m0, l0, a0),
            (jnp.moveaxis(ks, 1, 0), jnp.moveaxis(vs, 1, 0),
             jnp.arange(nkv)), unroll=unroll)
        o = acc / jnp.maximum(l, 1e-30)[..., None]          # (B,KH,G,cq,D)
        out.append(jnp.moveaxis(o, 3, 1).reshape(B, cq, H, D))
    return jnp.concatenate(out, axis=1).astype(q.dtype)


def prefill_attention(q, k_cache, v_cache, slot_pos, k_new, v_new,
                      positions, valid, *, window=None, scale=None,
                      softcap=None, impl="naive", interpret=False):
    """Chunked-prefill attention: one prompt chunk against cache + itself.

    q: (B,C,H,D) chunk queries; k_cache/v_cache: (B,T,KH,D) ring *before*
    this chunk's writes (an entry a later in-chunk token will overwrite is
    still a real past token for earlier queries -- attending the pre-write
    ring plus the explicit in-chunk keys reproduces exact causal/ring
    semantics, including sliding-window wrap); slot_pos: (B,T) absolute
    positions per ring slot (-1 empty); k_new/v_new: (B,C,KH,D) this
    chunk's keys/values; positions: (B,C) absolute; valid: (B,C) False on
    right-padding (those keys never win attention; their query outputs are
    garbage the caller must ignore).

    ``impl="fused"`` routes the concatenated problem through the Pallas
    flash kernel (``kernels.prefill_attn``, f32-rounding-identical online
    softmax, no (C, T) score materialization; interpret=True runs it on
    CPU); the default materializing ``naive_attention`` path is the
    reference."""
    kv_pos_new = jnp.where(valid, positions, -1)
    k_all = jnp.concatenate([k_cache, k_new.astype(k_cache.dtype)], axis=1)
    v_all = jnp.concatenate([v_cache, v_new.astype(v_cache.dtype)], axis=1)
    kv_pos = jnp.concatenate([slot_pos, kv_pos_new], axis=1)
    if impl == "fused":
        return prefill_attn_fused(q, k_all, v_all, positions, kv_pos,
                                  window=window, scale=scale,
                                  softcap=softcap, interpret=interpret)
    return naive_attention(q, k_all, v_all, causal=True, window=window,
                           scale=scale, softcap=softcap,
                           q_positions=positions, kv_positions=kv_pos)


def verify_attention(q, k_cache, v_cache, slot_pos, k_new, v_new,
                     positions, valid, *, window=None, scale=None,
                     softcap=None):
    """Draft-block verify attention (speculative decoding): BIT-identical
    to running ``decode_attention`` once per token.

    ``prefill_attention`` would be semantically correct here, but it sums
    the block's own keys at the END of the concatenated KV axis, while
    plain decode sums each new key in-place at its ring slot -- a
    different f32 accumulation order, i.e. logits that differ by an ulp
    and can flip a greedy argmax on a near-tie. Greedy speculative decode
    promises *bit-identical* output to plain decode, so verify replays
    decode's exact dataflow instead: scan the block's columns, write each
    valid column's K/V into the ring carry at ``position % T``, then run
    the very same ``decode_attention`` program on the updated ring. Every
    column sees the ring laid out exactly as plain decode would have laid
    it out at that step (accepted drafts resident at their slots, not
    appended), column shapes match decode's (B, 1, H, D), and the
    summation order is identical -- so the scores are too.

    q: (B, S, H, D); k_new/v_new: (B, S, KH, D) at ring dtype semantics
    (caller pre-rounds / pre-dequantizes exactly like the decode write
    path); positions (B, S) per-row absolute; valid (B, S) marks columns
    that run (col 0 only for a plain decode step riding the program).
    Invalid columns leave the ring untouched and their outputs are
    garbage the caller must ignore. Requires S <= T (distinct slots).
    Returns (B, S, H, D)."""
    B, S, H, D = q.shape
    T = k_cache.shape[1]
    bidx = jnp.arange(B)

    def step(carry, xs):
        kc, vc, sp = carry
        qj, kj, vj, pj, okj = xs            # (B,H,D) (B,KH,D) ... (B,) (B,)
        slot = pj % T
        kw = jnp.where(okj[:, None, None], kj.astype(kc.dtype),
                       kc[bidx, slot])
        vw = jnp.where(okj[:, None, None], vj.astype(vc.dtype),
                       vc[bidx, slot])
        pw = jnp.where(okj, pj, sp[bidx, slot])
        kc = kc.at[bidx, slot].set(kw)
        vc = vc.at[bidx, slot].set(vw)
        sp = sp.at[bidx, slot].set(pw)
        o = decode_attention(qj[:, None], kc, vc, sp, pj, window=window,
                             scale=scale, softcap=softcap)
        return (kc, vc, sp), o[:, 0]

    _, outs = jax.lax.scan(
        step, (k_cache, v_cache, slot_pos),
        (jnp.moveaxis(q, 1, 0), jnp.moveaxis(k_new, 1, 0),
         jnp.moveaxis(v_new, 1, 0), positions.T, valid.T))
    return jnp.moveaxis(outs, 0, 1)


def decode_attention(q, k_cache, v_cache, slot_pos, q_pos, *,
                     window=None, scale=None, softcap=None):
    """Single-step decode. q: (B,1,H,D); caches: (B,T,KH,D);
    slot_pos: (B,T) absolute positions per cache slot (-1 = empty);
    q_pos: (B,) current position."""
    B, _, H, D = q.shape
    T, KH = k_cache.shape[1], k_cache.shape[2]
    scale = scale or (1.0 / math.sqrt(D))
    qg = _split_gqa(q, KH).astype(jnp.float32)[:, 0]        # (B,KH,G,D)
    s = jnp.einsum("bkgd,btkd->bkgt", qg,
                   k_cache.astype(jnp.float32)) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    msk = (slot_pos >= 0) & (slot_pos <= q_pos[:, None])
    if window:
        msk &= slot_pos > (q_pos[:, None] - window)
    s = jnp.where(msk[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgt,btkd->bkgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, 1, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def _tp_mlp_active() -> bool:
    """Serve-TP lane sharding for the ffn block (shard_map body only)."""
    plan = serve_tp_plan()
    return plan is not None and plan.size > 1 and plan.mlp


def tp_lane_dense(x, w, out: str, *, impl="auto", interpret=False):
    """Serve-TP projection against a lane-sharded weight (``w`` is this
    shard's (..., K, N/size) lane slice; K rows are whole, so every
    output column is a full-K dot).

    ``out="local"``: return this shard's lane block, NO collective --
    q/k/v (the block IS this shard's heads) and gate/up/fc (the ffn
    hidden stays sharded through the elementwise activation).
    ``out="full"``: replicated full output via ONE collective -- o-proj
    and down-proj, whose consumers (residual adds, norms) need the
    replicated activation.

    Datapath per ServeTPPlan.matmul: "padded" zero-embeds the slice and
    runs the single-device gemm shape (bit-identical columns by
    construction -- the parity default); "sliced" runs the true
    lane-sliced gemm (FLOPs and packed HBM traffic 1/size per shard,
    equal to within an f32 ulp: CPU gemms round shape-dependently)."""
    plan = serve_tp_plan()
    if plan is None or plan.size == 1:
        return dense(x, w, impl=impl, interpret=interpret)
    if plan.matmul == "padded":
        y = kops.tp_local_lanes(
            dense(x, kops.tp_embed_lanes(w), impl=impl, interpret=interpret))
    else:
        y = dense(x, w, impl=impl, interpret=interpret)
    return y if out == "local" else kops.tp_gather_lanes(y)


def tp_ring_dense(x, w, *, impl="auto", interpret=False):
    """Ring collective-matmul: a full-output serve-TP projection whose
    input lives lane-sharded (``x`` is this shard's K-chunk) and whose
    weight lives lane-sharded too (``w`` is (..., K, N/size)), computed
    WITHOUT ever materializing the gathered input. Each of the ``size``
    steps multiplies the chunk currently in hand against its matching
    K-rows of the local weight while ``ppermute`` forwards the chunk one
    hop around the ring -- so the all-gather's wire time hides behind
    the gemms (the collective-matmul overlap; on a real mesh each hop's
    DMA runs concurrently with the current chunk's dot). The final lane
    outputs are assembled by the usual tiled all-gather.

    K accumulates chunk-at-a-time in an fp32 carry (single rounding at
    the end), so the result carries the same activation-ulp contract as
    the rest of the "sliced_row" datapath -- tp_lane_dense routes here
    for full-output projections when no row-parallel mode applies.
    Packed weights dequantize their local lane slice ONCE (1/size of the
    full weight traffic), outside the ring loop; the per-step K-row
    slice then needs no super-block alignment."""
    plan = serve_tp_plan()
    if plan is None or plan.size == 1:
        return dense(x, w, impl=impl, interpret=interpret)
    size, axis = plan.size, plan.axis
    kl = x.shape[-1]
    i = jax.lax.axis_index(axis)
    perm = [(s, (s + 1) % size) for s in range(size)]
    if isinstance(w, QTensor):
        wf = dequantize(w, dtype=jnp.bfloat16)
    else:
        wf = w.astype(x.dtype)

    chunk, acc = x, None
    for s in range(size):
        # the chunk in hand at step s started at shard (i - s): that is
        # its K offset into the (full-K, local-lane) weight
        j = (i - s) % size
        rows = jax.lax.dynamic_slice_in_dim(wf, j * kl, kl, 0)
        part = jnp.dot(chunk.astype(wf.dtype), rows,
                       preferred_element_type=jnp.float32)
        acc = part if acc is None else acc + part
        if s + 1 < size:
            chunk = jax.lax.ppermute(chunk, axis, perm)
    y = acc.astype(x.dtype)
    return kops.tp_gather_lanes(y)


def tp_row_dense(x, w, mode: str, *, impl="auto", interpret=False):
    """Serve-TP row-parallel projection (sliced datapath): ``x`` is this
    shard's K-slice of the projection input -- its own head outputs (the
    o-proj) or ffn lanes (the down-proj) -- fed straight into a
    partial-K gemm, and ONE ``psum`` assembles the replicated output.
    Pairs with the column-parallel projections upstream exactly as in
    Megatron, replacing TWO per-layer collectives (the input's lane
    gather + the output's gather) with one, at narrower wire width.

    ``mode`` (from ServeTPPlan.attn_row / mlp_row):
      "packed"  -- ``w`` is this shard's K-row slice (whole super-blocks,
        aux already localized), so the plain fused/XLA gemm applies.
      "dequant" -- ``w`` is the full replicated packed tensor; each shard
        dequantizes and slices its K rows (kops.tp_row_local_matmul).

    Partials emit fp32 and the psum runs at fp32 width, rounding to the
    activation dtype once AFTER the reduce (see tp_row_local_matmul) --
    so the only divergence from the lane dataflow is the K-reduction
    order across shards. That reorder cannot bit-match a full-K dot
    once activations round to bf16 at layer boundaries, which is why
    this path is its own datapath value ("sliced_row") with an
    activation-ulp tolerance contract -- f32 models stay inside the
    f32-ulp envelope ("padded"/"sliced" never route here)."""
    plan = serve_tp_plan()
    if plan is None or plan.size == 1:
        return dense(x, w, impl=impl, interpret=interpret)
    if isinstance(w, QTensor):
        y = kops.tp_row_local_matmul(x, w, mode, impl=impl,
                                     interpret=interpret)
    else:
        # plain weights row-shard whenever K divides, so shard_map has
        # already handed over this shard's (K/size, N) rows
        y = jnp.dot(x, w.astype(x.dtype),
                    preferred_element_type=jnp.float32)
    return jax.lax.psum(y, plan.axis).astype(x.dtype)


def swiglu_mlp(x, p: Dict, *, impl="auto", interpret=False):
    if _tp_mlp_active():
        plan = serve_tp_plan()
        # serve TP (shard_map): gate/up emit this shard's ffn lanes and
        # the activation stays local. Row-parallel plans feed those lanes
        # straight into the down-proj and psum once (tp_row_dense);
        # otherwise ONE exact all-gather assembles the hidden (w_down
        # keeps its K rows whole per shard) and one more gathers the down
        # output -- see tp_lane_dense
        g = tp_lane_dense(x, p["w_gate"], "local", impl=impl,
                          interpret=interpret)
        u = tp_lane_dense(x, p["w_up"], "local", impl=impl,
                          interpret=interpret)
        h = jax.nn.silu(g) * u
        if plan.mlp_row:
            return tp_row_dense(h, p["w_down"], plan.mlp_row, impl=impl,
                                interpret=interpret)
        if plan.matmul == "sliced_row":
            # no row layout for w_down (plan built without params):
            # ring collective-matmul hides the hidden's gather behind
            # the chunked down-proj gemms
            return tp_ring_dense(h, p["w_down"], impl=impl,
                                 interpret=interpret)
        h = kops.tp_gather_lanes(h)
        return tp_lane_dense(h, p["w_down"], "full", impl=impl,
                             interpret=interpret)
    CAL.tap(("mlp/w_gate", "mlp/w_up"), x)
    g = dense(x, p["w_gate"], impl=impl, interpret=interpret)
    u = dense(x, p["w_up"], impl=impl, interpret=interpret)
    # Megatron-style TP: ffn hidden sharded over model on the ff dim;
    # the row-parallel down-proj output is constrained replicated-on-d so
    # the TP all-reduce happens HERE, in bf16, not inside the next norm's
    # f32 upcast (GSPMD would otherwise sink it there at 2x width)
    h = constrain(jax.nn.silu(g) * u, "dp", None, "model")
    CAL.tap("mlp/w_down", h)
    return constrain(dense(h, p["w_down"], impl=impl, interpret=interpret),
                     "dp", None, None)


def gelu_mlp(x, p: Dict, *, impl="auto", interpret=False):
    if _tp_mlp_active():
        plan = serve_tp_plan()
        h = tp_lane_dense(x, p["c_fc"], "local", impl=impl,
                          interpret=interpret)
        if "b_fc" in p:
            # b_fc is lane-sharded with c_fc, so the add stays local;
            # b_proj adds after the output psum/gather and is replicated
            h = h + p["b_fc"].astype(h.dtype)
        h = jax.nn.gelu(h, approximate=True)
        if plan.mlp_row:
            o = tp_row_dense(h, p["c_proj"], plan.mlp_row, impl=impl,
                             interpret=interpret)
        elif plan.matmul == "sliced_row":
            o = tp_ring_dense(h, p["c_proj"], impl=impl,
                              interpret=interpret)
        else:
            h = kops.tp_gather_lanes(h)
            o = tp_lane_dense(h, p["c_proj"], "full", impl=impl,
                              interpret=interpret)
        if "b_proj" in p:
            o = o + p["b_proj"].astype(o.dtype)
        return o
    CAL.tap("mlp/c_fc", x)
    h = dense(x, p["c_fc"], impl=impl, interpret=interpret)
    if "b_fc" in p:
        h = h + p["b_fc"].astype(h.dtype)
    h = constrain(jax.nn.gelu(h, approximate=True), "dp", None, "model")
    CAL.tap("mlp/c_proj", h)
    o = constrain(dense(h, p["c_proj"], impl=impl, interpret=interpret),
                  "dp", None, None)
    if "b_proj" in p:
        o = o + p["b_proj"].astype(o.dtype)
    return o
