"""Mamba2 (SSD -- state-space duality) block: chunked scan + decode recurrence.

Training/prefill use the chunked SSD algorithm (quadratic *within* a chunk,
linear across chunks, state carried by ``lax.scan``); decode is the O(1)
recurrence -- which is why the ssm/hybrid archs run the 524k-token decode
cell that full-attention archs cannot.

Shapes: d_inner = expand * d_model; H = d_inner // head_dim heads of size P;
state N per head; n_groups = 1 (B/C shared across heads).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import calibrate as CAL
from repro.distributed.sharding import constrain
from repro.models.layers import dense, rmsnorm


def ssm_dims(cfg) -> Dict[str, int]:
    d_in = cfg.ssm_expand * cfg.d_model
    H = d_in // cfg.ssm_head_dim
    N = cfg.ssm_state
    conv_ch = d_in + 2 * cfg.ssm_groups * N
    d_proj = 2 * d_in + 2 * cfg.ssm_groups * N + H
    return dict(d_inner=d_in, n_heads=H, state=N, conv_ch=conv_ch,
                d_proj=d_proj, head_dim=cfg.ssm_head_dim)


def _split_proj(zxbcdt, cfg):
    dd = ssm_dims(cfg)
    d_in, N, H = dd["d_inner"], dd["state"], dd["n_heads"]
    g = cfg.ssm_groups
    z, xBC, dt = jnp.split(zxbcdt, [d_in, 2 * d_in + 2 * g * N], axis=-1)
    return z, xBC, dt


def _causal_conv(xBC, conv_w, conv_b, conv_state=None, state_take=None):
    """Depthwise causal conv1d. xBC: (B,S,C); conv_w: (W,C).
    conv_state: (B,W-1,C) previous tail (decode/chunked prefill).
    state_take: optional (B,) count of valid leading columns per row; the
    returned tail then ends at that column, so a row whose prompt ended
    mid-chunk keeps its true tail and a row with 0 valid columns keeps
    ``conv_state`` unchanged (masked batched prefill)."""
    W = conv_w.shape[0]
    if conv_state is None:
        pad = jnp.zeros(xBC.shape[:1] + (W - 1,) + xBC.shape[2:], xBC.dtype)
    else:
        pad = conv_state.astype(xBC.dtype)
    xp = jnp.concatenate([pad, xBC], axis=1)               # (B, S+W-1, C)
    out = sum(xp[:, i:i + xBC.shape[1]] * conv_w[i][None, None]
              for i in range(W))
    out = out + conv_b[None, None].astype(out.dtype)
    if state_take is None:
        new_state = xp[:, -(W - 1):]
    else:
        idx = state_take[:, None] + jnp.arange(W - 1)[None]    # (B, W-1)
        new_state = jnp.take_along_axis(xp, idx[:, :, None], axis=1)
    return jax.nn.silu(out), new_state


def _ssd_chunk_scan(x, dt, A, Bm, Cm, state0, chunk: int, unroll=1):
    """Chunked SSD. x: (B,S,H,P), dt: (B,S,H), A: (H,), Bm/Cm: (B,S,N),
    state0: (B,H,P,N). Returns y (B,S,H,P), state (B,H,P,N)."""
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    S0 = S
    if S % Q:
        # pad tail with dt=0 steps: decay=1 and zero input contribution,
        # so the state and all real outputs are unaffected
        pad = Q - S % Q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nc = S // Q

    def to_chunks(t):
        return jnp.moveaxis(t.reshape((Bsz, nc, Q) + t.shape[2:]), 1, 0)

    xs = (to_chunks(x.astype(jnp.float32)), to_chunks(dt.astype(jnp.float32)),
          to_chunks(Bm.astype(jnp.float32)), to_chunks(Cm.astype(jnp.float32)))

    Af = A.astype(jnp.float32)

    def step(state, inp):
        xc, dtc, Bc, Cc = inp                              # (B,Q,...)
        a = dtc * Af[None, None]                           # (B,Q,H)
        acs = jnp.cumsum(a, axis=1)                        # (B,Q,H)
        # intra-chunk (the "duality" quadratic term)
        CB = jnp.einsum("bin,bjn->bij", Cc, Bc)            # (B,Q,Q)
        decay = jnp.exp(acs[:, :, None] - acs[:, None])    # (B,i,j,H)
        ii = jnp.arange(Q)
        tri = (ii[:, None] >= ii[None, :])[None, :, :, None]
        scores = CB[..., None] * jnp.where(tri, decay, 0.0)
        y_diag = jnp.einsum("bijh,bjh,bjhp->bihp", scores, dtc, xc)
        # inter-chunk
        decay_last = jnp.exp(acs[:, -1:] - acs)            # (B,Q,H)
        chunk_state = jnp.einsum("bjn,bjh,bjhp->bhpn", Bc, dtc * decay_last,
                                 xc)
        y_off = jnp.einsum("bin,bhpn,bih->bihp", Cc, state,
                           jnp.exp(acs))
        state_new = (state * jnp.exp(acs[:, -1])[:, :, None, None]
                     + chunk_state)
        return state_new, y_diag + y_off

    state, ys = jax.lax.scan(step, state0.astype(jnp.float32), xs,
                             unroll=unroll)
    y = jnp.moveaxis(ys, 0, 1).reshape(Bsz, S, H, P)
    return y[:, :S0], state


def mamba2_forward(h: jnp.ndarray, p: Dict, cfg, *,
                   conv_state=None, ssm_state=None, valid=None, impl="auto",
                   interpret=False):
    """Full-sequence forward (train / prefill).

    h: (B, S, d_model). Returns (out (B,S,d), (conv_state, ssm_state)).

    valid: optional (B, S) bool -- True on real columns, always a
    contiguous prefix of each row (masked batched prefill). Invalid
    columns never touch the recurrent state: dt is zeroed post-softplus
    (decay exp(0)=1 and zero input contribution, the same identity the
    SSD tail-pad relies on) and the conv tail is gathered at each row's
    last valid column. Outputs at invalid columns are garbage and must
    be ignored by the caller."""
    dd = ssm_dims(cfg)
    Bsz, S, _ = h.shape
    H, P, N = dd["n_heads"], dd["head_dim"], dd["state"]

    CAL.tap("ssm/in_proj", h)
    zxbcdt = dense(h, p["in_proj"], impl=impl, interpret=interpret)
    z, xBC, dt = _split_proj(zxbcdt, cfg)
    xBC, conv_state_new = _causal_conv(
        xBC, p["conv_w"], p["conv_b"], conv_state,
        state_take=None if valid is None else jnp.sum(valid, axis=1))
    x, Bm, Cm = jnp.split(xBC, [dd["d_inner"], dd["d_inner"] + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32)[None, None])
    if valid is not None:
        dt = jnp.where(valid[..., None], dt, 0.0)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    x = constrain(x.reshape(Bsz, S, H, P), "dp", None, "model", None)
    if ssm_state is None:
        ssm_state = jnp.zeros((Bsz, H, P, N), jnp.float32)
    y, ssm_state_new = _ssd_chunk_scan(
        x, dt, A, Bm, Cm, ssm_state, cfg.ssm_chunk,
        unroll=True if (cfg.scan_unroll and cfg.ssd_unroll) else 1)
    y = y + p["D"].astype(jnp.float32)[None, None, :, None] \
        * x.astype(jnp.float32)
    y = y.reshape(Bsz, S, dd["d_inner"]).astype(h.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    CAL.tap("ssm/out_proj", y)
    out = dense(y, p["out_proj"], impl=impl, interpret=interpret)
    return out, (conv_state_new, ssm_state_new)


def mamba2_decode(h: jnp.ndarray, p: Dict, cfg, conv_state, ssm_state, *,
                  impl="auto", interpret=False):
    """Single-token decode. h: (B, d_model); conv_state: (B, W-1, C);
    ssm_state: (B, H, P, N)."""
    dd = ssm_dims(cfg)
    Bsz = h.shape[0]
    H, P, N = dd["n_heads"], dd["head_dim"], dd["state"]
    W = cfg.ssm_conv_width

    zxbcdt = dense(h, p["in_proj"], impl=impl, interpret=interpret)
    z, xBC, dt = _split_proj(zxbcdt, cfg)
    # conv recurrence: append new column, take last W
    hist = jnp.concatenate([conv_state.astype(xBC.dtype), xBC[:, None]],
                           axis=1)                          # (B, W, C)
    conv_state_new = hist[:, 1:]
    xBC = sum(hist[:, i] * p["conv_w"][i][None] for i in range(W))
    xBC = jax.nn.silu(xBC + p["conv_b"][None].astype(xBC.dtype))
    x, Bm, Cm = jnp.split(xBC, [dd["d_inner"], dd["d_inner"] + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32)[None])  # (B,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    x = x.reshape(Bsz, H, P).astype(jnp.float32)
    dA = jnp.exp(dt * A[None])                              # (B,H)
    ssm_state_new = (ssm_state * dA[..., None, None]
                     + jnp.einsum("bh,bn,bhp->bhpn", dt,
                                  Bm.astype(jnp.float32), x))
    y = jnp.einsum("bn,bhpn->bhp", Cm.astype(jnp.float32), ssm_state_new)
    y = y + p["D"].astype(jnp.float32)[None, :, None] * x
    y = y.reshape(Bsz, dd["d_inner"]).astype(h.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    out = dense(y, p["out_proj"], impl=impl, interpret=interpret)
    return out, (conv_state_new, ssm_state_new)


def naive_recurrence(x, dt, A, Bm, Cm, state0):
    """Step-by-step reference for tests. Same shapes as _ssd_chunk_scan."""
    Bsz, S, H, P = x.shape

    def step(state, t):
        xt, dtt, Bt, Ct = (x[:, t].astype(jnp.float32),
                           dt[:, t].astype(jnp.float32),
                           Bm[:, t].astype(jnp.float32),
                           Cm[:, t].astype(jnp.float32))
        dA = jnp.exp(dtt * A[None].astype(jnp.float32))
        state = state * dA[..., None, None] + jnp.einsum(
            "bh,bn,bhp->bhpn", dtt, Bt, xt)
        y = jnp.einsum("bn,bhpn->bhp", Ct, state)
        return state, y

    state, ys = jax.lax.scan(step, state0.astype(jnp.float32),
                             jnp.arange(S))
    return jnp.moveaxis(ys, 0, 1), state
