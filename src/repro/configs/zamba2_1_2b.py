"""zamba2-1.2b [hybrid]: 38L d=2048 32H (kv=32) d_ff=8192 vocab=32000,
ssm_state=64. Mamba2 backbone + one *shared* attention block applied every
6 Mamba2 layers over concat(hidden, initial-embedding) at width 2d
[arXiv:2411.15242] (per-application LoRA adapters are omitted; noted in
DESIGN.md). Decode state is O(1) per Mamba2 layer + 6 KV caches ->
long_500k runs."""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=32000, rope_theta=1e4,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2,
    hybrid_attn_every=6, hybrid_attn_d_ff=8192,
    subquadratic=True,
)

REDUCED = ModelConfig(
    name="zamba2-1.2b-reduced", family="hybrid",
    n_layers=4, d_model=256, n_heads=4, n_kv_heads=4,
    d_ff=512, vocab_size=512, rope_theta=1e4,
    ssm_state=16, ssm_head_dim=32, ssm_expand=2, ssm_chunk=16,
    hybrid_attn_every=2, hybrid_attn_d_ff=512,
    subquadratic=True, attn_impl="naive", remat=False,
)

register("zamba2-1.2b", CONFIG, REDUCED)
