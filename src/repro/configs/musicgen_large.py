"""musicgen-large [audio]: 48L d=2048 32H (kv=32) d_ff=8192 vocab=2048.

Decoder-only transformer over EnCodec tokens [arXiv:2306.05284]. The
EnCodec frontend is a stub per the assignment: ``input_specs`` provides
precomputed frame embeddings (B, S, d); the backbone is a LayerNorm/GELU
decoder with sinusoidal positions and a 2048-way codec head."""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=2048,
    norm_type="layernorm", act="gelu", pos_emb="sincos",
    embed_input=False,          # stub frame embeddings
    subquadratic=False,
)

REDUCED = ModelConfig(
    name="musicgen-large-reduced", family="audio",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=4,
    d_ff=512, vocab_size=256,
    norm_type="layernorm", act="gelu", pos_emb="sincos",
    embed_input=False, attn_impl="naive", remat=False,
)

register("musicgen-large", CONFIG, REDUCED)
