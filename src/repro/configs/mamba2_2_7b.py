"""mamba2-2.7b [ssm]: 64L d=2560 (attention-free) vocab=50280,
ssm_state=128, SSD (state-space duality) [arXiv:2405.21060].

d_inner = 2*2560 = 5120, head_dim 64 -> 80 SSD heads. Decode state is
O(1) -> long_500k runs trivially."""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_chunk=128,
    subquadratic=True,
)

REDUCED = ModelConfig(
    name="mamba2-2.7b-reduced", family="ssm",
    n_layers=2, d_model=256, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab_size=512,
    ssm_state=16, ssm_head_dim=32, ssm_expand=2, ssm_chunk=16,
    subquadratic=True, remat=False,
)

register("mamba2-2.7b", CONFIG, REDUCED)
