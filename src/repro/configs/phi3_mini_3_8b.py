"""phi3-mini-3.8b [dense]: 32L d=3072 32H (GQA kv=32) d_ff=8192 vocab=32064.

RoPE + SwiGLU; kv=32 means full multi-head attention (no GQA sharing).
d_head = 96."""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="phi3-mini-3.8b", family="dense",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=32064, rope_theta=1e4,
    subquadratic=False,
)

REDUCED = ModelConfig(
    name="phi3-mini-3.8b-reduced", family="dense",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=4,
    d_ff=512, vocab_size=512, rope_theta=1e4,
    attn_impl="naive", remat=False,
)

register("phi3-mini-3.8b", CONFIG, REDUCED)
