"""qwen2-vl-72b [vlm]: 80L d=8192 64H (GQA kv=8) d_ff=29568 vocab=152064.

M-RoPE + dynamic resolution [arXiv:2409.12191]. The vision frontend is a
stub per the assignment: ``input_specs`` provides precomputed patch/text
embeddings (B, S, d) plus the (3, B, S) M-RoPE position streams.

Note d_ff=29568 is not a multiple of 256, so ffn down-projections fall back
to Q8_0 at serve time -- exactly llama.cpp's behaviour for such tensors.
"""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=29568, vocab_size=152064,
    pos_emb="mrope", mrope_sections=(16, 24, 24), rope_theta=1e6,
    embed_input=False,          # stub patch/text embeddings
    subquadratic=False,         # full attention -> long_500k skipped
)

REDUCED = ModelConfig(
    name="qwen2-vl-72b-reduced", family="vlm",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2,
    d_ff=512, vocab_size=512,
    pos_emb="mrope", mrope_sections=(8, 12, 12), rope_theta=1e6,
    embed_input=False, attn_impl="naive", remat=False,
)

register("qwen2-vl-72b", CONFIG, REDUCED)
