"""qwen3-1.7b [dense]: 28L d=2048 16H (GQA kv=8) d_ff=6144 vocab=151936.

qk_norm per-head RMSNorm on q/k (Qwen3 family signature feature)."""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="qwen3-1.7b", family="dense",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=8,
    d_ff=6144, vocab_size=151936, rope_theta=1e6,
    qk_norm=True,
    subquadratic=False,
)

REDUCED = ModelConfig(
    name="qwen3-1.7b-reduced", family="dense",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2,
    d_ff=512, vocab_size=512, rope_theta=1e6,
    qk_norm=True, attn_impl="naive", remat=False,
)

register("qwen3-1.7b", CONFIG, REDUCED)
