"""llama3.2-1b [dense]: 16L d=2048 32H (GQA kv=8) d_ff=8192 vocab=128256."""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="llama3.2-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=32, n_kv_heads=8,
    d_ff=8192, vocab_size=128256, rope_theta=5e5,
    tie_embeddings=True,        # llama3.2-1b ties lm_head to embeddings
    subquadratic=False,
)

REDUCED = ModelConfig(
    name="llama3.2-1b-reduced", family="dense",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2,
    d_ff=512, vocab_size=512, rope_theta=5e5,
    tie_embeddings=True, attn_impl="naive", remat=False,
)

register("llama3.2-1b", CONFIG, REDUCED)
