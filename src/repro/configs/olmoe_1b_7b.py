"""olmoe-1b-7b [moe]: 16L d=2048 16H (GQA kv=16) per-expert d_ff=1024
vocab=50304, MoE 64 experts top-8 [arXiv:2409.02060].

64 % 16 == 0 -> true expert parallelism over the model axis."""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1024, vocab_size=50304, rope_theta=1e4,
    n_experts=64, n_experts_active=8, moe_d_ff=1024,
    qk_norm=True,               # OLMoE uses QK-norm
    subquadratic=False,
)

REDUCED = ModelConfig(
    name="olmoe-1b-7b-reduced", family="moe",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=512, rope_theta=1e4,
    n_experts=4, n_experts_active=2, moe_d_ff=128, qk_norm=True,
    capacity_factor=4.0,        # == n_experts: drop-free for exact tests
    attn_impl="naive", remat=False,
)

register("olmoe-1b-7b", CONFIG, REDUCED)
