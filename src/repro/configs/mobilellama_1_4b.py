"""mobilellama-1.4b: the paper's MobileLLaMA evaluation model (Table III:
1.4B, 49 Q2_K + 120 Q3_K MatMul layers, 560 MB). 24L d=2048 16H kv=16
d_ff=5632 [arXiv:2312.16886]."""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="mobilellama-1.4b", family="dense",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=5632, vocab_size=32000, rope_theta=1e4,
    subquadratic=False,
)

REDUCED = ModelConfig(
    name="mobilellama-1.4b-reduced", family="dense",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=4,
    d_ff=512, vocab_size=512, rope_theta=1e4,
    attn_impl="naive", remat=False,
)

register("mobilellama-1.4b", CONFIG, REDUCED)
