"""Model/config system: architecture descriptors, input shapes, registry.

Every assigned architecture is a ``ModelConfig`` built by its module in
``repro/configs/<arch>.py`` and registered under its ``--arch`` id. Each
arch also provides ``reduced()`` (a same-family tiny config for CPU smoke
tests) and shares the global SHAPES table (the assigned input-shape set).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp


# the canonical family set. models/state.py keys its per-family
# capability table (KV ring vs recurrent state, speculation, prefix
# mode, TP/EP) off these names and statically asserts it covers them
# all, so adding a family here without a capability row fails at import
FAMILIES: Tuple[str, ...] = (
    "dense", "gpt2", "moe", "ssm", "hybrid", "vlm", "audio")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # one of FAMILIES
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0                 # 0 -> d_model // n_heads

    # attention
    rope_theta: float = 1e6
    qk_norm: bool = False
    sliding_window: Optional[int] = None
    pos_emb: str = "rope"           # rope | mrope | sincos | learned
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    attn_logit_softcap: Optional[float] = None

    # block structure
    norm_type: str = "rmsnorm"      # rmsnorm | layernorm
    act: str = "swiglu"             # swiglu | gelu
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    fused_qkv: bool = False         # gpt2-style c_attn

    # MoE
    n_experts: int = 0
    n_experts_active: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25

    # SSM (mamba2 / zamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 128
    ssm_conv_width: int = 4
    ssm_groups: int = 1

    # hybrid: shared attention block applied every k SSM layers (zamba2)
    hybrid_attn_every: int = 0
    hybrid_attn_d_ff: int = 0

    # frontend
    embed_input: bool = True        # False: input_specs provides embeddings
    max_position: int = 1 << 20

    # runtime knobs
    dtype: str = "bfloat16"
    attn_impl: str = "auto"         # naive | blockwise | fused | auto
    attn_q_chunk: int = 1024
    attn_kv_chunk: int = 1024
    kernel_impl: str = "auto"       # pallas | xla | auto (see kernels/ops.py)
    remat: bool = True
    scan_unroll: bool = False       # unroll layer scans (cost probes)
    ssd_unroll: bool = True         # also unroll SSD chunk scans when
                                    # scan_unroll (probes disable + correct
                                    # analytically: compile-time bound)
    loss_chunk: int = 2048          # tokens/chunk for vocab-sharded CE; 0=off
    kv_cache_quant: bool = False    # int8 KV cache (per-token-head scales)
    # sub-quadratic attention available? (gates the long_500k cell)
    subquadratic: bool = False

    def __post_init__(self):
        if self.family not in FAMILIES:
            raise ValueError(
                f"unknown model family {self.family!r}; known: {FAMILIES}")
        if self.d_head == 0 and self.n_heads:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = (
    "qwen2-vl-72b", "llama3.2-1b", "qwen3-1.7b", "phi3-mini-3.8b",
    "h2o-danube-1.8b", "granite-moe-3b-a800m", "olmoe-1b-7b",
    "musicgen-large", "zamba2-1.2b", "mamba2-2.7b",
    # the paper's own evaluation models (Table III/IV)
    "gpt2-paper", "tinyllama-1.1b", "mobilellama-1.4b",
)

_MODULE_FOR = {i: i.replace("-", "_").replace(".", "_") for i in ARCH_IDS}
_REGISTRY: Dict[str, "ArchSpec"] = {}


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    config: ModelConfig
    reduced: ModelConfig            # CPU smoke-test config, same family


def register(arch_id: str, config: ModelConfig, reduced: ModelConfig):
    _REGISTRY[arch_id] = ArchSpec(config, reduced)


def get_arch(arch_id: str, reduced: bool = False) -> ModelConfig:
    if arch_id not in _REGISTRY:
        mod = _MODULE_FOR.get(arch_id)
        if mod is None:
            raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
        importlib.import_module(f"repro.configs.{mod}")
    spec = _REGISTRY[arch_id]
    return spec.reduced if reduced else spec.config


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether an (arch x shape) cell runs; see DESIGN.md §4."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, ("pure full-attention arch: 524k-token decode needs "
                       "sub-quadratic attention (skip per DESIGN.md §4)")
    return True, ""


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                cache_len: Optional[int] = None) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    Modality frontends ([vlm]/[audio]) are stubs: precomputed patch/frame
    embeddings replace the token ids, per the assignment brief.
    """
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    specs: Dict[str, jax.ShapeDtypeStruct] = {}
    if shape.kind == "train":
        if cfg.embed_input:
            specs["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        else:
            specs["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), dt)
        specs["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        if cfg.pos_emb == "mrope":
            specs["positions"] = jax.ShapeDtypeStruct((3, B, S), jnp.int32)
    elif shape.kind == "prefill":
        if cfg.embed_input:
            specs["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        else:
            specs["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), dt)
        if cfg.pos_emb == "mrope":
            specs["positions"] = jax.ShapeDtypeStruct((3, B, S), jnp.int32)
    elif shape.kind == "decode":
        # one new token against a cache of seq_len
        if cfg.embed_input:
            specs["tokens"] = jax.ShapeDtypeStruct((B,), jnp.int32)
        else:
            specs["embeds"] = jax.ShapeDtypeStruct((B, cfg.d_model), dt)
        specs["position"] = jax.ShapeDtypeStruct((B,), jnp.int32)
    else:
        raise ValueError(shape.kind)
    return specs
