"""h2o-danube-1.8b [dense]: 24L d=2560 32H (GQA kv=8) d_ff=6912 vocab=32000.

llama+mistral mix with sliding-window attention (window 4096), which makes
decode state O(window): this arch RUNS the long_500k cell."""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="h2o-danube-1.8b", family="dense",
    n_layers=24, d_model=2560, n_heads=32, n_kv_heads=8,
    d_ff=6912, vocab_size=32000, rope_theta=1e4,
    sliding_window=4096,
    subquadratic=True,          # SWA -> long_500k runs with ring cache
)

REDUCED = ModelConfig(
    name="h2o-danube-1.8b-reduced", family="dense",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2,
    d_ff=512, vocab_size=512, rope_theta=1e4,
    sliding_window=64, subquadratic=True, attn_impl="naive", remat=False,
)

register("h2o-danube-1.8b", CONFIG, REDUCED)
