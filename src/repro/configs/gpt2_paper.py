"""gpt2-paper: the paper's GPT2 evaluation model (Table III: 163M params,
25 Q2_K + 24 Q3_K MatMul layers, 77 MB).

GPT2-base is 124M; the paper's 163M count corresponds to an *untied*
lm_head (124M + 38.6M), and 49 MatMul layers = 12 blocks x 4 + lm_head.
Fused c_attn, LayerNorm, GELU, learned positions."""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="gpt2-paper", family="gpt2",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab_size=50257,
    norm_type="layernorm", act="gelu", pos_emb="learned",
    fused_qkv=True, max_position=1024,
    subquadratic=False,
)

REDUCED = ModelConfig(
    name="gpt2-paper-reduced", family="gpt2",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=4,
    d_ff=512, vocab_size=512,
    norm_type="layernorm", act="gelu", pos_emb="learned",
    fused_qkv=True, max_position=256, attn_impl="naive", remat=False,
)

register("gpt2-paper", CONFIG, REDUCED)
