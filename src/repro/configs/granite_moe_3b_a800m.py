"""granite-moe-3b-a800m [moe]: 32L d=1536 24H (GQA kv=8) per-expert
d_ff=512 vocab=49155, MoE 40 experts top-8.

40 % 16 != 0, so expert weights use FFN-TP (f sharded over model) rather
than EP; see distributed/sharding.py and DESIGN.md §5."""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8,
    d_ff=512, vocab_size=49155, rope_theta=1e4,
    n_experts=40, n_experts_active=8, moe_d_ff=512,
    subquadratic=False,
)

REDUCED = ModelConfig(
    name="granite-moe-3b-a800m-reduced", family="moe",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=512, rope_theta=1e4,
    n_experts=4, n_experts_active=2, moe_d_ff=128,
    capacity_factor=4.0,        # == n_experts: drop-free for exact tests
    attn_impl="naive", remat=False,
)

register("granite-moe-3b-a800m", CONFIG, REDUCED)
