"""tinyllama-1.1b: the paper's TinyLlama evaluation model (Table III: 1.1B,
45 Q2_K + 110 Q3_K MatMul layers, 460 MB). 22L d=2048 32H kv=4 d_ff=5632."""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="tinyllama-1.1b", family="dense",
    n_layers=22, d_model=2048, n_heads=32, n_kv_heads=4,
    d_ff=5632, vocab_size=32000, rope_theta=1e4,
    subquadratic=False,
)

REDUCED = ModelConfig(
    name="tinyllama-1.1b-reduced", family="dense",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2,
    d_ff=512, vocab_size=512, rope_theta=1e4,
    attn_impl="naive", remat=False,
)

register("tinyllama-1.1b", CONFIG, REDUCED)
