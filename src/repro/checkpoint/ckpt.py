"""Fault-tolerant checkpointing: atomic, step-tagged, async, auto-resume.

Layout: ``<dir>/step_<N>/arrays.npz`` + ``MANIFEST`` (written last -- a
checkpoint without MANIFEST is treated as torn and ignored). Writes go to
``step_<N>.tmp`` and are renamed into place, so a preemption mid-save never
corrupts the latest valid checkpoint. ``save_async`` runs serialization on
a background thread (training continues; ``wait()`` joins before the next
save). ``restore_latest`` scans for the newest valid step -- the restart
path after a node failure.

On a real multi-host pod each process saves its local shard
(``process_<i>.npz``); here process_count()==1 and the same layout holds.
"""
from __future__ import annotations

import os
import shutil
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

_SEP = "\x1d"


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}{_SEP}"))
    else:
        out[prefix[:-1]] = np.asarray(tree)
    return out


def _unflatten(flat: Dict[str, np.ndarray]):
    tree: Dict[str, Any] = {}
    for path, v in flat.items():
        parts = path.split(_SEP)
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return tree


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------
    def _write(self, step: int, flat: Dict[str, np.ndarray]):
        proc = jax.process_index()
        tmp = os.path.join(self.dir, f"step_{step}.tmp")
        final = os.path.join(self.dir, f"step_{step}")
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, f"process_{proc}.npz"), **flat)
        with open(os.path.join(tmp, "MANIFEST"), "w") as f:
            f.write(f"step={step}\nprocesses={jax.process_count()}\n"
                    f"time={time.time()}\n")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    def save(self, step: int, tree) -> None:
        self.wait()
        tree = jax.tree.map(np.asarray, jax.device_get(tree))
        self._write(step, _flatten(tree))

    def save_async(self, step: int, tree) -> None:
        self.wait()
        # device_get on the main thread (arrays may be donated next step)
        flat = _flatten(jax.tree.map(np.asarray, jax.device_get(tree)))
        self._thread = threading.Thread(target=self._write,
                                        args=(step, flat), daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # -- restore ------------------------------------------------------------
    def all_steps(self):
        steps = []
        for name in os.listdir(self.dir):
            full = os.path.join(self.dir, name)
            if (name.startswith("step_") and not name.endswith(".tmp")
                    and os.path.exists(os.path.join(full, "MANIFEST"))):
                try:
                    steps.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(steps)

    def restore(self, step: int):
        proc = jax.process_index()
        path = os.path.join(self.dir, f"step_{step}", f"process_{proc}.npz")
        with np.load(path) as z:
            flat = {k: z[k] for k in z.files}
        return _unflatten(flat)

    def restore_latest(self) -> Tuple[Optional[int], Optional[Any]]:
        steps = self.all_steps()
        if not steps:
            return None, None
        return steps[-1], self.restore(steps[-1])
