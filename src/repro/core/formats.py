"""BFP (block floating point) quantization format descriptors.

Implements the llama.cpp / GGUF "k-quant" family used by the F-BFQ paper:

  * Q2_K, Q3_K   -- the two variants the paper's accelerator executes
  * Q4_K, Q5_K, Q6_K, Q8_0 -- the paper's stated future work ("support
                    Q4_K-Q8_K"), implemented here as beyond-paper variants
  * Q8_K         -- activation format (int8 per 256-value super-block)

Packed layout is TPU-native structure-of-arrays (SoA): for a weight matrix
``W`` of shape ``(K, N)`` quantized along the reduction axis ``K``, every
payload array keeps ``N`` on the minor (128-lane) dimension and packs
sub-byte fields along ``K`` in *slab order*:

    within each super-block of ``R`` rows, the packed array has ``R // F``
    rows (``F`` fields per byte); bit-field ``j`` (shift ``j * bits``) of
    packed row ``p`` holds original row ``j * (R // F) + p``.

Unpacking is therefore ``concat([(q >> bits*j) & mask for j in range(F)])``
over whole ``(R//F, N)`` slabs -- vectorizable on the TPU VPU with no
sub-lane shuffles (this is the kernel-side analogue of the paper's
"bit-slicer + data mapper").
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Tuple

import jax.numpy as jnp
import numpy as np

SUPER_BLOCK = 256   # weights per super-block (SB) for k-quants
BLOCK16 = 16        # Q2_K/Q3_K/Q6_K sub-block
BLOCK32 = 32        # Q4_K/Q5_K sub-block, Q8_0 block


@dataclasses.dataclass(frozen=True)
class ArraySpec:
    """Shape/dtype of one packed payload array, as a function of (K, N)."""
    name: str
    # divisor along K (packed rows = K // k_div); 0 means shape (K//256, N)
    k_div: int
    dtype: str

    def shape(self, K: int, N: int) -> Tuple[int, int]:
        return (K // self.k_div, N)


@dataclasses.dataclass(frozen=True)
class QuantFormat:
    name: str
    # effective bits per weight of THIS implementation's packed layout
    bits_per_weight: float
    # llama.cpp reference bits per weight (for honesty in reports)
    bits_per_weight_gguf: float
    block: int                 # sub-block size (per-block scale granularity)
    super_block: int           # rows per super-block along K
    arrays: Tuple[ArraySpec, ...]
    is_weight_format: bool = True

    def array_shapes(self, K: int, N: int) -> Dict[str, Tuple[Tuple[int, int], str]]:
        if K % self.super_block:
            raise ValueError(
                f"{self.name}: K={K} not divisible by super-block "
                f"{self.super_block}")
        return {a.name: (a.shape(K, N), a.dtype) for a in self.arrays}

    def nbytes(self, K: int, N: int) -> int:
        total = 0
        for a in self.arrays:
            shp = a.shape(K, N)
            total += int(np.prod(shp)) * np.dtype(a.dtype).itemsize
        return total


# --------------------------------------------------------------------------
# Format registry.
#
# bits/weight bookkeeping (ours vs llama.cpp GGUF):
#   Q2_K : 2 + 8/16 + 2*16/256                  = 2.625   (gguf: 2.625, exact)
#   Q3_K : 2 + 1 + 8/16 + 16/256                = 3.5625  (gguf: 3.4375; we
#           store the 6-bit block scales byte-aligned for lane-conflict-free
#           access -- +0.125 b/w)
#   Q3_K_O: q3_k + 8*(8+16)/256 outlier sidecar = 4.3125  (gguf: 4.1875)
#   Q4_0 : 4 + 16/32                            = 4.5     (gguf: 4.5, exact)
#   Q4_K : 4 + 2*8/32 + 2*16/256                = 4.625   (gguf: 4.5)
#   Q5_K : 5 + 2*8/32 + 2*16/256                = 5.625   (gguf: 5.5)
#   Q6_K : 4 + 2 + 8/16 + 16/256                = 6.5625  (gguf: 6.5625, exact)
#   Q8_0 : 8 + 16/32                            = 8.5     (gguf: 8.5, exact)
#   Q8_K : 8 + 32/256 + 16*16/256 (bsums)       = 9.125   (activation format)
# --------------------------------------------------------------------------

Q2_K = QuantFormat(
    name="q2_k", bits_per_weight=2.625, bits_per_weight_gguf=2.625,
    block=BLOCK16, super_block=SUPER_BLOCK,
    arrays=(
        ArraySpec("qs", 4, "uint8"),       # 4 x 2-bit quants per byte
        ArraySpec("scales", 16, "uint8"),  # lo nibble: scale, hi nibble: min
        ArraySpec("d", 256, "float16"),    # SB super-scale for scales
        ArraySpec("dmin", 256, "float16"), # SB super-scale for mins
    ))

Q3_K = QuantFormat(
    name="q3_k", bits_per_weight=3.5625, bits_per_weight_gguf=3.4375,
    block=BLOCK16, super_block=SUPER_BLOCK,
    arrays=(
        ArraySpec("qs", 4, "uint8"),       # low 2 bits
        ArraySpec("hmask", 8, "uint8"),    # high bit
        ArraySpec("scales", 16, "uint8"),  # 6-bit scale, stored 0..63
        ArraySpec("d", 256, "float16"),
    ))

Q3_K_O = QuantFormat(
    # beyond-paper outlier-aware variant (d-Matrix-style outlier blocks,
    # PAPERS.md): q3_k base plus an fp16 sidecar holding, per 256-row
    # super-block and per output column, the 8 most activation-sensitive
    # weight rows at full fp16 (local row index + value). The base q3_k
    # payload stores 0 at those positions; dequant scatters the sidecar
    # back. 8*(8+16)/256 = 0.75 extra bits/weight over q3_k.
    name="q3_k_o", bits_per_weight=4.3125, bits_per_weight_gguf=4.1875,
    block=BLOCK16, super_block=SUPER_BLOCK,
    arrays=(
        ArraySpec("qs", 4, "uint8"),       # low 2 bits (as q3_k)
        ArraySpec("hmask", 8, "uint8"),    # high bit (as q3_k)
        ArraySpec("scales", 16, "uint8"),  # 6-bit scale, stored 0..63
        ArraySpec("d", 256, "float16"),
        ArraySpec("oidx", 32, "uint8"),    # 8 outlier row idx per SB (local)
        ArraySpec("ovals", 32, "float16"), # their fp16 values
    ))

Q4_K = QuantFormat(
    name="q4_k", bits_per_weight=4.625, bits_per_weight_gguf=4.5,
    block=BLOCK32, super_block=SUPER_BLOCK,
    arrays=(
        ArraySpec("qs", 2, "uint8"),       # 2 x 4-bit per byte
        ArraySpec("scales", 32, "uint8"),  # 6-bit scale, 0..63
        ArraySpec("mins", 32, "uint8"),    # 6-bit min, 0..63
        ArraySpec("d", 256, "float16"),
        ArraySpec("dmin", 256, "float16"),
    ))

Q5_K = QuantFormat(
    name="q5_k", bits_per_weight=5.625, bits_per_weight_gguf=5.5,
    block=BLOCK32, super_block=SUPER_BLOCK,
    arrays=(
        ArraySpec("qs", 2, "uint8"),       # low 4 bits
        ArraySpec("qh", 8, "uint8"),       # high bit
        ArraySpec("scales", 32, "uint8"),
        ArraySpec("mins", 32, "uint8"),
        ArraySpec("d", 256, "float16"),
        ArraySpec("dmin", 256, "float16"),
    ))

Q6_K = QuantFormat(
    name="q6_k", bits_per_weight=6.5625, bits_per_weight_gguf=6.5625,
    block=BLOCK16, super_block=SUPER_BLOCK,
    arrays=(
        ArraySpec("ql", 2, "uint8"),       # low 4 bits
        ArraySpec("qh", 4, "uint8"),       # high 2 bits
        ArraySpec("scales", 16, "int8"),   # signed 8-bit block scales
        ArraySpec("d", 256, "float16"),
    ))

Q4_0 = QuantFormat(
    # llama.cpp's classic 32-block symmetric 4-bit format: one fp16 scale
    # per 32 values, d pinned by the abs-max element mapping to code 0
    name="q4_0", bits_per_weight=4.5, bits_per_weight_gguf=4.5,
    block=BLOCK32, super_block=BLOCK32,
    arrays=(
        ArraySpec("qs", 2, "uint8"),       # 2 x 4-bit per byte
        ArraySpec("d", 32, "float16"),
    ))

Q8_0 = QuantFormat(
    # llama.cpp fallback for tensors whose K is not a multiple of 256
    name="q8_0", bits_per_weight=8.5, bits_per_weight_gguf=8.5,
    block=BLOCK32, super_block=BLOCK32,
    arrays=(
        ArraySpec("qs", 1, "int8"),
        ArraySpec("d", 32, "float16"),
    ))

Q8_K = QuantFormat(
    # activation format: int8 per 256-value SB + fp32 scale + 16-block sums
    name="q8_k", bits_per_weight=9.125, bits_per_weight_gguf=9.125,
    block=BLOCK16, super_block=SUPER_BLOCK,
    arrays=(
        ArraySpec("qs", 1, "int8"),
        ArraySpec("d", 256, "float32"),
        ArraySpec("bsums", 16, "int16"),
    ),
    is_weight_format=False)

FORMATS: Dict[str, QuantFormat] = {
    f.name: f for f in (Q2_K, Q3_K, Q3_K_O, Q4_0, Q4_K, Q5_K, Q6_K, Q8_0,
                        Q8_K)
}

# variants the paper's accelerator supports natively
PAPER_VARIANTS = ("q2_k", "q3_k")
# variants listed as the paper's future work, implemented here (q3_k_o is
# our beyond-paper outlier-sidecar variant used by `--policy auto`)
EXTENDED_VARIANTS = ("q3_k_o", "q4_0", "q4_k", "q5_k", "q6_k", "q8_0")
WEIGHT_VARIANTS = PAPER_VARIANTS + EXTENDED_VARIANTS


def get_format(name: str) -> QuantFormat:
    try:
        return FORMATS[name]
    except KeyError:
        raise KeyError(f"unknown quant format {name!r}; "
                       f"known: {sorted(FORMATS)}") from None


def pick_fallback(name: str, K: int) -> str:
    """llama.cpp behaviour: k-quants need K % 256 == 0; otherwise the tensor
    falls back to a 32-block format (Q8_0 here)."""
    fmt = get_format(name)
    if K % fmt.super_block == 0:
        return name
    if K % 32 == 0:
        return "q8_0"
    raise ValueError(f"K={K} not quantizable (needs K % 32 == 0)")


# ---------------------------------------------------------------------------
# slab pack/unpack primitives (shared by quantize.py, kernels, tests)
# ---------------------------------------------------------------------------

def slab_pack(q: jnp.ndarray, bits: int, sb_rows: int) -> jnp.ndarray:
    """Pack integer array q (K, N), values in [0, 2^bits), into bytes.

    F = 8 // bits fields per byte; within each super-block of ``sb_rows``
    rows, field j of packed row p holds original row ``j * (sb_rows//F) + p``.
    """
    F = 8 // bits
    K, N = q.shape
    assert K % sb_rows == 0, (K, sb_rows)
    slab = sb_rows // F
    qq = q.astype(jnp.uint8).reshape(K // sb_rows, F, slab, N)
    out = jnp.zeros((K // sb_rows, slab, N), jnp.uint8)
    for j in range(F):
        out = out | (qq[:, j] << (bits * j))
    return out.reshape(K // F, N)


def slab_unpack(packed: jnp.ndarray, bits: int, sb_rows: int) -> jnp.ndarray:
    """Inverse of slab_pack: (K//F, N) bytes -> (K, N) ints in [0, 2^bits)."""
    F = 8 // bits
    Kp, N = packed.shape
    slab = sb_rows // F
    assert Kp % slab == 0, (Kp, sb_rows)
    p = packed.reshape(Kp // slab, slab, N)
    mask = (1 << bits) - 1
    slabs = [((p >> (bits * j)) & mask) for j in range(F)]
    return jnp.concatenate(slabs, axis=1).reshape(Kp * F, N)
