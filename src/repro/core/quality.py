"""Quantization quality metrics: teacher-logit KL + pseudo-perplexity.

The repo's first quality metric (ROADMAP item 5). A quantized ("student")
model is scored against its own fp32 weights ("teacher") on a fixed eval
batch: no dataset needed, works for every family in ``configs/`` via
``forward_seq``, and deterministic for a given seed -- which is what the
policy search and the e2e_serve bench gate need (relative quality across
policies, not an absolute language-modeling number).

Metrics (all averaged over batch x sequence):
  * ``kl``         -- KL(teacher || student) over the vocab softmax; the
                      primary search objective (0 = logit-identical).
  * ``pseudo_ppl`` -- exp(mean student NLL of the teacher's argmax token):
                      perplexity against teacher-greedy pseudo-labels.
  * ``top1``       -- fraction of positions where the argmaxes agree
                      (greedy-decode fidelity).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp


def eval_tokens(cfg, *, batch: int = 2, seq: int = 64, seed: int = 1234):
    """Deterministic eval inputs for ``cfg`` (tokens, or embeds for
    families with ``embed_input=False``)."""
    key = jax.random.PRNGKey(seed)
    if cfg.embed_input:
        return jax.random.randint(key, (batch, seq), 0, cfg.vocab_size)
    return jax.random.normal(key, (batch, seq, cfg.d_model))


def _forward_logits(params, cfg, inputs, interpret=False):
    from repro.models import transformer as T
    kwargs = dict(tokens=inputs) if cfg.embed_input else dict(embeds=inputs)
    lg, _, _ = T.forward_seq(params, cfg, interpret=interpret, **kwargs)
    return lg.astype(jnp.float32)


def logit_metrics(teacher_logits, student_logits) -> Dict[str, float]:
    """Metrics from two (B, S, V) logit tensors (teacher = reference)."""
    tl = jax.nn.log_softmax(teacher_logits.astype(jnp.float32), axis=-1)
    sl = jax.nn.log_softmax(student_logits.astype(jnp.float32), axis=-1)
    tp = jnp.exp(tl)
    kl = jnp.sum(tp * (tl - sl), axis=-1)                   # (B, S)
    labels = jnp.argmax(teacher_logits, axis=-1)            # (B, S)
    nll = -jnp.take_along_axis(sl, labels[..., None], axis=-1)[..., 0]
    top1 = (jnp.argmax(student_logits, axis=-1) == labels)
    return dict(kl=float(jnp.mean(kl)),
                pseudo_ppl=float(jnp.exp(jnp.mean(nll))),
                top1=float(jnp.mean(top1)))


def quality_eval(teacher_params, student_params, cfg, *,
                 inputs=None, batch: int = 2, seq: int = 64,
                 seed: int = 1234, teacher_logits=None,
                 interpret: bool = False) -> Dict[str, float]:
    """Score ``student_params`` (typically quantized) against
    ``teacher_params`` (fp32) on a fixed eval batch.

    Pass ``teacher_logits`` to amortize the teacher forward across many
    student evaluations (the policy search's inner loop)."""
    if inputs is None:
        inputs = eval_tokens(cfg, batch=batch, seq=seq, seed=seed)
    if teacher_logits is None:
        teacher_logits = _forward_logits(teacher_params, cfg, inputs,
                                         interpret=interpret)
    student_logits = _forward_logits(student_params, cfg, inputs,
                                     interpret=interpret)
    return logit_metrics(teacher_logits, student_logits)


def teacher_logits_for(params, cfg, *, inputs=None, batch: int = 2,
                       seq: int = 64, seed: int = 1234,
                       interpret: bool = False):
    """(inputs, teacher_logits) pair for repeated student scoring."""
    if inputs is None:
        inputs = eval_tokens(cfg, batch=batch, seq=seq, seed=seed)
    return inputs, _forward_logits(params, cfg, inputs, interpret=interpret)
