"""Activation calibration for quantization policy search (ROADMAP item 5).

Runs a small token budget through the fp32 model and records, per matmul
input, the statistics that drive format selection (Agile-Quant-style
activation-guided sensitivity; see PAPERS.md):

  * per-K-column activation abs-max   -> outlier columns for q3_k_o
  * per-K-column mean square          -> activation-weighted quant error
  * outlier-column fraction           -> which layers want the sidecar

Mechanics: the model's matmul call sites invoke :func:`tap` with a stable
projection *suffix* name (e.g. ``"attn/wq"``, ``"mlp/w_down"``) and the
matmul input. When no collector is active (normal serving/training) the
tap is a trace-time no-op -- zero graph overhead. Inside
:func:`collecting`, the tap emits in-graph reductions through
``jax.debug.callback``, which fires once per ``lax.scan`` iteration at
*runtime* -- so stacked scan layers accumulate into one per-suffix
aggregate, exactly matching the per-projection granularity of
``QuantPolicy`` paths (stacked layers share one path).

Calibration drives the model's full-sequence forward -- the same
``_qkv``/``_attn_out``/mlp code path the serving engine's chunked prefill
executes -- so it works unchanged on every family in ``configs/``.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quantize as Q

# active collector; checked at TRACE time so ordinary jitted serving code
# contains no callbacks at all
_COLLECTOR: Optional["_Collector"] = None


class _Collector:
    def __init__(self):
        self.absmax: Dict[str, np.ndarray] = {}
        self.sumsq: Dict[str, np.ndarray] = {}
        self.rows: Dict[str, float] = {}

    def record(self, name: str, absmax, sumsq, rows):
        a = np.asarray(absmax, np.float32)
        s = np.asarray(sumsq, np.float32)
        r = float(rows)
        if name in self.absmax:
            self.absmax[name] = np.maximum(self.absmax[name], a)
            self.sumsq[name] = self.sumsq[name] + s
            self.rows[name] += r
        else:
            self.absmax[name] = a
            self.sumsq[name] = s
            self.rows[name] = r


def tap(name, x) -> None:
    """Record activation stats for matmul input ``x`` (..., K) feeding the
    weight(s) whose parameter path ends with ``name`` (a str or a tuple of
    suffixes sharing this input, e.g. wq/wk/wv). No-op unless inside
    :func:`collecting`."""
    col = _COLLECTOR
    if col is None:
        return
    names = (name,) if isinstance(name, str) else tuple(name)
    K = x.shape[-1]
    xf = x.astype(jnp.float32).reshape(-1, K)
    absmax = jnp.max(jnp.abs(xf), axis=0)
    sumsq = jnp.sum(xf * xf, axis=0)
    rows = jnp.asarray(xf.shape[0], jnp.float32)

    def _cb(a, s, r, _names=names, _col=col):
        for n in _names:
            _col.record(n, a, s, r)

    jax.debug.callback(_cb, absmax, sumsq, rows)


@contextlib.contextmanager
def collecting():
    """Activate a stats collector for taps traced within the block."""
    global _COLLECTOR
    prev = _COLLECTOR
    col = _Collector()
    _COLLECTOR = col
    try:
        yield col
    finally:
        _COLLECTOR = prev


# ---------------------------------------------------------------------------
# calibration results
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CalibStats:
    """Aggregated activation statistics, keyed by tap suffix name."""
    absmax: Dict[str, np.ndarray]     # name -> (K,) column abs-max
    mean_sq: Dict[str, np.ndarray]    # name -> (K,) column mean square
    tokens: int                       # total calibration rows observed

    def names(self):
        return sorted(self.absmax)

    def outlier_fraction(self, name: str, z: float = 6.0) -> float:
        """Fraction of K columns whose abs-max exceeds z * median abs-max
        (the d-Matrix outlier-block criterion, column granularity)."""
        a = self.absmax[name]
        med = float(np.median(a))
        if med <= 0:
            return 0.0
        return float(np.mean(a > z * med))

    def for_paths(self, paths: Sequence[str]) -> Dict[str, np.ndarray]:
        """Map tap suffixes onto full parameter paths by suffix match --
        the shape ``quantize_params(calib=...)`` expects."""
        out = {}
        for path in paths:
            for name, a in self.absmax.items():
                if path == name or path.endswith("/" + name):
                    out[path] = a
                    break
        return out


def _stats_from(col: _Collector) -> CalibStats:
    mean_sq = {n: col.sumsq[n] / max(col.rows[n], 1.0) for n in col.sumsq}
    tokens = int(max(col.rows.values())) if col.rows else 0
    return CalibStats(dict(col.absmax), mean_sq, tokens)


def run_calibration(params, cfg, *, tokens=None, batch: int = 2,
                    seq: int = 64, n_batches: int = 2, seed: int = 0,
                    interpret: bool = False) -> CalibStats:
    """Run the fp32 model over a small token budget and collect stats.

    ``tokens``: optional (B, S) int array per batch list; otherwise
    ``n_batches`` random batches are drawn (fine for policy search: the
    stats feeding the search only need the activation *distribution
    shape*, and the quality eval uses the same distribution).
    Families with ``embed_input=False`` get random embedding inputs.
    """
    from repro.models import transformer as T

    if tokens is not None:
        batches = [jnp.asarray(t) for t in
                   (tokens if isinstance(tokens, (list, tuple)) else [tokens])]
    else:
        keys = jax.random.split(jax.random.PRNGKey(seed), n_batches)
        if cfg.embed_input:
            batches = [jax.random.randint(k, (batch, seq), 0,
                                          cfg.vocab_size) for k in keys]
        else:
            batches = [jax.random.normal(k, (batch, seq, cfg.d_model))
                       for k in keys]
    with collecting() as col:
        for b in batches:
            kwargs = (dict(tokens=b) if cfg.embed_input
                      else dict(embeds=b))
            lg, _, _ = T.forward_seq(params, cfg, interpret=interpret,
                                     **kwargs)
            jax.block_until_ready(lg)   # flush debug callbacks
    return _stats_from(col)


# ---------------------------------------------------------------------------
# offline per-format quantization error (no model run needed)
# ---------------------------------------------------------------------------

def format_mse(params, stats: Optional[CalibStats],
               candidates: Sequence[str],
               paths: Optional[Sequence[str]] = None) -> Dict[str, Dict[str, float]]:
    """Activation-weighted quantization MSE per (path, candidate format).

    For each quantizable weight W (K, N) and candidate variant v:
        mse = mean_k,n [ (W - deq(quant_v(W)))^2 * E[x_k^2] / mean E[x^2] ]
    i.e. reconstruction error weighted by how hard each K row is actually
    driven by the calibration activations. The absolute numbers only rank
    candidates per path; the policy search uses the real end-to-end
    quality eval for accept decisions.
    """
    from repro.core.qlinear import _flatten_paths, _is_quantizable_path

    flat = _flatten_paths(params)
    want = set(paths) if paths is not None else None
    out: Dict[str, Dict[str, float]] = {}
    for path, arr in flat:
        if want is not None and path not in want:
            continue
        if arr.ndim < 2 or not _is_quantizable_path(path):
            continue
        K, N = arr.shape[-2], arr.shape[-1]
        if K % 256 != 0:
            continue
        w = jnp.asarray(arr, jnp.float32).reshape(-1, K, N)
        wk = None
        if stats is not None:
            m = stats.for_paths([path]).get(path)
            # for_paths returns absmax; weight by mean-square instead
            for name in stats.mean_sq:
                if path == name or path.endswith("/" + name):
                    m = stats.mean_sq[name]
                    break
            if m is not None and K % m.size == 0:
                wk = np.tile(np.asarray(m, np.float32), K // m.size)
                mean = float(wk.mean())
                wk = wk / mean if mean > 0 else None
        per = {}
        for v in candidates:
            qfn = Q._QUANTIZE[v]
            if v == "q3_k_o" and wk is not None:
                a = jnp.asarray(np.sqrt(wk))
                qd = jax.vmap(lambda x, _a=a:
                              Q.dequantize(Q.quantize_q3_k_o(x, act_absmax=_a)))(w)
            else:
                qd = jax.vmap(lambda x, _f=qfn: Q.dequantize(_f(x)))(w)
            err = (w - qd) ** 2
            if wk is not None:
                err = err * jnp.asarray(wk)[None, :, None]
            per[v] = float(jnp.mean(err))
        out[path] = per
    return out
