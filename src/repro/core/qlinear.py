"""Serve-time parameter quantization: bf16/f32 params -> packed QTensors.

``quantize_params`` walks the parameter pytree, asks the ``QuantPolicy``
for each matmul weight's variant (mixed per-layer/per-tensor -- the paper's
deployment reality), and packs it. Stacked leading dims (scan layers,
experts) are handled by vmapping the quantizer, except MoE expert stacks
which pack along E*K into a single QTensor so the expert einsum can
dequantize once (see models/moe.py).

This module is the software analogue of the paper's F-BFQ *driver*
configuration step: it decides, per tensor, which mode (weight_type
register) the DSBP will run in.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import quantize as Q
from repro.core.policy import QuantPolicy

# parameter-path fragments that are never quantized at serve time
_NEVER = ("ln", "norm", "wpe", "b_", "bias", "router", "conv", "A_log", "D",
          "dt_bias", "pos", "wte")


def _is_quantizable_path(path: str) -> bool:
    parts = path.split("/")
    leaf = parts[-1]
    for frag in _NEVER:
        if leaf == frag or leaf.startswith(frag):
            return False
    if any(p.startswith("ln") or p == "norm" for p in parts[:-1]):
        return False
    return True


def _flatten_paths(tree, prefix=""):
    out = []
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.extend(_flatten_paths(tree[k], f"{prefix}{k}/"))
    else:
        out.append((prefix[:-1], tree))
    return out


def quantize_params(params: Dict[str, Any], policy: QuantPolicy,
                    expert_stack_paths: Tuple[str, ...] = ("moe/w_",),
                    calib: Optional[Dict[str, Any]] = None):
    """Returns (qparams, report). report: path -> variant|None.

    ``calib`` optionally maps parameter path -> per-K-column activation
    abs-max (from core/calibrate.py); outlier-aware variants (q3_k_o) use
    it to pick which rows go to the fp16 sidecar. Stats for a stacked
    expert tensor (packed along E*K) are tiled across experts."""
    report: Dict[str, Optional[str]] = {}

    def walk(node, prefix=""):
        if isinstance(node, dict):
            return {k: walk(v, f"{prefix}{k}/") for k, v in node.items()}
        path = prefix[:-1]
        arr = node
        if arr.ndim < 2 or not _is_quantizable_path(path):
            report[path] = None
            return arr
        K, N = arr.shape[-2], arr.shape[-1]
        is_expert = any(f in path for f in expert_stack_paths)
        variant = policy.variant_for(path, K, N)
        if variant is None:
            report[path] = None
            return arr
        report[path] = variant
        qfn = Q._QUANTIZE[variant]
        if variant == "q3_k_o" and calib is not None:
            stats = calib.get(path)
            Keff = arr.shape[-3] * K if (is_expert and arr.ndim >= 3) else K
            if stats is not None:
                a = jnp.asarray(stats, jnp.float32).reshape(-1)
                if Keff % a.size == 0:
                    aa = jnp.tile(a, Keff // a.size)
                    qfn = (lambda w, _a=aa:
                           Q.quantize_q3_k_o(w, act_absmax=_a))
        if arr.ndim == 2:
            return qfn(arr)
        if is_expert and arr.ndim >= 3:
            # pack experts along E*K: (L, E, K, N) -> per-layer (E*K, N)
            lead = arr.shape[:-3]
            E = arr.shape[-3]
            flat = arr.reshape(lead + (E * K, N))
            f = qfn
            for _ in lead:
                f = jax.vmap(f)
            return f(flat)
        # stacked layers: vmap over leading dims
        f = qfn
        for _ in arr.shape[:-2]:
            f = jax.vmap(f)
        return f(arr)

    qparams = walk(params)
    return qparams, report


def quantized_param_bytes(qparams) -> Dict[str, int]:
    """HBM footprint by leaf kind (packed vs residual fp)."""
    packed = unpacked = 0
    for leaf in jax.tree.leaves(
            qparams, is_leaf=lambda x: isinstance(x, Q.QTensor)):
        if isinstance(leaf, Q.QTensor):
            for a in leaf.data.values():
                import numpy as np
                packed += int(np.prod(a.shape)) * jnp.dtype(a.dtype).itemsize
        else:
            import numpy as np
            unpacked += int(np.prod(leaf.shape)) * jnp.dtype(leaf.dtype).itemsize
    return dict(packed=packed, unpacked=unpacked, total=packed + unpacked)


def spec_like_quantized(params_spec: Dict[str, Any], policy: QuantPolicy,
                        expert_stack_paths: Tuple[str, ...] = ("moe/w_",)):
    """ShapeDtypeStruct version of quantize_params for dry-run lowering:
    walks a pytree of ShapeDtypeStructs and replaces quantizable leaves with
    packed-spec QTensors (no allocation)."""
    from repro.core.formats import get_format, pick_fallback

    def walk(node, prefix=""):
        if isinstance(node, dict):
            return {k: walk(v, f"{prefix}{k}/") for k, v in node.items()}
        path = prefix[:-1]
        arr = node
        if len(arr.shape) < 2 or not _is_quantizable_path(path):
            return arr
        K, N = arr.shape[-2], arr.shape[-1]
        is_expert = any(f in path for f in expert_stack_paths)
        variant = policy.variant_for(path, K, N)
        if variant is None:
            return arr
        variant = pick_fallback(variant, K)
        fmt = get_format(variant)
        if is_expert and len(arr.shape) >= 3:
            lead = arr.shape[:-3]
            E = arr.shape[-3]
            Keff = E * K
        else:
            lead = arr.shape[:-2]
            Keff = K
        data = {}
        for name, (shape, dt) in fmt.array_shapes(Keff, N).items():
            data[name] = jax.ShapeDtypeStruct(tuple(lead) + shape,
                                              jnp.dtype(dt))
        return Q.QTensor(variant, (Keff, N), data)

    return walk(params_spec)
