"""Micro-ISA opcode stream generator + functional simulator (paper Table I).

The F-BFQ driver controls the accelerator with five opcodes sent over
AXI-Stream; operands (packed super-blocks) follow load opcodes inline.
We reproduce the instruction stream *exactly* (opcodes, config registers,
output-stationary tiling decision from §III-C) and provide a functional
simulator that executes a stream against packed ``QTensor`` data. The
simulator doubles as the oracle for the Pallas kernel's tiling plan and
as the byte-traffic model for the Table II/IV analyses.

Driver flow (paper §III-C):
  1. 0x01 CONFIG with MatMul dims + weight_type register (Q2_K / Q3_K mode)
  2. if the input matrix fits the input buffer: send it once; otherwise
     output-stationary tiling, streaming weights (0x02) / inputs (0x04)
  3. 0x08 SCHEDULE starts the DSBP on the loaded tile
  4. 0x10 STORE drains the accumulator back to main memory
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.core.formats import get_format
from repro.core.quantize import QTensor
from repro.kernels import ref as _ref


class Op(enum.IntEnum):
    CONFIG = 0x01
    LOAD_W = 0x02
    LOAD_I = 0x04
    SCHEDULE = 0x08
    STORE = 0x10


@dataclasses.dataclass
class Insn:
    op: Op
    # CONFIG operands
    dims: Optional[Tuple[int, int, int]] = None      # (M, K, N)
    weight_type: Optional[str] = None                # "q2_k" | "q3_k" | ...
    n_sbs: Optional[int] = None                      # SBs per load (0x01 cfg)
    # LOAD operands: half-open tile ranges
    k_range: Optional[Tuple[int, int]] = None
    n_range: Optional[Tuple[int, int]] = None
    m_range: Optional[Tuple[int, int]] = None


def qtensor_tile(t: QTensor, k0: int, k1: int, n0: int, n1: int) -> QTensor:
    """Slice a packed tensor along (K, N); k0/k1 must be SB-aligned."""
    fmt = get_format(t.variant)
    sb = fmt.super_block
    assert k0 % sb == 0 and k1 % sb == 0, (k0, k1, sb)
    kdiv = {a.name: a.k_div for a in fmt.arrays}
    data = {name: arr[k0 // kdiv[name]: k1 // kdiv[name], n0:n1]
            for name, arr in t.data.items()}
    return QTensor(t.variant, (k1 - k0, n1 - n0), data)


@dataclasses.dataclass
class TilingPlan:
    tile_m: int
    tile_n: int
    tile_k: int
    whole_input: bool     # paper: input fits in input buffer -> send once


def plan_tiling(M: int, K: int, N: int, variant: str,
                input_buf_bytes: int = 1 << 20,
                weight_buf_bytes: int = 1 << 20,
                tile_m: int = 128, tile_n: int = 256,
                x_itemsize: int = 4) -> TilingPlan:
    """Output-stationary tiling decision (paper §III-C / driver step ii)."""
    fmt = get_format(variant)
    sb = fmt.super_block
    whole_input = M * K * x_itemsize <= input_buf_bytes
    tk = K
    # shrink K tile until the packed weight tile fits the weight buffer
    while fmt.nbytes(tk, min(tile_n, N)) > weight_buf_bytes and tk > sb:
        tk = max(sb, tk // 2 // sb * sb)
    return TilingPlan(tile_m=min(tile_m, M), tile_n=min(tile_n, N),
                      tile_k=tk, whole_input=whole_input)


def generate_stream(M: int, K: int, N: int, variant: str,
                    plan: Optional[TilingPlan] = None) -> List[Insn]:
    """Driver: emit the opcode stream for one MatMul (paper Table I)."""
    plan = plan or plan_tiling(M, K, N, variant)
    fmt = get_format(variant)
    ins: List[Insn] = [Insn(Op.CONFIG, dims=(M, K, N), weight_type=variant,
                            n_sbs=plan.tile_k // fmt.super_block)]
    if plan.whole_input:
        ins.append(Insn(Op.LOAD_I, m_range=(0, M), k_range=(0, K)))
    for n0 in range(0, N, plan.tile_n):
        n1 = min(N, n0 + plan.tile_n)
        for m0 in range(0, M, plan.tile_m):
            m1 = min(M, m0 + plan.tile_m)
            # output-stationary: sweep K for a fixed output tile
            for k0 in range(0, K, plan.tile_k):
                k1 = min(K, k0 + plan.tile_k)
                ins.append(Insn(Op.LOAD_W, k_range=(k0, k1), n_range=(n0, n1)))
                if not plan.whole_input:
                    ins.append(Insn(Op.LOAD_I, m_range=(m0, m1),
                                    k_range=(k0, k1)))
                ins.append(Insn(Op.SCHEDULE))
            ins.append(Insn(Op.STORE, m_range=(m0, m1), n_range=(n0, n1)))
    return ins


@dataclasses.dataclass
class SimStats:
    weight_bytes: int = 0
    input_bytes: int = 0
    output_bytes: int = 0
    schedules: int = 0

    @property
    def total_stream_bytes(self):
        return self.weight_bytes + self.input_bytes + self.output_bytes


class FBFQSimulator:
    """Functional model of the accelerator executing an opcode stream.

    State mirrors Fig. 3/4: config registers, weight/input SB caches,
    an fp32 accumulator. The DSBP compute step uses the llama.cpp-exact
    integer datapath (``ref.matmul_q8k_ref``) for q2_k/q3_k and the
    dequant datapath otherwise.
    """

    def __init__(self, x: np.ndarray, w: QTensor, use_int_datapath=True):
        self.x = np.asarray(x, dtype=np.float32)
        self.w = w
        self.use_int = use_int_datapath and w.variant in ("q2_k", "q3_k")
        self.cfg = None
        self.w_tile: Optional[QTensor] = None
        self.x_tile: Optional[np.ndarray] = None
        self.x_rng = None
        self.w_rng = None
        self.acc: Optional[np.ndarray] = None
        self.out: Optional[np.ndarray] = None
        self.stats = SimStats()

    def run(self, stream: List[Insn]) -> np.ndarray:
        for ins in stream:
            getattr(self, f"_op_{ins.op.name.lower()}")(ins)
        assert self.out is not None, "stream produced no STORE"
        return self.out

    # -- opcode handlers ----------------------------------------------------
    def _op_config(self, ins: Insn):
        assert ins.weight_type == self.w.variant, "weight_type register mismatch"
        self.cfg = ins
        M, K, N = ins.dims
        self.out = np.zeros((M, N), np.float32)
        self._accs: Dict[Tuple[int, int], np.ndarray] = {}

    def _op_load_w(self, ins: Insn):
        k0, k1 = ins.k_range
        n0, n1 = ins.n_range
        self.w_tile = qtensor_tile(self.w, k0, k1, n0, n1)
        self.w_rng = (ins.k_range, ins.n_range)
        self.stats.weight_bytes += self.w_tile.nbytes

    def _op_load_i(self, ins: Insn):
        m0, m1 = ins.m_range
        k0, k1 = ins.k_range
        self.x_tile = self.x[m0:m1, k0:k1]
        self.x_rng = (ins.m_range, ins.k_range)
        # Q8_K stream density: ~9.125 bits/value (qs + d + bsums)
        self.stats.input_bytes += int(self.x_tile.size * 9.125 / 8)

    def _op_schedule(self, ins: Insn):
        assert self.w_tile is not None and self.x_tile is not None
        (k0w, k1w), (n0, n1) = self.w_rng
        (m0, m1), (k0x, k1x) = self.x_rng
        # align input slice to the weight tile's K range
        xs = self.x[m0:m1, k0w:k1w] if (k0x, k1x) != (k0w, k1w) else self.x_tile
        if self.use_int:
            import jax.numpy as jnp
            from repro.core.quantize import quantize_q8_k
            qx = quantize_q8_k(jnp.asarray(xs))
            part = np.asarray(_ref.matmul_q8k_ref(qx, self.w_tile))
        else:
            import jax.numpy as jnp
            part = np.asarray(_ref.matmul_ref(jnp.asarray(xs), self.w_tile))
        key = ((m0, m1), (n0, n1))
        self._accs[key] = self._accs.get(key, 0) + part
        self.stats.schedules += 1

    def _op_store(self, ins: Insn):
        m0, m1 = ins.m_range
        n0, n1 = ins.n_range
        self.out[m0:m1, n0:n1] = self._accs.pop(((m0, m1), (n0, n1)))
        self.stats.output_bytes += (m1 - m0) * (n1 - n0) * 4


def run_matmul(x: np.ndarray, w: QTensor,
               plan: Optional[TilingPlan] = None,
               use_int_datapath: bool = True):
    """Convenience: driver + simulator for one MatMul; returns (out, stats)."""
    M, K = x.shape
    Kt, N = w.shape
    assert K == Kt
    stream = generate_stream(M, K, N, w.variant, plan)
    sim = FBFQSimulator(x, w, use_int_datapath=use_int_datapath)
    out = sim.run(stream)
    return out, sim.stats
