"""Quantize / dequantize for the GGUF k-quant family (pure JAX, jittable).

Dequantization is llama.cpp-exact in *semantics* (same reconstruction
formulas, same field widths). Quantization is a vectorized one-shot
min/max (affine formats) or absmax (symmetric formats) fit with the block
scales themselves re-quantized to their narrow fields against a per-SB
super-scale, exactly mirroring the two-level scheme of the paper's Fig. 2 --
but without llama.cpp's iterative `make_qkx2_quants` refinement search (the
paper's contribution is executing pre-quantized models, not the quantizer;
see DESIGN.md §7).

Weights: shape (K, N), quantized along K (the reduction axis), N on lanes.
Activations (Q8_K): shape (..., K), quantized along the trailing axis.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import formats as F
from repro.core.formats import slab_pack, slab_unpack


# ---------------------------------------------------------------------------
# QTensor: packed quantized weight tensor (registered pytree)
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QTensor:
    """A (K, N) weight matrix in packed BFP form.

    ``data`` holds the payload arrays named per ``formats.FORMATS[variant]``;
    ``variant``/``shape`` are static (pytree aux) so jitted code can dispatch
    per-variant without retracing on values.
    """
    variant: str
    shape: Tuple[int, int]      # logical (K, N)
    data: Dict[str, jnp.ndarray]

    def tree_flatten(self):
        keys = tuple(sorted(self.data))
        return tuple(self.data[k] for k in keys), (self.variant, self.shape, keys)

    @classmethod
    def tree_unflatten(cls, aux, children):
        variant, shape, keys = aux
        return cls(variant, shape, dict(zip(keys, children)))

    @property
    def nbytes(self) -> int:
        total = 0
        for v in self.data.values():
            # works for ShapeDtypeStruct stand-ins too
            import numpy as _np
            total += int(_np.prod(v.shape)) * jnp.dtype(v.dtype).itemsize
        return total

    @property
    def bits_per_weight(self) -> float:
        K, N = self.shape
        return self.nbytes * 8.0 / (K * N)

    def astuple(self):
        return tuple(self.data[k] for k in sorted(self.data))


def _nearest(x):
    # round-half-away like llama.cpp's nearest_int on the values we produce
    return jnp.round(x)


def _safe_inv(x):
    return jnp.where(x > 0, 1.0 / jnp.where(x > 0, x, 1.0), 0.0)


# ---------------------------------------------------------------------------
# Q2_K
# ---------------------------------------------------------------------------

def quantize_q2_k(w: jnp.ndarray) -> QTensor:
    K, N = w.shape
    assert K % 256 == 0, K
    nsb = K // 256
    x = w.astype(jnp.float32).reshape(nsb, 16, 16, N)       # (sb, blk, in, N)
    bmax = x.max(axis=2)
    bmin = x.min(axis=2)
    min_f = jnp.maximum(0.0, -bmin)                          # (sb, 16, N) >= 0
    scale_f = jnp.maximum(bmax + min_f, 0.0) / 3.0
    d = scale_f.max(axis=1) / 15.0                           # (sb, N)
    dmin = min_f.max(axis=1) / 15.0
    sc_q = jnp.clip(_nearest(scale_f * _safe_inv(d)[:, None]), 0, 15)
    m_q = jnp.clip(_nearest(min_f * _safe_inv(dmin)[:, None]), 0, 15)
    eff_sc = d[:, None] * sc_q                               # (sb, 16, N)
    eff_mn = dmin[:, None] * m_q
    q = jnp.clip(_nearest((x + eff_mn[:, :, None]) * _safe_inv(eff_sc)[:, :, None]),
                 0, 3)
    qs = slab_pack(q.reshape(K, N), 2, 256)
    scales = (sc_q.astype(jnp.uint8) | (m_q.astype(jnp.uint8) << 4)).reshape(K // 16, N)
    return QTensor("q2_k", (K, N), dict(
        qs=qs, scales=scales,
        d=d.astype(jnp.float16), dmin=dmin.astype(jnp.float16)))


def dequantize_q2_k(t: QTensor, dtype=jnp.float32) -> jnp.ndarray:
    K, N = t.shape
    nsb = K // 256
    q = slab_unpack(t.data["qs"], 2, 256).reshape(nsb, 16, 16, N).astype(jnp.float32)
    sc = (t.data["scales"] & 0xF).reshape(nsb, 16, N).astype(jnp.float32)
    mn = (t.data["scales"] >> 4).reshape(nsb, 16, N).astype(jnp.float32)
    d = t.data["d"].astype(jnp.float32)[:, None]             # (sb, 1, N)
    dmin = t.data["dmin"].astype(jnp.float32)[:, None]
    w = (d * sc)[:, :, None] * q - (dmin * mn)[:, :, None]
    return w.reshape(K, N).astype(dtype)


# ---------------------------------------------------------------------------
# Q3_K
# ---------------------------------------------------------------------------

def quantize_q3_k(w: jnp.ndarray) -> QTensor:
    K, N = w.shape
    assert K % 256 == 0, K
    nsb = K // 256
    x = w.astype(jnp.float32).reshape(nsb, 16, 16, N)
    amax = jnp.abs(x).max(axis=2)                            # (sb, 16, N)
    scale_f = amax / 4.0
    d = scale_f.max(axis=1) / 31.0                           # (sb, N)
    sc_q = jnp.clip(_nearest(scale_f * _safe_inv(d)[:, None]), 0, 31)
    eff = d[:, None] * sc_q
    q = jnp.clip(_nearest(x * _safe_inv(eff)[:, :, None]), -4, 3) + 4  # [0,7]
    q = q.reshape(K, N)
    qs = slab_pack(q.astype(jnp.uint8) & 3, 2, 256)
    hmask = slab_pack(q.astype(jnp.uint8) >> 2, 1, 256)
    scales = (sc_q + 32).astype(jnp.uint8).reshape(K // 16, N)  # stored 0..63
    return QTensor("q3_k", (K, N), dict(
        qs=qs, hmask=hmask, scales=scales, d=d.astype(jnp.float16)))


def dequantize_q3_k(t: QTensor, dtype=jnp.float32) -> jnp.ndarray:
    K, N = t.shape
    nsb = K // 256
    lo = slab_unpack(t.data["qs"], 2, 256)
    hi = slab_unpack(t.data["hmask"], 1, 256)
    q = (lo + (hi << 2)).astype(jnp.float32) - 4.0           # [-4, 3]
    q = q.reshape(nsb, 16, 16, N)
    sc = t.data["scales"].astype(jnp.float32).reshape(nsb, 16, N) - 32.0
    d = t.data["d"].astype(jnp.float32)[:, None]
    w = (d * sc)[:, :, None] * q
    return w.reshape(K, N).astype(dtype)


# ---------------------------------------------------------------------------
# Q3_K_O (beyond-paper): q3_k base + fp16 outlier sidecar.
#
# Per 256-row super-block and per output column, the OUTLIERS_PER_SB rows
# with the largest (activation-weighted) magnitude are stored exactly in an
# fp16 sidecar (local row index + value) and zeroed before the q3_k fit, so
# the narrow 3-bit grid is spent on the well-behaved bulk. ``act_absmax``
# comes from core/calibrate.py (per-K-column activation abs-max); without it
# the selection falls back to weight magnitude alone.
# ---------------------------------------------------------------------------

OUTLIERS_PER_SB = 8


def quantize_q3_k_o(w: jnp.ndarray, act_absmax=None) -> QTensor:
    K, N = w.shape
    assert K % 256 == 0, K
    nsb = K // 256
    no = OUTLIERS_PER_SB
    x = w.astype(jnp.float32).reshape(nsb, 256, N)
    score = jnp.abs(x)
    if act_absmax is not None:
        a = jnp.asarray(act_absmax, jnp.float32).reshape(nsb, 256)
        score = score * a[:, :, None]
    # top-`no` rows per (super-block, column); top_k works on the last axis
    _, idx = jax.lax.top_k(jnp.swapaxes(score, 1, 2), no)   # (nsb, N, no)
    idx = jnp.swapaxes(idx, 1, 2)                           # (nsb, no, N)
    ovals = jnp.take_along_axis(x, idx, axis=1)             # (nsb, no, N)
    rows = jax.lax.broadcasted_iota(jnp.int32, (nsb, 256, N), 1)
    mask = jnp.zeros((nsb, 256, N), bool)
    for j in range(no):
        mask = mask | (rows == idx[:, j][:, None, :])
    base = jnp.where(mask, 0.0, x).reshape(K, N)
    qt = quantize_q3_k(base)
    return QTensor("q3_k_o", (K, N), dict(
        qt.data,
        oidx=idx.astype(jnp.uint8).reshape(K // 32, N),
        ovals=ovals.astype(jnp.float16).reshape(K // 32, N)))


def dequantize_q3_k_o(t: QTensor, dtype=jnp.float32) -> jnp.ndarray:
    K, N = t.shape
    nsb = K // 256
    no = OUTLIERS_PER_SB
    base = dequantize_q3_k(
        QTensor("q3_k", (K, N),
                {k: t.data[k] for k in ("qs", "hmask", "scales", "d")}),
        dtype=jnp.float32)
    idx = t.data["oidx"].astype(jnp.int32).reshape(nsb, no, N)
    vals = t.data["ovals"].astype(jnp.float32).reshape(nsb, no, N)
    w = base.reshape(nsb, 256, N)
    rows = jax.lax.broadcasted_iota(jnp.int32, (nsb, 256, N), 1)
    # scatter-by-comparison: VPU-friendly inside the Pallas kernel (no
    # gathers); top_k indices are distinct so `where` never double-writes
    for j in range(no):
        sel = rows == idx[:, j][:, None, :]
        w = jnp.where(sel, vals[:, j][:, None, :], w)
    return w.reshape(K, N).astype(dtype)


# ---------------------------------------------------------------------------
# Q4_K / Q5_K (affine, 32-blocks, 6-bit scales+mins)
# ---------------------------------------------------------------------------

def _quantize_q45_common(w, qmax, with_high):
    K, N = w.shape
    assert K % 256 == 0, K
    nsb = K // 256
    x = w.astype(jnp.float32).reshape(nsb, 8, 32, N)
    bmax = x.max(axis=2)
    bmin = x.min(axis=2)
    min_f = jnp.maximum(0.0, -bmin)
    scale_f = jnp.maximum(bmax + min_f, 0.0) / qmax
    d = scale_f.max(axis=1) / 63.0
    dmin = min_f.max(axis=1) / 63.0
    sc_q = jnp.clip(_nearest(scale_f * _safe_inv(d)[:, None]), 0, 63)
    m_q = jnp.clip(_nearest(min_f * _safe_inv(dmin)[:, None]), 0, 63)
    eff_sc = d[:, None] * sc_q
    eff_mn = dmin[:, None] * m_q
    q = jnp.clip(_nearest((x + eff_mn[:, :, None]) * _safe_inv(eff_sc)[:, :, None]),
                 0, qmax).astype(jnp.uint8).reshape(K, N)
    data = dict(
        qs=slab_pack(q & 15, 4, 256),
        scales=sc_q.astype(jnp.uint8).reshape(K // 32, N),
        mins=m_q.astype(jnp.uint8).reshape(K // 32, N),
        d=d.astype(jnp.float16), dmin=dmin.astype(jnp.float16))
    if with_high:
        data["qh"] = slab_pack(q >> 4, 1, 256)
    return data, (K, N)


def quantize_q4_k(w):
    data, shape = _quantize_q45_common(w, 15, with_high=False)
    return QTensor("q4_k", shape, data)


def quantize_q5_k(w):
    data, shape = _quantize_q45_common(w, 31, with_high=True)
    return QTensor("q5_k", shape, data)


def _dequantize_q45_common(t, dtype):
    K, N = t.shape
    nsb = K // 256
    q = slab_unpack(t.data["qs"], 4, 256)
    if "qh" in t.data:
        q = q + (slab_unpack(t.data["qh"], 1, 256) << 4)
    q = q.astype(jnp.float32).reshape(nsb, 8, 32, N)
    sc = t.data["scales"].astype(jnp.float32).reshape(nsb, 8, N)
    mn = t.data["mins"].astype(jnp.float32).reshape(nsb, 8, N)
    d = t.data["d"].astype(jnp.float32)[:, None]
    dmin = t.data["dmin"].astype(jnp.float32)[:, None]
    w = (d * sc)[:, :, None] * q - (dmin * mn)[:, :, None]
    return w.reshape(K, N).astype(dtype)


def dequantize_q4_k(t, dtype=jnp.float32):
    return _dequantize_q45_common(t, dtype)


def dequantize_q5_k(t, dtype=jnp.float32):
    return _dequantize_q45_common(t, dtype)


# ---------------------------------------------------------------------------
# Q6_K (symmetric, 16-blocks, int8 block scales)
# ---------------------------------------------------------------------------

def quantize_q6_k(w: jnp.ndarray) -> QTensor:
    K, N = w.shape
    assert K % 256 == 0, K
    nsb = K // 256
    x = w.astype(jnp.float32).reshape(nsb, 16, 16, N)
    amax = jnp.abs(x).max(axis=2)
    scale_f = amax / 32.0
    d = scale_f.max(axis=1) / 127.0
    sc_q = jnp.clip(_nearest(scale_f * _safe_inv(d)[:, None]), -128, 127)
    eff = d[:, None] * sc_q
    q = jnp.clip(_nearest(x * _safe_inv(eff)[:, :, None]), -32, 31) + 32
    q = q.astype(jnp.uint8).reshape(K, N)                    # [0, 63]
    return QTensor("q6_k", (K, N), dict(
        ql=slab_pack(q & 15, 4, 256),
        qh=slab_pack(q >> 4, 2, 256),
        scales=sc_q.astype(jnp.int8).reshape(K // 16, N),
        d=d.astype(jnp.float16)))


def dequantize_q6_k(t: QTensor, dtype=jnp.float32) -> jnp.ndarray:
    K, N = t.shape
    nsb = K // 256
    q = (slab_unpack(t.data["ql"], 4, 256)
         + (slab_unpack(t.data["qh"], 2, 256) << 4)).astype(jnp.float32) - 32.0
    q = q.reshape(nsb, 16, 16, N)
    sc = t.data["scales"].astype(jnp.float32).reshape(nsb, 16, N)
    d = t.data["d"].astype(jnp.float32)[:, None]
    w = (d * sc)[:, :, None] * q
    return w.reshape(K, N).astype(dtype)


# ---------------------------------------------------------------------------
# Q4_0 (classic 32-block symmetric 4-bit, fp16 scale)
# ---------------------------------------------------------------------------

def quantize_q4_0(w: jnp.ndarray) -> QTensor:
    """llama.cpp sign convention: d = (signed abs-max element) / -8, so
    the extreme value maps exactly to code 0 (-8 on the grid) and the
    grid's asymmetric [-8, 7] range points toward it."""
    K, N = w.shape
    assert K % 32 == 0, K
    x = w.astype(jnp.float32).reshape(K // 32, 32, N)
    imax = jnp.argmax(jnp.abs(x), axis=1)                    # (K//32, N)
    mval = jnp.take_along_axis(x, imax[:, None], axis=1)[:, 0]
    d = mval / -8.0
    inv = jnp.where(d != 0, 1.0 / jnp.where(d != 0, d, 1.0), 0.0)
    q = jnp.clip(_nearest(x * inv[:, None]) + 8, 0, 15)
    q = q.astype(jnp.uint8).reshape(K, N)
    return QTensor("q4_0", (K, N), dict(
        qs=slab_pack(q, 4, 32), d=d.astype(jnp.float16)))


def dequantize_q4_0(t: QTensor, dtype=jnp.float32) -> jnp.ndarray:
    K, N = t.shape
    q = slab_unpack(t.data["qs"], 4, 32).astype(jnp.float32) - 8.0
    d = t.data["d"].astype(jnp.float32)[:, None]             # (K//32, 1, N)
    w = d * q.reshape(K // 32, 32, N)
    return w.reshape(K, N).astype(dtype)


# ---------------------------------------------------------------------------
# Q8_0 (fallback for K % 256 != 0; blocks of 32, fp16 scale)
# ---------------------------------------------------------------------------

def quantize_q8_0(w: jnp.ndarray) -> QTensor:
    K, N = w.shape
    assert K % 32 == 0, K
    x = w.astype(jnp.float32).reshape(K // 32, 32, N)
    amax = jnp.abs(x).max(axis=1)                            # (K//32, N)
    d = amax / 127.0
    q = jnp.clip(_nearest(x * _safe_inv(d)[:, None]), -127, 127)
    return QTensor("q8_0", (K, N), dict(
        qs=q.astype(jnp.int8).reshape(K, N), d=d.astype(jnp.float16)))


def dequantize_q8_0(t: QTensor, dtype=jnp.float32) -> jnp.ndarray:
    K, N = t.shape
    q = t.data["qs"].astype(jnp.float32).reshape(K // 32, 32, N)
    d = t.data["d"].astype(jnp.float32)[:, None]
    return (d * q).reshape(K, N).astype(dtype)


# ---------------------------------------------------------------------------
# Q8_K activations: x (..., K) -> dict(qs int8, d f32, bsums int16)
# ---------------------------------------------------------------------------

def quantize_q8_k(x: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    K = x.shape[-1]
    assert K % 256 == 0, K
    lead = x.shape[:-1]
    xf = x.astype(jnp.float32).reshape(lead + (K // 256, 256))
    amax = jnp.abs(xf).max(axis=-1)                          # (..., nsb)
    d = amax / 127.0
    q = jnp.clip(_nearest(xf * _safe_inv(d)[..., None]), -127, 127)
    q = q.astype(jnp.int8)
    bsums = q.astype(jnp.int32).reshape(lead + (K // 256, 16, 16)).sum(-1)
    return dict(qs=q.reshape(lead + (K,)),
                d=d,
                bsums=bsums.astype(jnp.int16).reshape(lead + (K // 16,)))


def dequantize_q8_k(qx: Dict[str, jnp.ndarray], dtype=jnp.float32) -> jnp.ndarray:
    qs = qx["qs"]
    K = qs.shape[-1]
    lead = qs.shape[:-1]
    q = qs.astype(jnp.float32).reshape(lead + (K // 256, 256))
    x = q * qx["d"][..., None]
    return x.reshape(lead + (K,)).astype(dtype)


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------

_QUANTIZE = {
    "q2_k": quantize_q2_k, "q3_k": quantize_q3_k,
    "q3_k_o": quantize_q3_k_o, "q4_0": quantize_q4_0,
    "q4_k": quantize_q4_k, "q5_k": quantize_q5_k, "q6_k": quantize_q6_k,
    "q8_0": quantize_q8_0,
}
_DEQUANTIZE = {
    "q2_k": dequantize_q2_k, "q3_k": dequantize_q3_k,
    "q3_k_o": dequantize_q3_k_o,
    "q4_0": dequantize_q4_0, "q4_k": dequantize_q4_k,
    "q5_k": dequantize_q5_k, "q6_k": dequantize_q6_k,
    "q8_0": dequantize_q8_0,
}


def quantize(variant: str, w: jnp.ndarray) -> QTensor:
    """Quantize weight matrix w (K, N) along K. Applies the llama.cpp
    fallback rule (K % 256 != 0 -> q8_0)."""
    variant = F.pick_fallback(variant, w.shape[0])
    return _QUANTIZE[variant](w)


def dequantize(t: QTensor, dtype=jnp.float32) -> jnp.ndarray:
    return _DEQUANTIZE[t.variant](t, dtype=dtype)


def qtensor_spec(variant: str, K: int, N: int) -> QTensor:
    """ShapeDtypeStruct stand-in QTensor (for dry-run lowering)."""
    variant = F.pick_fallback(variant, K)
    fmt = F.get_format(variant)
    data = {name: jax.ShapeDtypeStruct(shape, jnp.dtype(dt))
            for name, (shape, dt) in fmt.array_shapes(K, N).items()}
    return QTensor(variant, (K, N), data)
