"""Per-tensor mixed-quantization policies (the paper's Fig. 1 motivation).

llama.cpp quantizes models with *mixed* BFP variants across tensors -- every
model in the paper contains both Q2_K and Q3_K MatMul layers (Table III).
A ``QuantPolicy`` is an ordered list of (glob-ish pattern -> variant) rules
applied to parameter paths (e.g. ``layers/attn/wv``); first match wins.

Presets below reproduce the paper's Table III layer counts exactly and its
model sizes to within ~2% (validated in benchmarks/table3 + tests):

  GPT2        25x Q2_K, 24x Q3_K,  163M params,  77 MB
  TinyLlama   45x Q2_K, 110x Q3_K, 1.1B params, 460 MB
  MobileLLaMA 49x Q2_K, 120x Q3_K, 1.4B params, 560 MB
"""
from __future__ import annotations

import dataclasses
import fnmatch
from typing import List, Optional, Sequence, Tuple

from repro.core import formats as F

# tensors smaller than this along K (or 1-D tensors) stay unquantized,
# mirroring llama.cpp (norm weights / biases / tiny projections stay f32)
MIN_QUANT_K = 256
MIN_QUANT_N = 32


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    name: str
    rules: Tuple[Tuple[str, str], ...]   # (pattern, variant|"none")
    default: str = "q3_k"

    def variant_for(self, path: str, K: int, N: int) -> Optional[str]:
        """Variant for parameter at `path` with logical shape (K, N); None
        means keep unquantized."""
        # Small-K tensors stay f32 (module rule above), and no packed
        # variant exists unless K divides into 32-wide blocks. Either
        # failure means "keep unquantized" -- never raise: one odd-shaped
        # tensor must not abort quantize_params for the whole model.
        if K < MIN_QUANT_K or K % 32 != 0:
            return None
        if N < MIN_QUANT_N:
            return None
        chosen = self.default
        for pat, variant in self.rules:
            if fnmatch.fnmatch(path, pat):
                chosen = variant
                break
        if chosen == "none":
            return None
        return F.pick_fallback(chosen, K)


def make_policy(name: str, rules: Sequence[Tuple[str, str]],
                default: str = "q3_k") -> QuantPolicy:
    return QuantPolicy(name, tuple(rules), default)


def pure(variant: str) -> QuantPolicy:
    """Everything at one variant (embeddings/head included)."""
    return QuantPolicy(f"pure_{variant}", (), default=variant)


# --------------------------------------------------------------------------
# Paper-model presets (Table III reproduction).
#
# llama-family (TinyLlama / MobileLLaMA): per block 7 matmuls
#   {wq, wk, wv, wo, w_gate, w_up, w_down} + lm_head:
#   Q2_K on {wk, wv, lm_head} -> 2*L + 1 layers; Q3_K on the other 5 -> 5*L.
#   token embedding Q2_K (not a MatMul layer; uncounted, as in the paper).
# --------------------------------------------------------------------------

PAPER_LLAMA_MIX = make_policy("paper_llama_mix", (
    ("*attn/wk", "q2_k"),
    ("*attn/wv", "q2_k"),
    ("*lm_head*", "q2_k"),
    ("*embed*", "q2_k"),
), default="q3_k")

# GPT2: per block 4 matmuls {c_attn, c_proj, mlp_fc, mlp_proj} + lm_head:
#   Q2_K on {c_attn, mlp_fc, lm_head} -> 2*L + 1; Q3_K on the rest -> 2*L.
#   wte at Q6_K, wpe kept fp16 (llama.cpp keeps it high precision).
PAPER_GPT2_MIX = make_policy("paper_gpt2_mix", (
    ("*attn/c_attn", "q2_k"),
    ("*mlp/c_fc", "q2_k"),
    ("*lm_head*", "q2_k"),
    ("*wte*", "q6_k"),
    ("*wpe*", "none"),
), default="q3_k")

# Default serving policy for the assigned architectures: the paper's two
# native variants, distributed llama.cpp-style (K/V low-bit, rest Q3_K).
DEFAULT_SERVE_MIX = make_policy("default_serve_mix", (
    ("*attn/wk", "q2_k"),
    ("*attn/wv", "q2_k"),
    ("*lm_head*", "q2_k"),
    ("*embed*", "q2_k"),
    # SSM internals: conv/dt/A/D tensors are tiny -> unquantized
    ("*ssm/dt*", "none"),
    ("*ssm/A*", "none"),
    ("*ssm/D*", "none"),
    ("*conv*", "none"),
    ("*norm*", "none"),
), default="q3_k")

# Beyond-paper policy exercising the extended variant set (paper future work)
EXTENDED_MIX = make_policy("extended_mix", (
    ("*attn/wv", "q4_k"),
    ("*mlp/w_down", "q4_k"),
    ("*lm_head*", "q6_k"),
    ("*embed*", "q4_k"),
    ("*norm*", "none"),
), default="q3_k")

POLICIES = {
    p.name: p for p in (
        PAPER_LLAMA_MIX, PAPER_GPT2_MIX, DEFAULT_SERVE_MIX, EXTENDED_MIX,
        pure("q2_k"), pure("q3_k"), pure("q4_0"), pure("q4_k"),
        pure("q6_k"))
}


def get_policy(name: str) -> QuantPolicy:
    return POLICIES[name]


# --------------------------------------------------------------------------
# searched-policy serialization (launch/policy_search.py writes these;
# ``serve --policy auto`` loads them back)
# --------------------------------------------------------------------------

def policy_to_dict(policy: QuantPolicy) -> dict:
    """JSON-ready form: {"name", "rules": [[pattern, variant], ...],
    "default"}.  Searched policies use exact paths as patterns (fnmatch
    treats a glob with no metacharacters as an exact match), so the same
    schema covers hand-written and searched policies."""
    return {"name": policy.name,
            "rules": [list(r) for r in policy.rules],
            "default": policy.default}


def policy_from_dict(d: dict) -> QuantPolicy:
    rules = tuple((str(p), str(v)) for p, v in d.get("rules", ()))
    for _, v in rules:
        if v != "none" and v not in F.FORMATS:
            raise ValueError(f"unknown variant {v!r} in policy rules")
    default = str(d.get("default", "q3_k"))
    if default != "none" and default not in F.FORMATS:
        raise ValueError(f"unknown default variant {default!r}")
    return QuantPolicy(str(d.get("name", "searched")), rules, default)


def save_policy(policy: QuantPolicy, path) -> None:
    import json
    with open(path, "w") as f:
        json.dump(policy_to_dict(policy), f, indent=2, sort_keys=True)
        f.write("\n")


def load_policy(path) -> QuantPolicy:
    import json
    with open(path) as f:
        return policy_from_dict(json.load(f))


# --------------------------------------------------------------------------
# accounting helpers (Fig. 1 / Table III reproduction)
# --------------------------------------------------------------------------

def summarize(policy: QuantPolicy,
              matmuls: Sequence[Tuple[str, int, int]],
              extra_f16: Sequence[Tuple[str, int]] = ()):
    """Given MatMul tensors [(path, K, N)] and non-matmul fp16 tensors
    [(path, numel)], return per-variant layer counts, parameter counts and
    total size in bytes (both our-layout and gguf-faithful bits).
    """
    counts, params = {}, {}
    size_ours = 0.0
    size_gguf = 0.0
    for path, K, N in matmuls:
        v = policy.variant_for(path, K, N)
        key = v or "f16"
        counts[key] = counts.get(key, 0) + 1
        params[key] = params.get(key, 0) + K * N
        if v is None:
            size_ours += K * N * 2
            size_gguf += K * N * 2
        else:
            fmt = F.get_format(v)
            size_ours += K * N * fmt.bits_per_weight / 8.0
            size_gguf += K * N * fmt.bits_per_weight_gguf / 8.0
    for path, numel in extra_f16:
        size_ours += numel * 2
        size_gguf += numel * 2
    return dict(counts=counts, params=params,
                size_bytes=int(size_ours), size_bytes_gguf=int(size_gguf))
