"""Force N fake host devices for CPU tensor-parallel testing.

XLA only reads ``--xla_force_host_platform_device_count`` when the
backend initializes, so this must run BEFORE the process's first
``import jax``. This module deliberately imports nothing but ``os`` --
entry points import it first, call :func:`force_host_devices`, and only
then import jax (see launch/serve.py and benchmarks/e2e_serve.py).
"""
import os

_FLAG = "--xla_force_host_platform_device_count"


def force_host_devices(n) -> None:
    """Append ``--xla_force_host_platform_device_count=n`` to XLA_FLAGS.

    ``n`` may be an int or a numeric string (e.g. straight from the
    REPRO_FORCE_HOST_DEVICES env var); falsy values AND 0 (the natural
    "disabled" spelling) are a no-op, anything non-numeric is a clear
    error instead of a raw int() traceback. An already-forced count is
    left alone so nesting entry points (a test runner exporting
    XLA_FLAGS around a launcher that also asks) never stacks duplicate
    flags."""
    if n is None or n == "":
        return
    try:
        count = int(n)
    except (TypeError, ValueError):
        raise ValueError(
            f"force_host_devices needs an integer device count, got {n!r}")
    if count <= 0:
        return
    cur = os.environ.get("XLA_FLAGS", "")
    if any(tok.startswith(_FLAG) for tok in cur.split()):
        return
    os.environ["XLA_FLAGS"] = f"{cur} {_FLAG}={count}".strip()
