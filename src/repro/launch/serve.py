"""Serving launcher: quantize a model with a mixed BFP policy and serve
batched requests -- the llama-cli analogue of the paper's evaluation.

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --reduced --policy paper_llama_mix --tokens 32
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import get_arch
from repro.core.policy import get_policy
from repro.core.qlinear import quantize_params, quantized_param_bytes
from repro.models import transformer as T
from repro.serving.engine import Engine, ServeConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--policy", default="default_serve_mix")
    ap.add_argument("--no-quant", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=6)   # paper: 6 tokens
    ap.add_argument("--tokens", type=int, default=10)      # paper: 10 tokens
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch, reduced=args.reduced)
    if not cfg.embed_input:
        raise SystemExit(f"{args.arch} has a stub modality frontend; "
                         "serve driver needs token inputs")
    key = jax.random.PRNGKey(args.seed)
    params = T.init_params(cfg, key)
    if args.no_quant:
        qp = params
        print("serving UNQUANTIZED (baseline)")
    else:
        t0 = time.time()
        qp, report = quantize_params(params, get_policy(args.policy))
        counts = {}
        for v in report.values():
            if v:
                counts[v] = counts.get(v, 0) + 1
        sizes = quantized_param_bytes(qp)
        print(f"quantized with policy {args.policy} in {time.time()-t0:.1f}s:"
              f" {counts}; packed {sizes['packed']/2**20:.1f} MiB + residual "
              f"{sizes['unpacked']/2**20:.1f} MiB")

    engine = Engine(cfg, qp, ServeConfig(max_new_tokens=args.tokens,
                                         temperature=args.temperature))
    rng = np.random.default_rng(args.seed)
    prompts = [list(rng.integers(0, cfg.vocab_size, args.prompt_len))
               for _ in range(args.batch)]
    outs = engine.generate(prompts)
    for i, o in enumerate(outs[:4]):
        print(f"req {i}: {o}")
    s = engine.stats
    print(f"prefill {s['prefill_s']:.3f}s, decode {s['decode_s']:.3f}s, "
          f"{s['tok_per_s']:.1f} tok/s ({s['tokens']} tokens)")


if __name__ == "__main__":
    main()
