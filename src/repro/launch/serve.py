"""Serving launcher: quantize a model with a mixed BFP policy and serve a
queue of requests through the continuous-batching engine -- the llama-cli
analogue of the paper's evaluation.

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --reduced --policy paper_llama_mix --tokens 32 --requests 8 --slots 4

Disaggregated serving (``--disagg --prefill-workers N --decode-workers
M``) splits the engine into a prefill tier and a decode tier behind a
KV-aware radix router; prompts route to the prefill worker with maximal
prefix-cache overlap and their finished KV pages migrate to a decode
worker (routed output stays token-identical to one monolithic engine).

Tensor-parallel serving (``--tp N``) runs every jitted engine program
through shard_map over a ("model",) mesh; on a CPU-only box add
``--force-host-devices N`` (or XLA_FLAGS=--xla_force_host_platform_
device_count=N) to split the host into N fake devices for testing.
"""
from __future__ import annotations

import argparse
import os
import sys
import time


from repro.launch.hostdev import force_host_devices


def _forced_host_devices():
    """--force-host-devices must take effect BEFORE jax initializes its
    backends, so peek argv (and the env) ahead of the argparse run.
    Prefix matching mirrors argparse's abbreviation rule (no other flag
    starts with --force); non-numeric values are left for argparse's own
    type=int error instead of crashing pre-init."""
    for i, a in enumerate(sys.argv):
        if not a.startswith("--force"):
            continue
        if "=" in a:
            val = a.split("=", 1)[1]
        elif i + 1 < len(sys.argv):
            val = sys.argv[i + 1]
        else:
            continue
        return val if val.lstrip("-").isdigit() else None
    return os.environ.get("REPRO_FORCE_HOST_DEVICES")


force_host_devices(_forced_host_devices())

import jax
import numpy as np

from repro.configs.base import get_arch
from repro.core.policy import get_policy
from repro.core.qlinear import quantize_params, quantized_param_bytes
from repro.models import transformer as T
from repro.serving.disagg import DisaggEngine
from repro.serving.engine import Engine, ServeConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--policy", default="default_serve_mix",
                    help="named policy from core.policy.POLICIES, or "
                         "'auto' to load/search a calibrated per-layer "
                         "assignment (see --policy-json)")
    ap.add_argument("--policy-json", default=None,
                    help="searched-policy JSON for --policy auto; if the "
                         "file exists it is loaded, otherwise the search "
                         "runs and writes it (default: "
                         "results/auto_<arch>.json)")
    ap.add_argument("--search-rounds", type=int, default=2,
                    help="refinement rounds for the --policy auto search")
    ap.add_argument("--no-quant", action="store_true")
    ap.add_argument("--requests", type=int, default=4,
                    help="queue depth (may exceed --slots)")
    ap.add_argument("--slots", type=int, default=4,
                    help="concurrent batch slots")
    ap.add_argument("--chunk", type=int, default=0,
                    help="decode steps per host sync (0 = --tokens)")
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--prefill-batch", type=int, default=8,
                    help="max requests per batched prefill group")
    ap.add_argument("--prefill-chunk", type=int, default=64,
                    help="tokens per prefill chunk (long prompts stream "
                         "through one fixed-shape compiled program)")
    ap.add_argument("--prefill-bucket", type=int, default=16,
                    help="prompt pad granularity (compilations are "
                         "O(#buckets), not O(#prompt lengths))")
    ap.add_argument("--prompt-len", type=int, default=6)   # paper: 6 tokens
    ap.add_argument("--tokens", type=int, default=10)      # paper: 10 tokens
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--eos-id", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--stream", action="store_true",
                    help="print tokens as they are emitted")
    ap.add_argument("--drafter", default=None,
                    choices=("ngram", "self"),
                    help="enable speculative decoding with this drafter "
                         "(greedy output stays bit-identical to plain "
                         "decode)")
    ap.add_argument("--draft-k", type=int, default=4,
                    help="drafted tokens per verify round")
    ap.add_argument("--draft-layers", type=int, default=2,
                    help="self-drafter: how many leading target-model "
                         "layers draft (same quantized weights)")
    ap.add_argument("--draft-ngram", type=int, default=2,
                    help="ngram drafter: match gram length")
    ap.add_argument("--draft-verify", default="scan",
                    choices=("scan", "batched"),
                    help="verify datapath: 'scan' is bit-exact vs plain "
                         "decode, 'batched' scores the whole draft block "
                         "in one masked forward")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="enable the prefix cache: admission reuses the "
                         "longest cached token prefix and prefills only "
                         "the suffix (output stays token-identical to a "
                         "cold prefill). KV families page the ring; "
                         "recurrent families (ssm/hybrid) checkpoint "
                         "conv/SSM state at prefill-chunk boundaries")
    ap.add_argument("--prefix-page", type=int, default=16,
                    help="positions per KV page (clamped to a divisor of "
                         "the ring length; recurrent families pin the "
                         "page to --prefill-chunk instead)")
    ap.add_argument("--prefix-bytes", type=int, default=64 << 20,
                    help="device byte budget for the page pool (LRU "
                         "eviction of zero-ref pages beyond it)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="prepend this many shared system-prompt tokens "
                         "to every request (the prefix-cache workload)")
    ap.add_argument("--disagg", action="store_true",
                    help="disaggregated serving: split into prefill-"
                         "worker and decode-worker engine instances "
                         "behind a KV-aware radix router; finished "
                         "prefill KV pages migrate to the decode tier "
                         "(routed output stays token-identical to one "
                         "monolithic engine)")
    ap.add_argument("--prefill-workers", type=int, default=1,
                    help="prefill-tier engine instances (--disagg)")
    ap.add_argument("--decode-workers", type=int, default=1,
                    help="decode-tier engine instances (--disagg)")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel degree: run the engine's jitted "
                         "programs via shard_map over a ('model',) mesh "
                         "of this many devices (lane-only sharding; "
                         "greedy output stays token-identical to --tp 1)")
    ap.add_argument("--tp-matmul", default="padded",
                    choices=("padded", "sliced", "sliced_row"),
                    help="TP projection datapath: 'padded' keeps the "
                         "single-device gemm shape per shard (bit-exact "
                         "parity; weights/KV still sharded), 'sliced' "
                         "runs true lane-sliced gemms (1/N FLOPs per "
                         "shard, equal to within an f32 ulp), "
                         "'sliced_row' adds row-parallel o-/down-"
                         "projections (half the collectives per layer; "
                         "equal to within ~a few activation-dtype ulps)")
    ap.add_argument("--no-tp-ep", dest="tp_ep", action="store_false",
                    help="disable expert parallelism under --tp for MoE "
                         "archs (by default expert stacks shard over the "
                         "model axis when n_experts divides the mesh; "
                         "outputs are bit-identical either way)")
    ap.add_argument("--force-host-devices", type=int, default=None,
                    help="split the host platform into this many fake "
                         "devices for CPU TP testing (applied before "
                         "jax init; also honored from the "
                         "REPRO_FORCE_HOST_DEVICES env var)")
    ap.add_argument("--http", action="store_true",
                    help="serve an OpenAI-compatible HTTP front-end "
                         "(POST /v1/completions with a token-id prompt, "
                         "SSE streaming, per-request priority/deadline_s/"
                         "timeout_s; GET /health, /v1/models, /stats) "
                         "instead of draining a synthetic queue")
    ap.add_argument("--host", default="127.0.0.1",
                    help="--http listen address")
    ap.add_argument("--port", type=int, default=8000,
                    help="--http listen port (0 = ephemeral)")
    ap.add_argument("--max-queue", type=int, default=0,
                    help="bounded admission queue: submit()/HTTP requests "
                         "beyond this depth are rejected with a "
                         "structured reason (HTTP 429); 0 = unbounded")
    ap.add_argument("--preempt", action="store_true",
                    help="allow a strictly-higher-priority queued request "
                         "to preempt the lowest-priority running slot "
                         "(the victim keeps its streamed tokens)")
    ap.add_argument("--request-timeout", type=float, default=120.0,
                    help="--http default per-request wall ceiling in "
                         "seconds (overridable per request via "
                         "timeout_s)")
    args = ap.parse_args()

    cfg = get_arch(args.arch, reduced=args.reduced)
    if not cfg.embed_input:
        raise SystemExit(f"{args.arch} has a stub modality frontend; "
                         "serve driver needs token inputs")
    key = jax.random.PRNGKey(args.seed)
    params = T.init_params(cfg, key)
    if args.no_quant:
        qp = params
        print("serving UNQUANTIZED (baseline)")
    else:
        t0 = time.time()
        calib = None
        if args.policy == "auto":
            from repro.core import calibrate as CAL
            from repro.core.policy import load_policy
            from repro.launch.policy_search import (search_policy,
                                                    save_searched_policy)
            path = args.policy_json or f"results/auto_{args.arch}.json"
            if os.path.exists(path):
                policy = load_policy(path)
                print(f"loaded searched policy from {path}")
                if any(v == "q3_k_o" for _, v in policy.rules):
                    # q3_k_o weighs outliers by activation absmax; redo
                    # the (cheap, deterministic) calibration pass
                    stats = CAL.run_calibration(params, cfg)
                    calib = stats.for_paths(
                        [p for p, _ in policy.rules])
            else:
                policy, info = search_policy(
                    cfg, params, arch=args.arch,
                    rounds=args.search_rounds)
                save_searched_policy(path, policy, info)
                print(f"searched policy written to {path}")
                # pack with the same activation stats the search's
                # verified evals used -- q3_k_o outlier selection must
                # match the assignment the search validated, not fall
                # back to weight-magnitude-only selection
                calib = info["stats"].for_paths(
                    [p for p, _ in policy.rules])
        else:
            policy = get_policy(args.policy)
        qp, report = quantize_params(params, policy, calib=calib)
        counts = {}
        for v in report.values():
            if v:
                counts[v] = counts.get(v, 0) + 1
        sizes = quantized_param_bytes(qp)
        print(f"quantized with policy {args.policy} in {time.time()-t0:.1f}s:"
              f" {counts}; packed {sizes['packed']/2**20:.1f} MiB + residual "
              f"{sizes['unpacked']/2**20:.1f} MiB")

    decode_chunk = args.chunk or args.tokens
    if args.drafter is not None:
        decode_chunk = max(decode_chunk, args.draft_k + 1)
    if args.tp > 1:
        print(f"tensor-parallel: tp={args.tp} ({args.tp_matmul} matmul) "
              f"over {len(jax.devices())} visible devices")
    scfg = ServeConfig(
        max_new_tokens=args.tokens, temperature=args.temperature,
        eos_id=args.eos_id, cache_len=args.cache_len, seed=args.seed,
        max_slots=args.slots, decode_chunk=decode_chunk,
        prefill_batch=args.prefill_batch, prefill_chunk=args.prefill_chunk,
        prefill_bucket=args.prefill_bucket,
        drafter=args.drafter, draft_k=args.draft_k,
        draft_layers=args.draft_layers, draft_ngram=args.draft_ngram,
        draft_verify=args.draft_verify,
        prefix_cache=args.prefix_cache, prefix_page=args.prefix_page,
        prefix_bytes=args.prefix_bytes,
        max_queue=args.max_queue, preempt=args.preempt,
        tp=args.tp, tp_matmul=args.tp_matmul, tp_ep=args.tp_ep)
    if args.disagg:
        print(f"disaggregated: {args.prefill_workers} prefill + "
              f"{args.decode_workers} decode worker(s), KV-aware router")
        engine = DisaggEngine(cfg, qp, scfg,
                              prefill_workers=args.prefill_workers,
                              decode_workers=args.decode_workers)
    else:
        engine = Engine(cfg, qp, scfg)

    if args.http:
        from repro.serving.frontend import FrontendConfig, serve_forever
        serve_forever(engine, FrontendConfig(
            host=args.host, port=args.port, model_name=args.arch,
            request_timeout_s=args.request_timeout,
            max_tokens_default=args.tokens))
        return

    on_token = None
    if args.stream:
        on_token = lambda rid, tok: print(f"  [req {rid}] += {tok}")
    rng = np.random.default_rng(args.seed)
    shared = list(rng.integers(0, cfg.vocab_size, args.shared_prefix))
    ids = [engine.submit(shared + list(rng.integers(0, cfg.vocab_size,
                                                    args.prompt_len)),
                         on_token=on_token)
           for _ in range(args.requests)]
    results = engine.run()
    for rid in ids[:4]:
        print(f"req {rid}: {results[rid]}")

    # rates print 0 on empty denominators (a queue whose every request is
    # cancelled from its on_token callback never decodes; spec_rounds may
    # be 0): the engine's _finalize_stats carries the same guards, and
    # every ratio derived HERE goes through _rate too
    _rate = lambda n, d: n / d if d else 0.0
    s = engine.stats
    spec = ""
    if args.drafter is not None:
        spec = (f", spec accept {s['accept_rate']:.0%} "
                f"({s['draft_accepted']:.0f}/{s['draft_tokens']:.0f} "
                f"drafts over {s['spec_rounds']:.0f} rounds)")
    disagg = ""
    if args.disagg:
        rt = s["router"]
        disagg = (f", router: {rt['migrated_pages_total']} pages migrated, "
                  f"prefill hit rates {rt['prefill_hit_rate']}, "
                  f"{rt['direct_decode']} direct-to-decode, peak depths "
                  f"P{rt['prefill_peak_depth']}/D{rt['decode_peak_depth']}")
    prefix = ""
    if args.prefix_cache:
        prefix = (f", prefix hits {_rate(s['prefix_hits'], s['admissions']):.0%} "
                  f"({s['prefix_tokens_reused']:.0f} tokens reused, "
                  f"{s['prefix_evictions']:.0f} evictions)")
    print(f"prefill {s['prefill_s']:.3f}s "
          f"({s['prefill_tok_per_s']:.1f} tok/s, "
          f"{s['prefill_groups']:.0f} fused groups, "
          f"mean ttft {s['ttft_s'] * 1e3:.1f}ms), "
          f"decode {s['decode_s']:.3f}s, "
          f"{s['tok_per_s']:.1f} tok/s ({s['tokens']} tokens, "
          f"{s['host_syncs']} host syncs / {s['requests']} requests, "
          f"{_rate(s['host_syncs'], s['requests']):.1f}/req, "
          f"{s['chunks']} fused chunks{spec}{prefix}{disagg})")


if __name__ == "__main__":
    main()
