"""Compiled-artifact analysis: collective-byte parsing + roofline terms.

This container has no TPU, so §Roofline derives the three terms from the
dry-run's compiled artifact:

  compute term    = HLO_FLOPs_per_chip / peak_FLOPs            (197 TF bf16)
  memory term     = HLO_bytes_per_chip / HBM_bw                (819 GB/s)
  collective term = collective_bytes_per_chip / link_bw        (~50 GB/s)

``cost_analysis()`` provides FLOPs/bytes of the *partitioned per-device*
module; collective bytes are parsed from the post-SPMD HLO text (XLA does
not report them in cost_analysis). For collectives we count the *result*
buffer bytes of each all-gather / all-reduce / reduce-scatter / all-to-all
/ collective-permute (async '-start' forms counted once, '-done' skipped)
-- a standard proxy for bytes moved per chip.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, Optional

# TPU v5e per-chip constants (assignment brief)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
LINK_BW = 50e9               # bytes/s per ICI link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def shape_bytes(type_str: str) -> int:
    total = 0
    for m in re.finditer(r"(\w+?)\[([\d,]*)\]", type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_LINE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<name>[\w.\-]+)\s*=\s*(?P<type>.+?)\s+"
    r"(?P<op>[\w\-]+)\((?P<args>.*?)\)")

_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _instruction_table(hlo_text: str):
    table = {}
    for line in hlo_text.splitlines():
        m = _LINE_RE.match(line)
        if m:
            table[m.group("name")] = (m.group("type"), m.group("op"),
                                      _OPERAND_RE.findall(m.group("args")))
    return table


def _is_promoted_bf16(name: str, table) -> bool:
    """XLA:CPU float-normalization promotes bf16 dots (and the collectives
    that consume them) to f32 -- on the TPU target these stay bf16. Detect
    the pattern: producer is a dot/fusion whose operands are converts from
    bf16 (names carry 'convert')."""
    entry = table.get(name)
    if entry is None:
        return False
    _, op, operands = entry
    if "convert" in name:
        return True
    if op in ("dot", "fusion", "add", "convert"):
        return any("convert" in o for o in operands)
    return False


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-kind result bytes of collective ops in (post-SPMD) HLO text.

    'total' counts raw HLO bytes; 'total_corrected' halves f32 collectives
    that are CPU-promotions of logically-bf16 values (see
    _is_promoted_bf16) -- the TPU-faithful number used for §Roofline.
    """
    table = _instruction_table(hlo_text)
    out: Dict[str, int] = {}
    corrected = 0
    for line in hlo_text.splitlines():
        m = _LINE_RE.match(line)
        if not m:
            continue
        op = m.group("op")
        base = None
        for c in _COLLECTIVES:
            if op == c or op == c + "-start":
                base = c
                break
        if base is None:
            continue
        b = shape_bytes(m.group("type"))
        out[base] = out.get(base, 0) + b
        if m.group("type").lstrip("(").startswith("f32"):
            ops_ = _OPERAND_RE.findall(m.group("args"))
            if ops_ and any(_is_promoted_bf16(o, table) for o in ops_):
                b = b // 2
        corrected += b
    out["total"] = sum(v for k, v in out.items() if k != "total")
    out["total_corrected"] = corrected
    return out


def hlo_collective_summary(hlo_text: str, top: int = 12):
    """The largest collective ops (for perf iteration)."""
    rows = []
    for line in hlo_text.splitlines():
        m = _LINE_RE.match(line)
        if not m:
            continue
        op = m.group("op")
        if any(op == c or op == c + "-start" for c in _COLLECTIVES):
            rows.append((shape_bytes(m.group("type")), op,
                         m.group("type")[:80]))
    rows.sort(reverse=True)
    return rows[:top]


@dataclasses.dataclass
class Roofline:
    flops_per_chip: float
    bytes_per_chip: float         # raw HLO bytes-accessed (CPU, fusion-blind)
    coll_bytes_per_chip: float
    coll_breakdown: Dict[str, int]
    model_flops: float = 0.0      # analytic useful FLOPs (global)
    n_chips: int = 1
    bytes_analytic_per_chip: float = 0.0   # fused-TPU HBM model (flops.py)

    @property
    def compute_s(self):
        return self.flops_per_chip / PEAK_FLOPS

    @property
    def memory_s(self):
        """Memory term: the analytic fused-HBM model when available (the
        CPU HLO byte count has no TPU-style fusion and overcounts 10-50x;
        it is kept as memory_s_hlo for relative diagnostics)."""
        b = self.bytes_analytic_per_chip or self.bytes_per_chip
        return b / HBM_BW

    @property
    def memory_s_hlo(self):
        return self.bytes_per_chip / HBM_BW

    @property
    def collective_s(self):
        return self.coll_bytes_per_chip / LINK_BW

    @property
    def dominant(self) -> str:
        terms = dict(compute=self.compute_s, memory=self.memory_s,
                     collective=self.collective_s)
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline-model step time: max of the three terms (perfect
        overlap assumption; the no-overlap bound is the sum)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_fraction(self) -> float:
        total = self.flops_per_chip * self.n_chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilization at the roofline step time."""
        denom = self.step_time_s * PEAK_FLOPS * self.n_chips
        return self.model_flops / denom if denom else 0.0

    def as_dict(self) -> Dict[str, Any]:
        return dict(
            flops_per_chip=self.flops_per_chip,
            bytes_per_chip=self.bytes_per_chip,
            bytes_analytic_per_chip=self.bytes_analytic_per_chip,
            coll_bytes_per_chip=self.coll_bytes_per_chip,
            coll_breakdown=self.coll_breakdown,
            compute_s=self.compute_s, memory_s=self.memory_s,
            memory_s_hlo=self.memory_s_hlo,
            collective_s=self.collective_s, dominant=self.dominant,
            step_time_s=self.step_time_s, model_flops=self.model_flops,
            useful_flops_fraction=self.useful_flops_fraction,
            mfu=self.mfu, n_chips=self.n_chips)


def analyze_compiled(compiled, n_chips: int,
                     model_flops: float = 0.0) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):          # some backends return [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    text = compiled.as_text()
    coll = collective_bytes(text)
    return Roofline(flops_per_chip=flops, bytes_per_chip=byts,
                    coll_bytes_per_chip=float(
                        coll.get("total_corrected", coll.get("total", 0))),
                    coll_breakdown=coll, model_flops=model_flops,
                    n_chips=n_chips)


def memory_stats(compiled) -> Dict[str, int]:
    ma = compiled.memory_analysis()
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")
    out = {}
    for k in keys:
        out[k] = int(getattr(ma, k, 0))
    out["total_hbm_bytes"] = (out["argument_size_in_bytes"]
                              + out["output_size_in_bytes"]
                              + out["temp_size_in_bytes"]
                              - out["alias_size_in_bytes"])
    return out
