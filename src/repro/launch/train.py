"""Training launcher: --arch <id> [--reduced] with the full fault-tolerant
loop (checkpoint/restart, watchdog, microbatching).

On a real pod this runs once per host under the cluster scheduler; the mesh
comes from jax.devices() (elastic). On this CPU container use --reduced.

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --reduced \
      --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse

from repro.configs.base import get_arch
from repro.optim.adamw import AdamWConfig
from repro.training.loop import run_training


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch, reduced=args.reduced)
    opt = AdamWConfig(lr=args.lr, warmup_steps=args.warmup,
                      total_steps=args.steps)
    res = run_training(cfg, steps=args.steps, global_batch=args.batch,
                       seq_len=args.seq, ckpt_dir=args.ckpt_dir,
                       ckpt_every=args.ckpt_every,
                       microbatches=args.microbatches, opt=opt,
                       seed=args.seed)
    t = res["timing"]
    print(f"done: final loss {res['losses'][-1]:.4f}, "
          f"step p50 {t.get('p50', 0):.3f}s p99 {t.get('p99', 0):.3f}s, "
          f"stragglers {t.get('stragglers', 0)}")


if __name__ == "__main__":
    main()
