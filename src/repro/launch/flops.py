"""Analytic MODEL_FLOPS per (arch x shape): the 'useful compute' yardstick.

Convention: 6*N*D for training (fwd+bwd), 2*N*D for inference, with N the
*active* non-embedding parameter count (MoE: experts counted at k/E), plus
the sequence-interaction terms the N*D rule misses:
  * attention: 4*B*H*Dh*(causal token pairs) per layer (x3 for training)
  * SSD: intra-chunk quadratic + state terms per layer
Used for the MODEL_FLOPS / HLO_FLOPs ratio in §Roofline (remat/padding/
capacity-factor waste shows up as a ratio < 1).
"""
from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import mamba2 as M2
from repro.models import transformer as T


@functools.lru_cache(maxsize=64)
def param_counts(cfg: ModelConfig) -> Dict[str, float]:
    shapes = jax.eval_shape(
        lambda: T.init_params(cfg, jax.random.PRNGKey(0)))

    def walk(node, prefix=""):
        total = expert = embed = 0
        if isinstance(node, dict):
            for k, v in node.items():
                t, e, m = walk(v, f"{prefix}{k}/")
                total += t
                expert += e
                embed += m
            return total, expert, embed
        n = int(np.prod(node.shape))
        path = prefix[:-1]
        is_expert = "moe/w_" in path
        is_embed = path.split("/")[-1] in ("wte", "wpe")
        return n, n if is_expert else 0, n if is_embed else 0

    total, expert, embed = walk(shapes)
    active = total - embed
    if cfg.n_experts:
        active = active - expert * (1 - cfg.n_experts_active / cfg.n_experts)
    head = 0.0 if cfg.tie_embeddings else float(
        cfg.d_model * cfg.vocab_size)
    if cfg.tie_embeddings:
        head = float(cfg.d_model * cfg.vocab_size)
        active += head                   # tied head still costs flops
    return dict(total=float(total), active=float(active),
                expert=float(expert), embed=float(embed), head=head)


def _attn_pairs(S: int, window) -> float:
    """Causal (q, k) pair count per sequence."""
    if window and window < S:
        return S * window - window * (window - 1) / 2.0
    return S * (S + 1) / 2.0


def _attn_flops_seq(cfg: ModelConfig, B: int, S: int, n_layers: int,
                    heads: int, d_head: int) -> float:
    pairs = _attn_pairs(S, cfg.sliding_window)
    return 4.0 * B * heads * d_head * pairs * n_layers


def _ssd_flops_seq(cfg: ModelConfig, B: int, S: int, n_layers: int) -> float:
    dd = M2.ssm_dims(cfg)
    Q = min(cfg.ssm_chunk, S)
    H, P, N = dd["n_heads"], dd["head_dim"], dd["state"]
    intra = 2.0 * B * S * Q * (N + H * P)
    inter = 4.0 * B * S * H * P * N
    return (intra + inter) * n_layers


# ---------------------------------------------------------------------------
# analytic HBM-traffic model (the roofline memory term)
#
# XLA:CPU "bytes accessed" counts every unfused op's operands -- on TPU the
# elementwise chains fuse, so CPU numbers are 10-50x pessimistic. This model
# counts the traffic a fused TPU execution actually pays, per chip per step:
#
#   train : weights 6 B/param (bf16 read fwd + bwd + remat-recompute)
#           + optimizer 32 B/param (fp32 grad w+r, m/v r+w, master r+w)
#           + activation boundary traffic per layer (write fwd + read bwd,
#             x1.5 remat recompute) + flash-attention KV re-reads
#           + chunked-loss logits spills
#   serve : weights once (PACKED bits for quantized tensors -- the paper's
#           benefit), KV cache read + slot write, boundary activations
# ---------------------------------------------------------------------------

_TRAIN_WEIGHT_B = 6.0
_TRAIN_OPT_B = 32.0
_REMAT_FACTOR = 1.5


def _act_bytes_per_token_layer(cfg: ModelConfig) -> float:
    """Boundary activation bytes (bf16 write+read) per token per layer."""
    d = cfg.d_model
    if cfg.family in ("ssm", "hybrid"):
        dd = M2.ssm_dims(cfg)
        base = 3 * dd["d_inner"] + 2 * dd["state"] + dd["n_heads"] + 2 * d
        # SSD chunk decay/score spills ~ Q * H fp32 per token
        base += 2 * min(cfg.ssm_chunk, 256) * dd["n_heads"]
    elif cfg.family == "moe":
        fe = cfg.moe_d_ff * cfg.n_experts_active * cfg.capacity_factor
        base = 4 * d + 2 * cfg.n_kv_heads * cfg.d_head + 3 * fe
    else:
        base = (4 * d + 2 * cfg.n_kv_heads * cfg.d_head + 3 * cfg.d_ff)
    return base * 2 * 2.0            # bf16, write + read


def _kv_reread_bytes_per_token_layer(cfg: ModelConfig, S: int,
                                     q_chunk: int) -> float:
    """Flash attention re-reads K/V once per query chunk."""
    if cfg.family == "ssm":
        return 0.0
    ctx = min(S, cfg.sliding_window or S)
    rereads = max(ctx / (2.0 * q_chunk), 1.0)
    return rereads * 2 * cfg.n_kv_heads * cfg.d_head * 2


def serve_param_bytes(cfg: ModelConfig, quantized: bool = True,
                      policy_name: str = "default_serve_mix") -> float:
    """Per-replica serve weight bytes (packed where the policy quantizes)."""
    from repro.core.policy import get_policy
    from repro.core.qlinear import spec_like_quantized
    from repro.core.quantize import QTensor
    sds = jax.eval_shape(lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
    if quantized:
        sds = spec_like_quantized(sds, get_policy(policy_name))
    total = 0.0
    for leaf in jax.tree.leaves(
            sds, is_leaf=lambda x: isinstance(x, QTensor)):
        if isinstance(leaf, QTensor):
            total += leaf.nbytes
        else:
            total += float(np.prod(leaf.shape)) * 2   # bf16 residual
    return total


def memory_model(cfg: ModelConfig, shape: ShapeConfig, *, n_chips: int,
                 model_par: int, serve_quantized: bool = True,
                 policy_name: str = "default_serve_mix",
                 fused_weights: bool = True,
                 kv_cache_bits: int = 16) -> Dict[str, float]:
    """Per-chip HBM bytes per step (see module comment).

    fused_weights=False models the XLA dequantize-then-matmul baseline
    (the paper's CPU-framework analogue): packed weights are read AND the
    dequantized bf16 copy is written + read back. fused_weights=True is
    the F-BFQ datapath: packed bytes only. kv_cache_bits=8 models the
    int8-quantized KV cache (beyond-paper §Perf option).
    """
    pc = param_counts(cfg)
    B, S = shape.global_batch, shape.seq_len
    dp = max(n_chips // model_par, 1)
    L = cfg.n_layers

    if shape.kind == "train":
        tokens_local = B * S / dp
        w = pc["total"] * (_TRAIN_WEIGHT_B + _TRAIN_OPT_B) / n_chips
        act = (tokens_local * L
               * _act_bytes_per_token_layer(cfg) / model_par
               * _REMAT_FACTOR)
        act += (tokens_local * L
                * _kv_reread_bytes_per_token_layer(cfg, S,
                                                   cfg.attn_q_chunk))
        V_local = cfg.vocab_size / model_par
        loss = tokens_local * (V_local * 4 * 2 + cfg.d_model * 2 * 2)
        cache = 0.0
    elif shape.kind == "prefill":
        tokens_local = B * S / dp
        w = serve_param_bytes(cfg, serve_quantized) / model_par
        if not fused_weights and serve_quantized:
            w += pc["total"] * 2 * 2 / model_par   # bf16 copy write + read
        act = tokens_local * L * _act_bytes_per_token_layer(cfg) / model_par
        act += (tokens_local * L
                * _kv_reread_bytes_per_token_layer(cfg, S,
                                                   cfg.attn_q_chunk))
        loss = B / dp * cfg.vocab_size / model_par * 4
        cache = (tokens_local * L * 2 * cfg.n_kv_heads * cfg.d_head
                 * (kv_cache_bits / 8.0)
                 / model_par) if cfg.family != "ssm" else 0.0
    else:                                        # decode
        w = serve_param_bytes(cfg, serve_quantized) / model_par
        if not fused_weights and serve_quantized:
            w += pc["total"] * 2 * 2 / model_par   # bf16 copy write + read
        # cache shards over dp via batch when divisible, else via the cache
        # sequence dim (B=1 long-context; see sharding.cache_specs)
        B_local = B / dp
        cache = 0.0
        if cfg.family in ("dense", "vlm", "audio", "moe", "gpt2", "hybrid"):
            Tc = min(S, cfg.sliding_window or S)
            napp = L
            if cfg.family == "hybrid":
                napp = sum(1 for g in T._hybrid_groups(cfg)
                           if g == cfg.hybrid_attn_every)
            cache += (B_local * napp * Tc * 2 * cfg.n_kv_heads
                      * (2 * cfg.d_model // cfg.n_heads
                         if cfg.family == "hybrid" else cfg.d_head)
                      * (kv_cache_bits / 8.0) / model_par)
        if cfg.family in ("ssm", "hybrid"):
            dd = M2.ssm_dims(cfg)
            cache += (B_local * L * dd["n_heads"] * dd["head_dim"]
                      * dd["state"] * 4 * 2 / model_par)
        act = B_local * L * _act_bytes_per_token_layer(cfg) / model_par / 2
        loss = B_local * cfg.vocab_size / model_par * 4
    total = w + act + loss + cache
    return dict(weights=w, activations=act, loss=loss, cache=cache,
                total=total)


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    pc = param_counts(cfg)
    B, S = shape.global_batch, shape.seq_len
    N = pc["active"]
    if shape.kind == "train":
        base = 6.0 * N * B * S
        mult = 3.0                       # fwd + bwd on seq terms
        tokens_seq = S
    elif shape.kind == "prefill":
        # serve_prefill computes head logits for the last position only
        base = 2.0 * (N - pc["head"]) * B * S + 2.0 * pc["head"] * B
        mult = 1.0
        tokens_seq = S
    else:                                # decode: one token, cache of S
        base = 2.0 * N * B
        mult = 1.0
        tokens_seq = None

    extra = 0.0
    if cfg.family in ("dense", "vlm", "audio", "moe", "gpt2"):
        if tokens_seq is None:
            ctx = min(S, cfg.sliding_window or S)
            extra = 4.0 * B * cfg.n_heads * cfg.d_head * ctx * cfg.n_layers
        else:
            extra = mult * _attn_flops_seq(cfg, B, S, cfg.n_layers,
                                           cfg.n_heads, cfg.d_head)
    elif cfg.family == "ssm":
        if tokens_seq is None:
            dd = M2.ssm_dims(cfg)
            extra = (4.0 * B * dd["n_heads"] * dd["head_dim"] * dd["state"]
                     * cfg.n_layers)
        else:
            extra = mult * _ssd_flops_seq(cfg, B, S, cfg.n_layers)
    elif cfg.family == "hybrid":
        napp = sum(1 for g in T._hybrid_groups(cfg)
                   if g == cfg.hybrid_attn_every)
        Dh2 = 2 * cfg.d_model // cfg.n_heads
        if tokens_seq is None:
            dd = M2.ssm_dims(cfg)
            extra = (4.0 * B * dd["n_heads"] * dd["head_dim"] * dd["state"]
                     * cfg.n_layers)
            extra += 4.0 * B * cfg.n_heads * Dh2 * min(S, S) * napp
        else:
            extra = mult * _ssd_flops_seq(cfg, B, S, cfg.n_layers)
            extra += mult * _attn_flops_seq(cfg, B, S, napp, cfg.n_heads,
                                            Dh2)
    return base + extra
