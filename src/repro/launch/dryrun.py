import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import (jax locks the device
# count at first init). REPRO_DRYRUN_DEVICES overrides for the tiny-mesh
# CI test -- still before any jax import.
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])

# Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.
#
# For each cell this builds the *real* step function (train_step /
# serve_prefill / serve_step with BFP-quantized weights), gives it
# ShapeDtypeStruct stand-ins (no allocation), lowers and compiles it against
# the production mesh, and records:
#
#   * memory_analysis()  -- per-chip HBM: proves the cell fits
#   * cost_analysis()    -- per-chip FLOPs / bytes for §Roofline
#   * collective bytes   -- parsed from post-SPMD HLO for §Roofline
#
# Usage:
#   python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
#   python -m repro.launch.dryrun --all --multi-pod --out results/dryrun

import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import (ARCH_IDS, SHAPES, get_arch, input_specs,
                                shape_applicable)
from repro.core.policy import get_policy
from repro.core.qlinear import spec_like_quantized
from repro.distributed import sharding as SH
from repro.launch import analysis as AN
from repro.launch import flops as FL
from repro.launch.mesh import make_production_mesh, validate_mesh
from repro.models import transformer as T
from repro.optim.adamw import AdamWConfig
from repro.training import steps as S


def _bf16_specs(tree):
    def c(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return jax.ShapeDtypeStruct(x.shape, jnp.bfloat16)
        return x
    return jax.tree.map(c, tree)


def _tune_for_dryrun(cfg, shape):
    """Dry-run lowers the XLA dataflow path (Pallas cannot target the CPU
    backend); attention must be the memory-bounded blockwise impl."""
    kw = dict(kernel_impl="xla", attn_impl="blockwise")
    if shape.kind == "train":
        kw["remat"] = True
    return cfg.replace(**kw)


def _probe_depths(cfg):
    """Two reduced depths for the unrolled cost probes (XLA counts a scan
    body once regardless of trip count, so cost/collective metrics come
    from unrolled lowerings at two depths, linearly extrapolated to the
    true depth; memory/compile proof uses the full scanned graph)."""
    if cfg.family == "hybrid":
        k = cfg.hybrid_attn_every
        return k, 2 * k
    return 1, 2


def _extrapolate(m1, m2, l1, l2, L):
    b = (m2 - m1) / (l2 - l1)
    a = m1 - b * l1
    return a + b * L


def _lower_cell(cfg, shape, mesh, *, quant_policy, kv_shard, fsdp,
                microbatches, serve_quantized, tp=True):
    """Build + lower the cell's step function. Returns (lowered,)."""
    import contextlib
    tp_ctx = contextlib.nullcontext() if tp else SH.tp_off()
    with tp_ctx:
        return _lower_cell_inner(cfg, shape, mesh, quant_policy=quant_policy,
                                 kv_shard=kv_shard, fsdp=fsdp,
                                 microbatches=microbatches,
                                 serve_quantized=serve_quantized)


def _lower_cell_inner(cfg, shape, mesh, *, quant_policy, kv_shard, fsdp,
                      microbatches, serve_quantized):
    specs = input_specs(cfg, shape)
    batch_sh = SH.named(SH.batch_specs(specs, mesh), mesh)

    with mesh, SH.activation_axes(mesh):
        if shape.kind == "train":
            opt = AdamWConfig()
            state_sds = jax.eval_shape(
                lambda: S.init_train_state(cfg, opt, jax.random.PRNGKey(0)))
            pspecs = SH.param_specs(state_sds["params"], mesh, fsdp=fsdp)
            state_specs = dict(params=pspecs,
                               opt=SH.opt_state_specs(pspecs), step=P())
            state_sh = SH.named(state_specs, mesh)
            step_fn = S.make_train_step(cfg, opt, microbatches=microbatches)
            jitted = jax.jit(step_fn, in_shardings=(state_sh, batch_sh),
                             out_shardings=(state_sh, None),
                             donate_argnums=(0,))
            return jitted.lower(state_sds, specs)

        params_sds = _bf16_specs(jax.eval_shape(
            lambda: T.init_params(cfg, jax.random.PRNGKey(0))))
        if serve_quantized:
            params_sds = spec_like_quantized(params_sds,
                                             get_policy(quant_policy))
        psh = SH.named(SH.param_specs(params_sds, mesh, fsdp=False), mesh)

        if shape.kind == "prefill":
            prefill, _ = S.make_serve_steps(cfg)
            jitted = jax.jit(prefill, in_shardings=(psh, batch_sh))
            return jitted.lower(params_sds, specs)

        # decode
        cache_sds = jax.eval_shape(
            lambda: T.init_cache(cfg, shape.global_batch, shape.seq_len))
        cache_sh = SH.named(
            SH.cache_specs(cache_sds, mesh, kv_shard=kv_shard), mesh)
        _, decode = S.make_serve_steps(cfg)
        jitted = jax.jit(decode, in_shardings=(psh, cache_sh, batch_sh),
                         out_shardings=(None, cache_sh),
                         donate_argnums=(1,))
        return jitted.lower(params_sds, cache_sds, specs)


def dryrun_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
                quant_policy: str = "default_serve_mix",
                kv_shard: str = "auto", fsdp: bool = True,
                microbatches: int = 1,
                serve_quantized: bool = True,
                cost_probes: bool = True, tp: bool = True,
                mesh=None, config_override=None) -> Dict[str, Any]:
    """Lower+compile one cell; returns the record for EXPERIMENTS.md."""
    shape = SHAPES[shape_name]
    cfg = _tune_for_dryrun(get_arch(arch), shape)
    if config_override:
        cfg = cfg.replace(**config_override)
    ok, why = shape_applicable(cfg, shape)
    rec: Dict[str, Any] = dict(arch=arch, shape=shape_name,
                               multi_pod=multi_pod, kind=shape.kind,
                               kv_shard=kv_shard, fsdp=fsdp,
                               serve_quantized=serve_quantized)
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec

    mesh = mesh if mesh is not None else make_production_mesh(
        multi_pod=multi_pod)
    n_chips = mesh.size
    validate_mesh(mesh, shape.global_batch)
    kw = dict(quant_policy=quant_policy, kv_shard=kv_shard, fsdp=fsdp,
              microbatches=microbatches, serve_quantized=serve_quantized,
              tp=tp)

    # 1) full-depth scanned graph: the compile/memory proof
    t0 = time.time()
    lowered = _lower_cell(cfg, shape, mesh, **kw)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = AN.memory_stats(compiled)

    # 2) unrolled cost probes at two reduced depths -> exact per-layer
    #    cost extrapolated to true depth (scan bodies are cost-counted once)
    mf = FL.model_flops(get_arch(arch), shape)
    if cost_probes:
        l1, l2 = _probe_depths(cfg)
        probes = []
        probe_text = None
        for lp in (l1, l2):
            # unrolled probe: scan bodies are cost-counted once, so every
            # scan (layers, attention kv chunks, SSD chunks, microbatches)
            # unrolls; the chunked loss switches to its dense equivalent.
            # Attention chunks coarsen to bound unrolled-HLO size (single
            # core: compile time); this overcounts the triangular-diagonal
            # waste by <= cq/S ~ 6%, i.e. the compute term is conservative.
            pcfg = cfg.replace(n_layers=lp, scan_unroll=True, loss_chunk=0,
                               attn_q_chunk=2048, attn_kv_chunk=2048,
                               ssd_unroll=False)
            pl = _lower_cell(pcfg, shape, mesh, **kw).compile()
            if probe_text is None:
                probe_text = pl.as_text()
            pr = AN.analyze_compiled(pl, n_chips)
            # SSD chunk scans stay rolled in probes (compile-time bound on
            # this 1-core host): add the missing (nc-1)/nc of the exact
            # analytic SSD flops for the probe depth
            if cfg.family in ("ssm", "hybrid") and shape.kind != "decode":
                from repro.models.mamba2 import ssm_dims
                nc = max(1, shape.seq_len // cfg.ssm_chunk)
                mult = 3.0 if shape.kind == "train" else 1.0
                dd = ssm_dims(cfg)
                mp = mesh.shape.get("model", 1) if tp else 1
                # tokens shard over dp; heads over model when divisible,
                # else each model rank recomputes the full head set
                sharded_chips = (n_chips if dd["n_heads"] % mp == 0
                                 else n_chips // mp)
                missing = (mult * FL._ssd_flops_seq(
                    cfg, shape.global_batch, shape.seq_len, lp)
                    * (nc - 1) / nc / sharded_chips)
                pr = AN.Roofline(
                    flops_per_chip=pr.flops_per_chip + missing,
                    bytes_per_chip=pr.bytes_per_chip,
                    coll_bytes_per_chip=pr.coll_bytes_per_chip,
                    coll_breakdown=pr.coll_breakdown, n_chips=n_chips)
            probes.append(pr)
        L = cfg.n_layers
        ex = lambda f: max(0.0, _extrapolate(f(probes[0]), f(probes[1]),
                                             l1, l2, L))
        coll_kinds = set(probes[0].coll_breakdown) | set(
            probes[1].coll_breakdown)
        coll = {k: int(ex(lambda p, k=k: p.coll_breakdown.get(k, 0)))
                for k in coll_kinds}
        roof = AN.Roofline(
            flops_per_chip=ex(lambda p: p.flops_per_chip),
            bytes_per_chip=ex(lambda p: p.bytes_per_chip),
            coll_bytes_per_chip=float(
                coll.get("total_corrected", coll.get("total", 0))),
            coll_breakdown=coll, model_flops=mf, n_chips=n_chips)
        top_coll = AN.hlo_collective_summary(probe_text, top=8)
    else:
        roof = AN.analyze_compiled(compiled, n_chips, model_flops=mf)
        top_coll = AN.hlo_collective_summary(compiled.as_text(), top=8)

    # analytic fused-HBM model -> the roofline memory term (see flops.py)
    mcfg = cfg
    mm = FL.memory_model(
        mcfg, shape, n_chips=n_chips,
        model_par=mesh.shape.get("model", 1) if tp else 1,
        serve_quantized=serve_quantized,
        policy_name=quant_policy,
        kv_cache_bits=8 if mcfg.kv_cache_quant else 16)
    roof.bytes_analytic_per_chip = mm["total"]
    rec["memory_model"] = {k: int(v) for k, v in mm.items()}

    rec.update(status="ok", n_chips=n_chips,
               mesh=dict(zip(mesh.axis_names, [mesh.shape[a] for a in
                                               mesh.axis_names])),
               lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
               memory=mem, roofline=roof.as_dict(),
               top_collectives=top_coll)
    return rec


def print_record(rec: Dict[str, Any]) -> None:
    if rec["status"] == "skipped":
        print(f"[skip] {rec['arch']} x {rec['shape']}: {rec['reason']}")
        return
    r = rec["roofline"]
    m = rec["memory"]
    print(f"[ok] {rec['arch']} x {rec['shape']} "
          f"(multi_pod={rec['multi_pod']}, chips={rec['n_chips']})")
    print(f"     per-chip HBM: args {m['argument_size_in_bytes']/2**30:.2f} "
          f"GiB, temps {m['temp_size_in_bytes']/2**30:.2f} GiB, "
          f"out {m['output_size_in_bytes']/2**30:.2f} GiB")
    print(f"     roofline: compute {r['compute_s']*1e3:.2f} ms | memory "
          f"{r['memory_s']*1e3:.2f} ms (hlo {r.get('memory_s_hlo', 0)*1e3:.0f}) "
          f"| collective {r['collective_s']*1e3:.2f} ms -> "
          f"{r['dominant']}-bound")
    print(f"     useful-flops ratio {r['useful_flops_fraction']:.3f}, "
          f"roofline MFU {r['mfu']:.3f} "
          f"(lower {rec['lower_s']}s, compile {rec['compile_s']}s)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--kv-shard", default="auto")
    ap.add_argument("--quant-policy", default="default_serve_mix")
    ap.add_argument("--no-quant", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--no-probes", action="store_true",
                    help="skip unrolled cost probes (compile proof only)")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--out", default=None)
    ap.add_argument("--only-arch", default=None,
                    help="comma-separated arch filter for --all")
    args = ap.parse_args()

    if args.all:
        archs = (args.only_arch.split(",") if args.only_arch
                 else ARCH_IDS[:10])
        cells = [(a, s) for a in archs for s in SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    records = []
    for arch, shape in cells:
        for mp in meshes:
            try:
                rec = dryrun_cell(
                    arch, shape, multi_pod=mp, kv_shard=args.kv_shard,
                    quant_policy=args.quant_policy,
                    serve_quantized=not args.no_quant,
                    fsdp=not args.no_fsdp,
                    cost_probes=not args.no_probes,
                    microbatches=args.microbatches)
            except Exception as e:  # a failure here is a bug in the system
                rec = dict(arch=arch, shape=shape, multi_pod=mp,
                           status="error", error=f"{type(e).__name__}: {e}",
                           traceback=traceback.format_exc()[-2000:])
            print_record(rec) if rec["status"] != "error" else print(
                f"[ERROR] {arch} x {shape}: {rec['error']}")
            records.append(rec)
            if args.out:
                os.makedirs(args.out, exist_ok=True)
                tag = "multi" if mp else "single"
                path = os.path.join(args.out,
                                    f"{arch}__{shape}__{tag}.json")
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1, default=str)
    n_ok = sum(r["status"] == "ok" for r in records)
    n_skip = sum(r["status"] == "skipped" for r in records)
    n_err = sum(r["status"] == "error" for r in records)
    print(f"\n== dry-run: {n_ok} ok, {n_skip} skipped, {n_err} errors ==")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
