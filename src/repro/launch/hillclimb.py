import os
os.environ.setdefault(
    "XLA_FLAGS", "--xla_force_host_platform_device_count=256")

# §Perf hillclimb driver: run named variants of the three chosen cells and
# record before/after roofline terms (EXPERIMENTS.md §Perf).
#
# This drives PERF search over dryrun roofline cells. Quantization-POLICY
# search (per-layer format assignment against the measured quality-vs-
# bytes Pareto) lives in ``launch/policy_search.py``.
#
#   REPRO_DRYRUN_DEVICES=256 PYTHONPATH=src python -m repro.launch.hillclimb \
#       --cell h1 --out results/hillclimb

import argparse
import json
import time

from repro.launch.dryrun import dryrun_cell, print_record

# hypothesis -> change lists; each entry: (variant_name, kwargs)
CELLS = {
    # H1: qwen2-vl-72b x decode_32k -- the paper-representative cell
    # (BFP-quantized decode) and the most collective-bound overall.
    "h1": ("qwen2-vl-72b", "decode_32k", [
        ("base_auto", dict()),                     # auto kv: head_dim mode
        ("kv_seq", dict(kv_shard="seq")),          # flash-decoding layout
        ("kv_seq_int8kv", dict(kv_shard="seq",
                               config_override=dict(kv_cache_quant=True))),
    ]),
    # H2: granite-moe-3b-a800m x train_4k -- worst useful-flops ratio and
    # most collective-bound train cell.
    "h2": ("granite-moe-3b-a800m", "train_4k", [
        ("base_tp16", dict()),
        ("pure_fsdp", dict(tp=False)),
        ("pure_fsdp_cf1", dict(tp=False,
                               config_override=dict(capacity_factor=1.0))),
    ]),
    # H3: llama3.2-1b x train_4k -- representative small dense train,
    # collective-bound at TP=16.
    "h3": ("llama3.2-1b", "train_4k", [
        ("base_tp16", dict()),
        ("pure_fsdp", dict(tp=False)),
    ]),
    # decode-fix validation on a second arch (same hypothesis as h1)
    "h1b": ("qwen3-1.7b", "decode_32k", [
        ("base_auto", dict()),
        ("kv_seq", dict(kv_shard="seq")),
        ("kv_seq_int8kv", dict(kv_shard="seq",
                               config_override=dict(kv_cache_quant=True))),
    ]),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, choices=sorted(CELLS))
    ap.add_argument("--out", default="results/hillclimb")
    args = ap.parse_args()
    arch, shape, variants = CELLS[args.cell]
    os.makedirs(args.out, exist_ok=True)
    for name, kw in variants:
        t0 = time.time()
        try:
            rec = dryrun_cell(arch, shape, **kw)
        except Exception as e:
            import traceback
            rec = dict(arch=arch, shape=shape, status="error",
                       error=str(e), traceback=traceback.format_exc()[-2000:])
        rec["variant"] = name
        path = os.path.join(args.out, f"{args.cell}__{name}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1, default=str)
        if rec["status"] == "ok":
            print(f"=== {args.cell}/{name} ({time.time()-t0:.0f}s)")
            print_record(rec)
        else:
            print(f"=== {args.cell}/{name} ERROR: {rec.get('error')}")


if __name__ == "__main__":
    main()
