"""Summarize dry-run JSON artifacts into the EXPERIMENTS.md tables.

  PYTHONPATH=src python -m repro.launch.summarize results/dryrun_single \
      [results/dryrun_multi ...]
"""
from __future__ import annotations

import json
import os
import sys
from typing import Dict, List


def load(dirpath: str) -> List[Dict]:
    recs = []
    for name in sorted(os.listdir(dirpath)):
        if name.endswith(".json"):
            with open(os.path.join(dirpath, name)) as f:
                recs.append(json.load(f))
    return recs


def fmt_bytes(b) -> str:
    return f"{float(b)/2**30:.2f}"


def dryrun_table(recs: List[Dict]) -> str:
    rows = ["| arch | shape | mesh | status | HBM/chip GiB (args+temp+out) "
            "| compile s |",
            "|---|---|---|---|---|---|"]
    for r in recs:
        mesh = "2x16x16" if r.get("multi_pod") else "16x16"
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | {mesh} | SKIP "
                        f"(full attention @524k) | - | - |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | {mesh} | "
                        f"ERROR {r.get('error','')[:60]} | - | - |")
            continue
        m = r["memory"]
        hbm = (f"{fmt_bytes(m['argument_size_in_bytes'])}+"
               f"{fmt_bytes(m['temp_size_in_bytes'])}+"
               f"{fmt_bytes(m['output_size_in_bytes'])}")
        rows.append(f"| {r['arch']} | {r['shape']} | {mesh} | ok | {hbm} | "
                    f"{r['compile_s']} |")
    return "\n".join(rows)


def roofline_table(recs: List[Dict]) -> str:
    rows = ["| arch | shape | compute ms | memory ms | coll ms | dominant "
            "| useful/HLO | roofline MFU | what would move the dominant "
            "term |",
            "|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["status"] != "ok" or r.get("multi_pod"):
            continue
        rf = r["roofline"]
        hint = _hint(r)
        rows.append(
            f"| {r['arch']} | {r['shape']} | {rf['compute_s']*1e3:.2f} | "
            f"{rf['memory_s']*1e3:.2f} | {rf['collective_s']*1e3:.2f} | "
            f"{rf['dominant']} | {rf['useful_flops_fraction']:.2f} | "
            f"{rf['mfu']:.3f} | {hint} |")
    return "\n".join(rows)


def _hint(r: Dict) -> str:
    rf = r["roofline"]
    dom = rf["dominant"]
    kind = r["kind"]
    if dom == "memory" and kind == "decode":
        mm = r.get("memory_model", {})
        if mm and mm.get("cache", 0) > mm.get("weights", 0):
            return "KV/SSM cache traffic: quantize cache or shard wider"
        return "weight traffic: lower-bit variants / wider TP"
    if dom == "memory":
        return "activation traffic: bigger fused blocks, less remat"
    if dom == "collective":
        return "resharding: SP/reduce-scatter, overlap, fewer TP syncs"
    return "MXU utilization: larger per-chip tiles / fewer small dots"


def main() -> None:
    for d in sys.argv[1:]:
        recs = load(d)
        print(f"\n## {d} ({len(recs)} records)\n")
        print("### Dry-run\n")
        print(dryrun_table(recs))
        singles = [r for r in recs if not r.get("multi_pod")]
        if singles:
            print("\n### Roofline (single-pod)\n")
            print(roofline_table(recs))


if __name__ == "__main__":
    main()
