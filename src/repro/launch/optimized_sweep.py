import os
os.environ.setdefault(
    "XLA_FLAGS", "--xla_force_host_platform_device_count=256")

# Optimized configuration sweep: apply the §Perf hillclimb recipes to every
# applicable cell and record the optimized roofline table.
#   decode/long cells : kv_shard=seq + int8 KV cache (H1 recipe)
#   train/prefill of <=2B-dense archs : tp_off pure-FSDP (H3 recipe)
#
#   REPRO_DRYRUN_DEVICES=256 PYTHONPATH=src \
#       python -m repro.launch.optimized_sweep --out results/dryrun_opt

import argparse
import json
import traceback

from repro.configs.base import ARCH_IDS, SHAPES, get_arch, shape_applicable
from repro.launch.dryrun import dryrun_cell, print_record

# archs where the H3 pure-FSDP remap beats TP on the production mesh
SMALL_DENSE = ("llama3.2-1b", "qwen3-1.7b", "h2o-danube-1.8b",
               "musicgen-large", "granite-moe-3b-a800m", "olmoe-1b-7b",
               "zamba2-1.2b", "mamba2-2.7b", "phi3-mini-3.8b")


def variant_for(arch: str, shape_name: str):
    kind = SHAPES[shape_name].kind
    if kind == "decode":
        return dict(kv_shard="auto",
                    config_override=dict(kv_cache_quant=True))
    if arch in SMALL_DENSE:
        return dict(tp=False)
    return None            # big-model train/prefill: baseline is right


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/dryrun_opt")
    ap.add_argument("--kinds", default="decode",
                    help="comma list of kinds to sweep (decode,train,prefill)")
    args = ap.parse_args()
    kinds = set(args.kinds.split(","))
    os.makedirs(args.out, exist_ok=True)
    for arch in ARCH_IDS[:10]:
        for shape_name, shape in SHAPES.items():
            if shape.kind not in kinds:
                continue
            if not shape_applicable(get_arch(arch), shape)[0]:
                continue
            kw = variant_for(arch, shape_name)
            if kw is None:
                continue
            try:
                rec = dryrun_cell(arch, shape_name, **kw)
            except Exception as e:
                rec = dict(arch=arch, shape=shape_name, status="error",
                           error=str(e),
                           traceback=traceback.format_exc()[-1500:])
            path = os.path.join(args.out, f"{arch}__{shape_name}__opt.json")
            with open(path, "w") as f:
                json.dump(rec, f, indent=1, default=str)
            if rec["status"] == "ok":
                print_record(rec)
            else:
                print(f"[ERROR] {arch} x {shape_name}: {rec.get('error')}")


if __name__ == "__main__":
    main()
