"""Mesh construction (production + elastic).

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state -- required for the dry-run's
device-count override to work.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_elastic_mesh(model_parallel: int = 1,
                      devices: Optional[Sequence] = None):
    """Build a (data, model) mesh from whatever devices exist, degrading
    model-parallel size to the largest divisor of the device count --
    the elastic-scaling entry point (a failed host shrinks the mesh and
    training resumes from the last checkpoint)."""
    devices = list(devices if devices is not None else jax.devices())
    n = len(devices)
    mp = max(d for d in range(1, model_parallel + 1) if n % d == 0)
    return jax.make_mesh((n // mp, mp), ("data", "model"),
                         devices=devices)


def validate_mesh(mesh, global_batch: int) -> None:
    dp = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            dp *= mesh.shape[a]
    if global_batch % dp and global_batch != 1:
        raise ValueError(
            f"global_batch {global_batch} not divisible by data-parallel "
            f"size {dp} of mesh {dict(mesh.shape)}")
    # global_batch == 1 (long-context decode): batch replicates; the cache
    # sequence dim shards over dp instead (see sharding.cache_specs)
