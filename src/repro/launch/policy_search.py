"""Auto quantization-policy search: greedy Pareto hill-climb over the
per-layer format assignment (ROADMAP item 5; takes over the role of the
``launch/hillclimb.py`` perf scaffolding for quantization policy).

Pipeline (calibrate -> search -> serve):

  1. ``core/calibrate.py`` runs a small token budget through the fp32
     model and records per-matmul activation stats (abs-max columns,
     outlier fractions, per-format weighted quantization MSE).
  2. This module searches the per-path format assignment, seeded from
     ``default_serve_mix``, against the quality-vs-bytes Pareto measured
     by ``core/quality.py`` (teacher-logit KL on a fixed eval batch).
     Three phases: (a) probe every single-path move once for its KL and
     byte delta; (b) sweep a Lagrangian trade-off over those first-order
     estimates to propose byte-budget-feasible assignments (paired
     upgrade+downgrade swaps that single-move hill-climbing cannot
     reach: the seed is a Pareto corner, so any lone upgrade overshoots
     the budget before a downgrade pays for it) plus the best-estimated
     explicit swap pairs, verifying each proposal with a true eval;
     (c) greedy single-move hill-climb refinement
     under strict dominance. The RETURNED assignment is the best
     verified state that weakly dominates the seed on both axes -- the
     seed itself always qualifies -- so the final policy dominates or
     matches ``default_serve_mix`` by construction (the
     ``check_policy_auto`` bench gate).
  3. The searched assignment serializes to JSON (exact-path rules; see
     ``core.policy.policy_to_dict``) and loads back via
     ``serve --policy auto --policy-json <file>``.

  PYTHONPATH=src python -m repro.launch.policy_search \
      --arch tinyllama-1.1b --reduced --out results/auto_tinyllama.json
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Any, Dict, List, Optional, Tuple

import jax

from repro.core import calibrate as C
from repro.core import formats as F
from repro.core import policy as P
from repro.core import quality as QY
from repro.core.qlinear import quantize_params, quantized_param_bytes

# search candidates: the paper's two native variants, our outlier-aware
# extension, and two fallback-quality tiers ("none" = keep fp)
DEFAULT_CANDIDATES = ("q2_k", "q3_k", "q3_k_o", "q4_k", "q6_k", "none")


def _unflatten(flat: Dict[str, Any]):
    root: Dict[str, Any] = {}
    for path, leaf in flat.items():
        parts = path.split("/")
        d = root
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = leaf
    return root


def _exact_policy(name: str, assignment: Dict[str, Optional[str]]):
    rules = tuple((path, v or "none") for path, v in sorted(assignment.items()))
    return P.QuantPolicy(name, rules, default="none")


def _nearest_candidate(variant: Optional[str], available) -> Optional[str]:
    """Map a seed-report variant onto the searched candidate set.

    ``quantize_params``'s report goes through ``pick_fallback``, so a
    shape with K % 32 == 0 but K % 256 != 0 reports a 32-block fallback
    (q8_0) that need not be in ``candidates``; pick the candidate closest
    in bits/weight so every seed leaf stays addressable in ``_Searcher``
    (the assembled leaf goes through the same fallback at that shape, so
    the evaluation matches what serving would pack)."""
    if variant is None or variant in available:
        return variant
    bits = F.get_format(variant).bits_per_weight
    return min(sorted(available),
               key=lambda c: abs(F.get_format(c).bits_per_weight - bits))


class _Searcher:
    """Caches one full-model quantization per candidate variant, then
    assembles assignment trees leaf-wise (each eval costs one student
    forward, not a re-quantization)."""

    def __init__(self, cfg, params, candidates, stats, *,
                 eval_batch=2, eval_seq=64, eval_seed=1234):
        from repro.core.qlinear import _flatten_paths
        self.cfg = cfg
        self.flat = dict(_flatten_paths(params))
        self.stats = stats
        self.qleaves: Dict[str, Dict[str, Any]] = {}   # variant -> path -> leaf
        self.paths: List[str] = []
        calib = None
        for v in [c for c in candidates if c != "none"]:
            pol = P.pure(v)
            if calib is None:
                probe, report = quantize_params(params, pol)
                self.paths = sorted(p for p, var in report.items() if var)
                calib = (stats.for_paths(self.paths)
                         if stats is not None else {})
                qp = probe if v != "q3_k_o" or not calib else None
            else:
                qp = None
            if qp is None:
                qp, _ = quantize_params(params, pol, calib=calib)
            self.qleaves[v] = dict(_flatten_paths(qp))
        self.inputs, self.teacher = QY.teacher_logits_for(
            params, cfg, batch=eval_batch, seq=eval_seq, seed=eval_seed)
        self._cache: Dict[Tuple, Dict[str, float]] = {}

    def assemble(self, assignment: Dict[str, Optional[str]]):
        flat = dict(self.flat)
        for path, v in assignment.items():
            if v:
                flat[path] = self.qleaves[v][path]
        return _unflatten(flat)

    def evaluate(self, assignment: Dict[str, Optional[str]]):
        key = tuple(sorted(assignment.items()))
        if key in self._cache:
            return self._cache[key]
        tree = self.assemble(assignment)
        m = QY.quality_eval(None, tree, self.cfg, inputs=self.inputs,
                            teacher_logits=self.teacher)
        m["bytes"] = quantized_param_bytes(tree)["total"]
        self._cache[key] = m
        return m


def search_policy(cfg, params, *, arch: str = "model",
                  candidates=DEFAULT_CANDIDATES,
                  seed_policy: str = "default_serve_mix",
                  rounds: int = 6, stats: Optional[C.CalibStats] = None,
                  calib_batches: int = 2, calib_seq: int = 64,
                  eval_seq: int = 64, swap_budget: int = 12,
                  verbose: bool = True):
    """Returns (QuantPolicy, info dict). ``info['meta']`` carries the
    seed/final metrics and the pure_q2_k / pure_q6_k anchors (only for
    anchor variants present in ``candidates`` -- the CI smoke sweep drops
    q6_k); ``info['stats']`` carries the :class:`~repro.core.calibrate.
    CalibStats` the search used, so callers can quantize the returned
    policy with the same activation stats its verified evals saw."""
    log = print if verbose else (lambda *a, **k: None)
    if stats is None:
        t0 = time.time()
        stats = C.run_calibration(params, cfg, n_batches=calib_batches,
                                  seq=calib_seq)
        log(f"[calibrate] {stats.tokens} rows over {len(stats.names())} "
            f"tap sites in {time.time() - t0:.1f}s")
    s = _Searcher(cfg, params, candidates, stats, eval_seq=eval_seq)

    _, seed_report = quantize_params(params, P.get_policy(seed_policy))
    assignment = {p: _nearest_candidate(seed_report.get(p), s.qleaves)
                  for p in s.paths}
    cur = s.evaluate(assignment)
    kl0, bytes0 = cur["kl"], cur["bytes"]
    log(f"[seed {seed_policy}] kl={kl0:.4f} bytes={bytes0}")

    # metric-only anchors, computed only for anchor variants actually
    # searched (consumers treat an absent anchor as "not measured")
    anchors = {}
    for v in ("q2_k", "q6_k"):
        if v not in s.qleaves:
            continue
        m = s.evaluate({p: v for p in s.paths})
        anchors[f"pure_{v}"] = dict(kl=m["kl"], bytes=m["bytes"],
                                    pseudo_ppl=m["pseudo_ppl"])

    def score(m):
        return ((m["kl"] - kl0) / max(kl0, 1e-9)
                + (m["bytes"] - bytes0) / max(bytes0, 1))

    def dominates_seed(m):
        return m["kl"] <= kl0 * (1 + 1e-6) and m["bytes"] <= bytes0

    # incumbent: best verified assignment weakly dominating the seed on
    # both axes. The seed itself qualifies, so the returned policy can
    # never be worse than default_serve_mix.
    incumbent = (score(cur), dict(assignment), dict(cur))

    def consider(trial, m):
        nonlocal incumbent
        if dominates_seed(m) and score(m) < incumbent[0] - 1e-9:
            incumbent = (score(m), dict(trial), dict(m))

    # phase (a): probe each single-path move once; its byte delta is
    # exact (only that leaf changed) and its KL delta seeds the
    # first-order additive estimate the sweep optimizes over
    trajectory = [dict(round=0, kl=kl0, bytes=bytes0)]
    deltas: Dict[str, Dict[Optional[str], Tuple[float, int]]] = {}
    for path in s.paths:
        deltas[path] = {assignment[path]: (0.0, 0)}
        for v in candidates:
            vv = None if v == "none" else v
            if vv in deltas[path]:
                continue
            trial = dict(assignment, **{path: vv})
            m = s.evaluate(trial)
            consider(trial, m)
            deltas[path][vv] = (m["kl"] - kl0, m["bytes"] - bytes0)

    # phase (b): Lagrangian sweep -- per path pick
    # argmin(dKL + lam * dbytes); feasible totals get a true eval
    lams = [0.0] + [10.0 ** e / 4 ** f
                    for e in range(-9, -2) for f in range(2)]
    proposed = set()
    for lam in sorted(lams):
        trial = {}
        est_bytes = 0
        for path in s.paths:
            vv = min(deltas[path],
                     key=lambda c: (deltas[path][c][0]
                                    + lam * deltas[path][c][1]))
            trial[path] = vv
            est_bytes += deltas[path][vv][1]
        key = tuple(sorted(trial.items()))
        if est_bytes > 0 or key in proposed:
            continue
        proposed.add(key)
        m = s.evaluate(trial)
        consider(trial, m)
        log(f"[sweep lam={lam:.2e}] kl={m['kl']:.4f} bytes={m['bytes']}"
            f"{'  *' if dict(incumbent[1]) == trial else ''}")

    # phase (b'): explicit paired upgrade+downgrade swaps. First-order
    # additivity is roughest exactly where the sweep leans on it, so
    # directly verify the best-estimated byte-feasible pairs too.
    pairs = []
    for pu in s.paths:
        for vu, (ku, bu) in deltas[pu].items():
            if ku >= 0:
                continue                      # not a quality upgrade
            for pd in s.paths:
                if pd == pu:
                    continue
                for vd, (kd, bd) in deltas[pd].items():
                    if bd >= 0 or bu + bd > 0 or ku + kd >= 0:
                        continue              # pair infeasible on est.
                    pairs.append((ku + kd, pu, vu, pd, vd))
    pairs.sort(key=lambda t: t[0])
    for est, pu, vu, pd, vd in pairs[:swap_budget]:
        trial = dict(assignment, **{pu: vu, pd: vd})
        key = tuple(sorted(trial.items()))
        if key in proposed:
            continue
        proposed.add(key)
        m = s.evaluate(trial)
        consider(trial, m)
        log(f"[swap {pu}->{vu or 'none'} / {pd}->{vd or 'none'}] "
            f"kl={m['kl']:.4f} bytes={m['bytes']}"
            f"{'  *' if dict(incumbent[1]) == trial else ''}")

    # phase (c): greedy single-move hill-climb from the incumbent under
    # strict dominance of the seed
    for r in range(1, rounds + 1):
        _, assignment, cur = incumbent
        best = None
        for path in s.paths:
            for v in candidates:
                vv = None if v == "none" else v
                if vv == assignment[path]:
                    continue
                trial = dict(assignment, **{path: vv})
                m = s.evaluate(trial)
                consider(trial, m)
        if incumbent[2]["kl"] >= cur["kl"] - 1e-9 \
                and incumbent[2]["bytes"] >= cur["bytes"]:
            log(f"[refine {r}] no improving move; stopping")
            break
        trajectory.append(dict(round=r, kl=incumbent[2]["kl"],
                               bytes=incumbent[2]["bytes"]))
        log(f"[refine {r}] kl={incumbent[2]['kl']:.4f} "
            f"bytes={incumbent[2]['bytes']}")

    _, assignment, cur = incumbent
    log(f"[final] kl={cur['kl']:.4f} bytes={cur['bytes']} "
        f"(seed kl={kl0:.4f} bytes={bytes0})")
    policy = _exact_policy(f"auto_{arch}", assignment)
    info = dict(
        meta=dict(arch=arch, seed_policy=seed_policy,
                  calib_tokens=stats.tokens,
                  seed=dict(kl=kl0, bytes=bytes0),
                  final=dict(kl=cur["kl"], bytes=cur["bytes"],
                             pseudo_ppl=cur["pseudo_ppl"],
                             top1=cur["top1"]),
                  anchors=anchors,
                  outlier_fractions={n: stats.outlier_fraction(n)
                                     for n in stats.names()},
                  trajectory=trajectory),
        assignment={p: (v or "none") for p, v in sorted(assignment.items())},
        stats=stats)
    return policy, info


def save_searched_policy(path: str, policy: P.QuantPolicy, info: Dict):
    d = P.policy_to_dict(policy)
    d["meta"] = info["meta"]
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(d, f, indent=2, sort_keys=True)
        f.write("\n")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--out", required=True,
                    help="searched-policy JSON output path")
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--seed-policy", default="default_serve_mix")
    ap.add_argument("--candidates",
                    default=",".join(DEFAULT_CANDIDATES))
    ap.add_argument("--calib-batches", type=int, default=2)
    ap.add_argument("--calib-seq", type=int, default=64)
    ap.add_argument("--eval-seq", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs.base import get_arch
    from repro.models import transformer as T

    cfg = get_arch(args.arch, reduced=args.reduced)
    params = T.init_params(cfg, jax.random.PRNGKey(args.seed))
    policy, info = search_policy(
        cfg, params, arch=args.arch,
        candidates=tuple(args.candidates.split(",")),
        seed_policy=args.seed_policy, rounds=args.rounds,
        calib_batches=args.calib_batches, calib_seq=args.calib_seq,
        eval_seq=args.eval_seq)
    save_searched_policy(args.out, policy, info)
    meta = info["meta"]
    print(f"wrote {args.out}: kl {meta['seed']['kl']:.4f} -> "
          f"{meta['final']['kl']:.4f}, bytes {meta['seed']['bytes']} -> "
          f"{meta['final']['bytes']}")


if __name__ == "__main__":
    main()
