"""Public fused BFP-matmul entry points (jit-friendly), plus the
ring-buffer gather/restore primitives the serving engine's speculative
decode uses to snapshot and rewind KV-cache rows (``ring_gather`` /
``ring_restore``) and the page-block gather/scatter primitives the paged
KV prefix cache copies pages with (``page_gather`` / ``page_scatter``).

``impl`` selects the datapath:
  * "pallas" -- the fused Pallas TPU kernel (HBM traffic stays packed).
                Use interpret=True on CPU for validation.
  * "xla"    -- dequantize-then-dot expressed in XLA. This is the
                *framework baseline* (the analogue of the paper's NEON CPU
                path): XLA materializes the dequantized weights, so the
                memory roofline term carries the full bf16 weight traffic.
  * "auto"   -- pallas on TPU backends, xla elsewhere (dry-run lowers the
                xla path; see DESIGN.md §7).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quantize import QTensor, dequantize, quantize_q8_k
from repro.distributed import sharding as SH
from repro.kernels.bfp_matmul import bfp_matmul_pallas
from repro.kernels.q8k_quant import q8k_quantize_pallas
from repro.kernels import ref as _ref


def _default_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def bfp_matmul(x: jnp.ndarray, t: QTensor, *, impl: str = "auto",
               compute_dtype=jnp.bfloat16, out_dtype=None,
               interpret: bool = False,
               block_m: int = 128, block_n: int = 256,
               block_k: int = 512) -> jnp.ndarray:
    """x: (..., K) activation; t: packed (K, N) weights. Returns (..., N).

    Dispatches one layer's MatMul to the variant-appropriate datapath --
    the JAX analogue of the paper's per-layer 0x01-config + 0x08-schedule.
    """
    if impl == "auto":
        impl = _default_impl()
    out_dtype = out_dtype or x.dtype
    lead = x.shape[:-1]
    K = x.shape[-1]
    x2 = x.reshape(-1, K)

    if impl == "pallas":
        out = bfp_matmul_pallas(
            x2, t, compute_dtype=compute_dtype, out_dtype=out_dtype,
            interpret=interpret, block_m=block_m, block_n=block_n,
            block_k=block_k)
    elif impl == "xla":
        # dot emits compute_dtype directly: TPU MXU still accumulates fp32
        # internally, and any TP partial-sum all-reduce stays at bf16 width
        # instead of fp32 (GSPMD places the reduce before a downcast)
        w = dequantize(t, dtype=compute_dtype)
        out = jnp.dot(x2.astype(compute_dtype), w).astype(out_dtype)
    elif impl == "ref":
        out = _ref.matmul_ref(x2, t, out_dtype=out_dtype)
    else:
        raise ValueError(f"unknown impl {impl!r}")
    return out.reshape(lead + (t.shape[1],))


def tp_gather_lanes(y: jnp.ndarray) -> jnp.ndarray:
    """Assemble a tensor-parallel lane slice into the full, replicated
    output with ONE collective per projection.

    Inside a shard_map body with an active serve-TP plan, ``y`` is this
    shard's (..., N/size) lane block (head outputs before the o-proj,
    the ffn hidden before the down-proj, or a sliced-matmul output).
    Shards own disjoint contiguous blocks in axis-index order, so a
    tiled all_gather IS the assembled full output -- pure data movement,
    bit-exact by definition, and it moves 1/size the bytes of the
    equivalent zero-fill all-reduce formulation (each shard padding its
    block into a full-width zero buffer and psumming; exact too, since
    x + 0.0 == x, but full-width on the wire -- contrast a Megatron
    row-parallel psum, which reorders the K reduction and is NOT exact).
    Identity when no serve-TP plan is active, so single-device paths
    never pay."""
    plan = SH.serve_tp_plan()
    if plan is None or plan.size == 1:
        return y
    return jax.lax.all_gather(y, plan.axis, axis=y.ndim - 1, tiled=True)


def tp_embed_lanes(w):
    """Zero-embed this shard's lane slice of a weight into its full-width
    shape (the "padded" TP matmul datapath).

    The projection then runs at the SAME gemm shape as the single-device
    program -- CPU gemms round shape-dependently, so a lane-sliced dot's
    columns can differ from the full dot's by an f32 ulp, and same-shape
    is what makes TP serving bit-identical across mesh sizes BY
    CONSTRUCTION: this shard's columns see exactly the single-device
    values, off-shard columns multiply exact zeros. Works for plain
    arrays and packed QTensors alike -- zero payload lanes dequantize to
    exactly +-0.0 in every registered format (the lane-padding inertness
    property test_kernels pins), so the embedded packed tensor is
    numerically inert off-shard. The weight STORAGE stays sharded; only
    the transient compute view is full-width (the price of guaranteed
    parity -- the "sliced" datapath keeps per-shard FLOPs 1/size at
    float-rounding fidelity)."""
    plan = SH.serve_tp_plan()
    if plan is None or plan.size == 1:
        return w
    i = jax.lax.axis_index(plan.axis)

    def emb(a):
        n = a.shape[-1]
        buf = jnp.zeros(a.shape[:-1] + (n * plan.size,), a.dtype)
        return jax.lax.dynamic_update_slice_in_dim(buf, a, i * n,
                                                   a.ndim - 1)

    if isinstance(w, QTensor):
        K, n = w.shape
        return QTensor(w.variant, (K, n * plan.size),
                       {k: emb(v) for k, v in w.data.items()})
    return emb(w)


def tp_local_lanes(y: jnp.ndarray) -> jnp.ndarray:
    """This shard's lane block of a full-width activation (inverse of
    ``tp_gather_lanes``; used by the padded datapath to drop the off-shard
    zero columns a ``tp_embed_lanes`` matmul produced)."""
    plan = SH.serve_tp_plan()
    if plan is None or plan.size == 1:
        return y
    n = y.shape[-1] // plan.size
    i = jax.lax.axis_index(plan.axis)
    return jax.lax.dynamic_slice_in_dim(y, i * n, n, y.ndim - 1)


def tp_row_local_matmul(x: jnp.ndarray, t: QTensor, mode: str, *,
                        impl: str = "auto",
                        compute_dtype=jnp.bfloat16,
                        interpret: bool = False) -> jnp.ndarray:
    """This shard's partial-K product for a row-parallel o-/down-proj
    (see ServeTPPlan.attn_row). ``x`` is the shard's (..., K/size) slice
    of the projection input -- its local head outputs / ffn lanes.

    ``mode``:
      "packed"  -- ``t`` is already this shard's K-row slice (whole
        super-blocks, aux localized): dispatch the fused/XLA gemm on the
        local packed payload.
      "dequant" -- ``t`` is the full REPLICATED packed tensor (K rows
        not super-block-divisible; these are 2.6-3.6 bit tensors, so the
        replicated payload is cheap): dequantize whole and take the K
        rows matching this shard's input slice with one
        ``dynamic_slice_in_dim``. Per-shard gemm FLOPs still 1/size.

    The partial EMITS fp32 (``preferred_element_type``) so the caller's
    assembling ``psum`` runs at fp32 width and the result rounds to the
    activation dtype ONCE, after the reduce -- rounding each shard's
    partial to bf16 first would cost ~eps_bf16 * |y| per element, far
    outside the sliced datapath's documented f32-ulp envelope."""
    lead = x.shape[:-1]
    kl = x.shape[-1]
    x2 = x.reshape(-1, kl)
    if mode == "packed":
        if impl == "auto":
            impl = _default_impl()
        if impl == "pallas":
            out = bfp_matmul_pallas(
                x2, t, compute_dtype=compute_dtype,
                out_dtype=jnp.float32, interpret=interpret)
            return out.reshape(lead + (t.shape[1],))
        w = dequantize(t, dtype=compute_dtype)
    else:
        w = dequantize(t, dtype=compute_dtype)
        plan = SH.serve_tp_plan()
        if plan is not None and plan.size > 1:
            i = jax.lax.axis_index(plan.axis)
            w = jax.lax.dynamic_slice_in_dim(w, i * kl, kl, 0)
    out = jnp.dot(x2.astype(compute_dtype), w,
                  preferred_element_type=jnp.float32)
    return out.reshape(lead + (t.shape[1],))


def ring_gather(arr: jnp.ndarray, slots: jnp.ndarray, *,
                ring_axis: int) -> jnp.ndarray:
    """Gather ring-buffer rows: snapshot ``slots`` (B, S) of a per-slot ring.

    ``arr`` carries the batch dimension at ``ring_axis - 1`` and the ring
    (cache position) dimension at ``ring_axis`` -- e.g. a KV ring
    (L, B, T, KH, Dh) with ring_axis=2, or a position ring (B, T) with
    ring_axis=1. Returns ``arr`` with the ring axis replaced by S: the
    pre-write contents of the rows a speculative draft block is about to
    overwrite (the paper-side analogue is a scratch accumulator the DSBP
    can discard without a writeback)."""
    B, S = slots.shape
    idx = slots.reshape((1,) * (ring_axis - 1) + (B, S)
                        + (1,) * (arr.ndim - ring_axis - 1))
    return jnp.take_along_axis(arr, idx, axis=ring_axis)


def ring_restore(arr: jnp.ndarray, snap: jnp.ndarray, slots: jnp.ndarray,
                 keep, *, ring_axis: int) -> jnp.ndarray:
    """Cache position rewind: un-write rejected speculative entries.

    Scatters snapshot column ``j`` (taken by ``ring_gather`` from the same
    ``slots``) back into the ring for every ``j >= keep[b]``; columns
    ``j < keep[b]`` keep their freshly written (accepted) values. ``keep``
    is traced, so one compiled program serves every per-slot acceptance
    count. Rows steered out of range are dropped, mirroring the masked
    scatter convention of the prefill pipeline."""
    B, S = slots.shape
    T = arr.shape[ring_axis]
    j = jnp.arange(S, dtype=slots.dtype)[None, :]
    sel = jnp.where(j >= keep[:, None], slots, T)        # T = drop (kept)
    bidx = jnp.arange(B)[:, None]
    if ring_axis == 1:
        return arr.at[bidx, sel].set(snap, mode="drop")
    if ring_axis == 2:
        return arr.at[:, bidx, sel].set(snap, mode="drop")
    raise ValueError(f"unsupported ring_axis {ring_axis}")


def page_gather(arr: jnp.ndarray, rows: jnp.ndarray, cols: jnp.ndarray, *,
                ring_axis: int) -> jnp.ndarray:
    """Gather page-shaped row blocks out of a per-slot ring.

    ``arr`` carries the batch dimension at ``ring_axis - 1`` and the ring
    (cache position) dimension at ``ring_axis`` -- the same convention as
    ``ring_gather``. ``rows`` (n,) are batch rows, ``cols`` (n, page) the
    ring slots of each page's entries (a position ``p`` lives at slot
    ``p % T``, so a page that sits across the sliding-window wrap still
    gathers its true rows). Returns the (batch, ring) dims replaced by
    (n, page): e.g. a KV ring (L, B, T, KH, Dh) with ring_axis=2 yields
    (L, n, page, KH, Dh). Out-of-range indices clamp -- callers drop pad
    entries at the paired scatter instead."""
    if ring_axis == 1:
        return arr[rows[:, None], cols]
    if ring_axis == 2:
        return arr[:, rows[:, None], cols]
    raise ValueError(f"unsupported ring_axis {ring_axis}")


def page_scatter(arr: jnp.ndarray, pages: jnp.ndarray, rows: jnp.ndarray,
                 cols: jnp.ndarray, *, ring_axis: int) -> jnp.ndarray:
    """Scatter page-shaped row blocks into a per-slot ring (inverse of
    ``page_gather``; same layout convention).

    ``pages`` is shaped like ``page_gather``'s output. An entry of
    ``cols`` >= T drops that element (mode="drop"), which is how callers
    express batch padding AND partial pages: a prefix-cache hit that ends
    mid-page scatters only the matched leading rows and leaves the rest
    for recompute -- copy-on-write at row granularity, since the source
    page itself is never touched. Callers must steer distinct (row, col)
    destinations (the ring guarantees it for positions within one ring
    length); duplicate scatter destinations are undefined in XLA."""
    if ring_axis == 1:
        return arr.at[rows[:, None], cols].set(pages, mode="drop")
    if ring_axis == 2:
        return arr.at[:, rows[:, None], cols].set(pages, mode="drop")
    raise ValueError(f"unsupported ring_axis {ring_axis}")


def q8k_quantize(x: jnp.ndarray, *, valid: jnp.ndarray = None,
                 impl: str = "auto", interpret: bool = False):
    """Quantize activations (..., K) to Q8_K payload dict (the input
    format of the integer datapath: ``ref.matmul_q8k_ref`` / the ISA
    simulator; the fused serving kernels consume float activations).

    Leading dims flatten into the kernel's M rows, so a right-padded
    (batch, seq, K) batch quantizes in one pass. ``valid``: an optional
    boolean mask over the leading dims; masked-out rows (batch padding)
    produce all-zero payloads, keeping padding inert in any downstream
    integer dot product."""
    if impl == "auto":
        impl = _default_impl()
    lead = x.shape[:-1]
    K = x.shape[-1]
    x2 = x.reshape(-1, K)
    v2 = None if valid is None else valid.reshape(-1)
    if impl == "pallas":
        q = q8k_quantize_pallas(x2, valid=v2, interpret=interpret)
    else:
        if v2 is not None:
            x2 = jnp.where(v2[:, None], x2, 0.0)
        q = quantize_q8_k(x2)
    return {k: v.reshape(lead + v.shape[1:]) for k, v in q.items()}
