"""Fused prefill-attention Pallas kernel (flash-style online softmax).

The chunked-prefill attention pattern (``layers.prefill_attention``) is a
masked cross-attention: a (B, C) prompt chunk's queries attend the decode
ring *plus* the chunk's own keys, with validity decided purely by
POSITION arrays (absolute query positions vs. per-slot kv positions,
``-1`` marking empty slots) rather than by a dense mask. The naive path
materializes the full (C, T) score matrix per head in f32; this kernel
streams KV tiles through VMEM with the canonical online-softmax
recurrence instead, so peak memory per grid step is one (bq, bk) score
tile and the (bq, D) output accumulator -- the same output-stationary
discipline as the fused dequant-matmul kernel (K innermost,
"arbitrary"; running max/denominator in VMEM scratch).

GQA is folded in the wrapper: heads collapse onto their KV group
((B, KH) becomes the outer grid axis, the G query heads of a group ride
along the row axis), so the kernel body is a plain single-head attention
over (rows, D) x (T, D) with per-row / per-column position operands.

Numerics match ``layers.naive_attention`` to f32 rounding: scores,
softmax statistics and the value accumulation all run in f32, with one
cast back to the query dtype at the end. Rows whose every column is
masked (right-padding / empty slots) produce garbage by the same
convention as the naive path -- callers discard them.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.bfp_matmul import _CompilerParams, _round_up

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, qp_ref, kp_ref, o_ref, m_ref, l_ref, *,
            scale: float, window, softcap, nt: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        o_ref[...] = jnp.zeros_like(o_ref)

    q = q_ref[0].astype(jnp.float32)                 # (bq, D)
    k = k_ref[0].astype(jnp.float32)                 # (bk, D)
    v = v_ref[0].astype(jnp.float32)                 # (bk, D)
    qp = qp_ref[0]                                   # (bq,) int32
    kp = kp_ref[0]                                   # (bk,) int32

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    msk = (kp[None, :] >= 0) & (kp[None, :] <= qp[:, None])
    if window:
        msk &= kp[None, :] > qp[:, None] - window
    s = jnp.where(msk, s, NEG_INF)

    m_prev = m_ref[...]                              # (bq, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)                           # (bq, bk)
    corr = jnp.exp(m_prev - m_new)                   # (bq, 1)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
    o_ref[0] = o_ref[0] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == nt - 1)
    def _finish():
        o_ref[0] = o_ref[0] / jnp.maximum(l_ref[...], 1e-30)


def prefill_attn_fused(q, k, v, q_pos, kv_pos, *, window=None, scale=None,
                       softcap=None, block_q: int = 128,
                       block_k: int = 256,
                       interpret: bool = False) -> jnp.ndarray:
    """q: (B,C,H,D); k/v: (B,T,KH,D); q_pos: (B,C); kv_pos: (B,T).

    Returns (B,C,H,D) in q.dtype: causal position-masked attention
    identical (to f32 rounding) to ``layers.naive_attention`` with the
    same position operands. kv_pos == -1 marks empty slots; right-padded
    query rows (q_pos past the prompt) yield garbage the caller ignores,
    same convention as the naive path."""
    B, C, H, D = q.shape
    T, KH = k.shape[1], k.shape[2]
    G = H // KH
    scale = scale or (1.0 / math.sqrt(D))

    # fold GQA: (B,C,H,D) -> (B*KH, C*G, D); row r <-> (c = r // G, g)
    qg = q.reshape(B, C, KH, G, D).transpose(0, 2, 1, 3, 4)
    qg = qg.reshape(B * KH, C * G, D)
    k2 = k.transpose(0, 2, 1, 3).reshape(B * KH, T, D)
    v2 = v.transpose(0, 2, 1, 3).reshape(B * KH, T, D)
    qp = jnp.repeat(q_pos.astype(jnp.int32), G, axis=1)       # (B, C*G)
    qp = jnp.repeat(qp[:, None], KH, axis=1).reshape(B * KH, C * G)
    kp = jnp.repeat(kv_pos.astype(jnp.int32)[:, None], KH,
                    axis=1).reshape(B * KH, T)

    M = C * G
    bq = min(block_q, _round_up(M, 8))
    bk = min(block_k, _round_up(T, 128))
    Mp, Tp = _round_up(M, bq), _round_up(T, bk)
    if Mp != M:
        qg = jnp.pad(qg, ((0, 0), (0, Mp - M), (0, 0)))
        qp = jnp.pad(qp, ((0, 0), (0, Mp - M)), constant_values=-1)
    if Tp != T:
        k2 = jnp.pad(k2, ((0, 0), (0, Tp - T), (0, 0)))
        v2 = jnp.pad(v2, ((0, 0), (0, Tp - T), (0, 0)))
        kp = jnp.pad(kp, ((0, 0), (0, Tp - T)), constant_values=-1)

    grid = (B * KH, Mp // bq, Tp // bk)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, window=window,
                          softcap=softcap, nt=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),
            pl.BlockSpec((1, bk), lambda b, i, j: (b, j)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KH, Mp, D), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qg, k2, v2, qp, kp)

    out = out[:, :M].reshape(B, KH, C, G, D).transpose(0, 2, 1, 3, 4)
    return out.reshape(B, C, H, D).astype(q.dtype)
