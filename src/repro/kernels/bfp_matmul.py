"""Fused BFP dequant-matmul Pallas TPU kernel -- the DSBP, TPU-native.

The paper's Dynamic Super-Block Processor streams packed super-blocks from
main memory through a bit-slicer/data-mapper into BRAM caches, runs a shared
integer vector engine, and applies variant-specific scaling. On TPU the same
dataflow becomes:

  HBM (packed SoA arrays)  --BlockSpec DMA-->  VMEM tiles
  bit-slicer/data-mapper    = vectorized shift/mask slab unpack (VPU)
  shared vector engine      = MXU ``jnp.dot`` with fp32 accumulation
  Q2/Q3 scalar units + mux  = variant-specific two-level scale fold,
                              selected statically per layer (one compiled
                              program holds both variants; switching per
                              layer needs no reconfiguration)

Output-stationary tiling (paper §III-C): grid (M/bm, N/bn, K/bk) with the
K dimension innermost/"arbitrary"; the output tile stays resident in VMEM
across the K sweep, exactly like the paper's accumulator register file.

HBM traffic per output tile is the *packed* operand bytes -- the entire
point of BFP quantization (2.625-3.5625 bits/weight instead of 16).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.formats import get_format
from repro.core.quantize import QTensor, dequantize

# jax renamed TPUCompilerParams -> CompilerParams across 0.4.x releases;
# accept whichever this install provides
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))
if _CompilerParams is None:
    raise ImportError(
        "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
        "TPUCompilerParams; unsupported jax version")


def _choose_block_k(K: int, sb: int, target: int = 512) -> int:
    """Largest bk <= target with bk % sb == 0 and K % bk == 0.

    Falls back to bk = sb when no super-block-aligned divisor of K exists
    at or below the target (e.g. K = 1792 with target 384): K is always a
    super-block multiple for packed tensors, so sb itself always tiles --
    a smaller-than-asked tile, never an error. A target below sb gets the
    same fallback."""
    if K % sb:
        raise ValueError(f"K={K} is not a multiple of super-block {sb}; "
                         "not a packable shape")
    if K <= target:
        return K
    bk = target - target % sb
    while bk >= sb:
        if K % bk == 0:
            return bk
        bk -= sb
    return sb


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _kernel(x_ref, *rest, variant: str, names: Tuple[str, ...],
            block_shape: Tuple[int, int], nk: int, compute_dtype):
    """rest = (*weight_refs, out_ref)."""
    w_refs, o_ref = rest[:-1], rest[-1]
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # "bit-slicer + data mapper": unpack/dequantize this VMEM tile.
    data = {name: ref[...] for name, ref in zip(names, w_refs)}
    qt = QTensor(variant, block_shape, data)
    w = dequantize(qt, dtype=compute_dtype)          # (bk, bn)
    x = x_ref[...].astype(compute_dtype)             # (bm, bk)
    o_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)


def bfp_matmul_pallas(x: jnp.ndarray, t: QTensor, *,
                      block_m: int = 128, block_n: int = 256,
                      block_k: int = 512,
                      compute_dtype=jnp.bfloat16,
                      out_dtype=None,
                      interpret: bool = False) -> jnp.ndarray:
    """x: (M, K) float; t: packed (K, N). Returns (M, N) in ``out_dtype``.

    M / N are padded to block multiples inside (packed arrays pad with
    zeros along lanes => zero weights, numerically inert).
    """
    M, K = x.shape
    Kt, N = t.shape
    assert K == Kt, (K, Kt)
    fmt = get_format(t.variant)
    for name, arr in t.data.items():
        # lane (last-axis) width must match the logical N: a QTensor whose
        # payloads were lane-sharded (serving TP slices lanes per shard;
        # K rows stay whole) but whose static aux shape still claims the
        # global N would otherwise fail deep inside the unpack reshapes --
        # shard_map callers must relocalize via
        # distributed.sharding.localize_serve_params first
        if arr.shape[-1] != N:
            raise ValueError(
                f"QTensor({t.variant}) payload {name!r} carries "
                f"{arr.shape[-1]} lanes but aux shape says N={N}; "
                "lane-sharded payloads need localize_serve_params")
    out_dtype = out_dtype or x.dtype

    bk = _choose_block_k(K, fmt.super_block, block_k)
    bm = min(block_m, _round_up(M, 8))
    bn = min(block_n, _round_up(N, 128))
    Mp, Np = _round_up(M, bm), _round_up(N, bn)

    if Mp != M:
        x = jnp.pad(x, ((0, Mp - M), (0, 0)))
    data = dict(t.data)
    if Np != N:
        data = {k2: jnp.pad(v, ((0, 0), (0, Np - N))) for k2, v in data.items()}

    names = tuple(sorted(data))
    kdiv = {a.name: a.k_div for a in fmt.arrays}
    grid = (Mp // bm, Np // bn, K // bk)

    in_specs = [pl.BlockSpec((bm, bk), lambda i, j, k: (i, k))]
    for name in names:
        dv = kdiv[name]
        in_specs.append(
            pl.BlockSpec((bk // dv, bn),
                         functools.partial(lambda i, j, k, _dv: (k, j), _dv=dv)))

    out = pl.pallas_call(
        functools.partial(_kernel, variant=t.variant, names=names,
                          block_shape=(bk, bn), nk=grid[2],
                          compute_dtype=compute_dtype),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, *[data[n] for n in names])
    return out[:M, :N].astype(out_dtype)


def vmem_bytes(variant: str, block_m: int, block_n: int, block_k: int,
               x_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16) -> dict:
    """Static VMEM budget of one grid step (Table II analogue)."""
    fmt = get_format(variant)
    w_packed = sum((block_k // a.k_div) * block_n * jnp.dtype(a.dtype).itemsize
                   for a in fmt.arrays)
    return dict(
        x_tile=block_m * block_k * jnp.dtype(x_dtype).itemsize,
        w_packed_tile=w_packed,
        w_dequant_tile=block_k * block_n * jnp.dtype(compute_dtype).itemsize,
        acc_tile=block_m * block_n * 4,
        total=(block_m * block_k * jnp.dtype(x_dtype).itemsize + w_packed
               + block_k * block_n * jnp.dtype(compute_dtype).itemsize
               + block_m * block_n * 4),
    )
