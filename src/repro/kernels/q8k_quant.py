"""Pallas kernel: quantize activations to Q8_K (per-256 super-block int8).

The paper's driver quantizes input tensors to Q8_K before streaming them to
the accelerator (llama.cpp does the same on CPU). On TPU this is a cheap
VPU pass: per 256-value super-block, absmax -> scale -> round, plus the
16-block partial sums ("bsums") that the Q2_K min-correction term consumes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, qs_ref, d_ref, bs_ref):
    x = x_ref[...].astype(jnp.float32)              # (bm, K)
    bm, K = x.shape
    nsb = K // 256
    xs = x.reshape(bm, nsb, 256)
    amax = jnp.abs(xs).max(axis=-1)                  # (bm, nsb)
    d = amax / 127.0
    inv = jnp.where(d > 0, 1.0 / jnp.where(d > 0, d, 1.0), 0.0)
    q = jnp.clip(jnp.round(xs * inv[..., None]), -127, 127)
    qi = q.astype(jnp.int32)
    bsums = qi.reshape(bm, nsb, 16, 16).sum(axis=-1)
    qs_ref[...] = qi.reshape(bm, K).astype(jnp.int8)
    d_ref[...] = d
    bs_ref[...] = bsums.reshape(bm, K // 16).astype(jnp.int16)


def q8k_quantize_pallas(x: jnp.ndarray, *, block_m: int = 8,
                        interpret: bool = False):
    """x: (M, K), K % 256 == 0 -> dict(qs int8 (M,K), d f32 (M,K/256),
    bsums int16 (M,K/16))."""
    M, K = x.shape
    assert K % 256 == 0, K
    bm = min(block_m, M)
    Mp = (M + bm - 1) // bm * bm
    if Mp != M:
        x = jnp.pad(x, ((0, Mp - M), (0, 0)))
    grid = (Mp // bm,)
    qs, d, bs = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, K), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bm, K), lambda i: (i, 0)),
            pl.BlockSpec((bm, K // 256), lambda i: (i, 0)),
            pl.BlockSpec((bm, K // 16), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Mp, K), jnp.int8),
            jax.ShapeDtypeStruct((Mp, K // 256), jnp.float32),
            jax.ShapeDtypeStruct((Mp, K // 16), jnp.int16),
        ],
        interpret=interpret,
    )(x)
    return dict(qs=qs[:M], d=d[:M], bsums=bs[:M])
