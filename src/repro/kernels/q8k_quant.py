"""Pallas kernel: quantize activations to Q8_K (per-256 super-block int8).

The paper's driver quantizes input tensors to Q8_K before streaming them to
the accelerator (llama.cpp does the same on CPU). On TPU this is a cheap
VPU pass: per 256-value super-block, absmax -> scale -> round, plus the
16-block partial sums ("bsums") that the Q2_K min-correction term consumes.

Batched callers of the *integer* (Q8_K) datapath -- ``ref.matmul_q8k_ref``
and the ISA simulator; the fused serving kernels take float activations
directly -- hand this kernel a right-padded (G, P, K) batch flattened to
M = G*P rows, where trailing rows of each request are padding. The
optional ``valid`` row mask zeroes those rows' payloads (qs/d/bsums all
exactly 0) inside the kernel, so the integer dot products see inert
padding without a separate masking pass over the (M, K) activations.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, valid_ref, qs_ref, d_ref, bs_ref):
    x = x_ref[...].astype(jnp.float32)              # (bm, K)
    if valid_ref is not None:
        x = x * valid_ref[...].astype(jnp.float32)  # (bm, 1) row mask
    bm, K = x.shape
    nsb = K // 256
    xs = x.reshape(bm, nsb, 256)
    amax = jnp.abs(xs).max(axis=-1)                  # (bm, nsb)
    d = amax / 127.0
    inv = jnp.where(d > 0, 1.0 / jnp.where(d > 0, d, 1.0), 0.0)
    q = jnp.clip(jnp.round(xs * inv[..., None]), -127, 127)
    qi = q.astype(jnp.int32)
    bsums = qi.reshape(bm, nsb, 16, 16).sum(axis=-1)
    qs_ref[...] = qi.reshape(bm, K).astype(jnp.int8)
    d_ref[...] = d
    bs_ref[...] = bsums.reshape(bm, K // 16).astype(jnp.int16)


def q8k_quantize_pallas(x: jnp.ndarray, *, valid: jnp.ndarray = None,
                        block_m: int = 8, interpret: bool = False):
    """x: (M, K), K % 256 == 0 -> dict(qs int8 (M,K), d f32 (M,K/256),
    bsums int16 (M,K/16)). ``valid``: optional (M,) bool/0-1 row mask --
    False rows (batch padding) quantize to all-zero payloads."""
    M, K = x.shape
    assert K % 256 == 0, K
    bm = min(block_m, M)
    Mp = (M + bm - 1) // bm * bm
    if Mp != M:
        x = jnp.pad(x, ((0, Mp - M), (0, 0)))
    grid = (Mp // bm,)
    in_specs = [pl.BlockSpec((bm, K), lambda i: (i, 0))]
    args = [x]
    if valid is not None:
        v2 = jnp.asarray(valid).astype(jnp.float32).reshape(M, 1)
        if Mp != M:
            v2 = jnp.pad(v2, ((0, Mp - M), (0, 0)))
        in_specs.append(pl.BlockSpec((bm, 1), lambda i: (i, 0)))
        args.append(v2)
        kernel = _kernel
    else:
        def kernel(x_ref, qs_ref, d_ref, bs_ref):
            _kernel(x_ref, None, qs_ref, d_ref, bs_ref)

    qs, d, bs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((bm, K), lambda i: (i, 0)),
            pl.BlockSpec((bm, K // 256), lambda i: (i, 0)),
            pl.BlockSpec((bm, K // 16), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Mp, K), jnp.int8),
            jax.ShapeDtypeStruct((Mp, K // 256), jnp.float32),
            jax.ShapeDtypeStruct((Mp, K // 16), jnp.int16),
        ],
        interpret=interpret,
    )(*args)
    return dict(qs=qs[:M], d=d[:M], bsums=bs[:M])
