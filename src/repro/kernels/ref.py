"""Pure-jnp oracles for the BFP matmul kernels.

Two reference semantics:

  * ``matmul_ref``      -- dequantize-to-f32 then matmul. This is the golden
    numerical reference for the fused Pallas kernel (which dequantizes
    per-VMEM-tile and feeds the MXU).
  * ``matmul_q8k_ref``  -- llama.cpp ``vec_dot_qX_K_q8_K`` semantics: integer
    dot products per 16-block with two-level rescaling, activations in Q8_K.
    This is the bit-faithful model of the paper's DSBP datapath (shared
    integer vector engine + Q2/Q3 scalar units + accumulator).
"""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp

from repro.core.formats import slab_unpack
from repro.core.quantize import QTensor, dequantize


def matmul_ref(x: jnp.ndarray, t: QTensor, out_dtype=jnp.float32) -> jnp.ndarray:
    """x: (..., K) float; t: packed (K, N). Returns (..., N)."""
    w = dequantize(t, dtype=jnp.float32)
    return jnp.dot(x.astype(jnp.float32), w).astype(out_dtype)


# ---------------------------------------------------------------------------
# integer-datapath reference (llama.cpp vec_dot semantics)
# ---------------------------------------------------------------------------

def _q8_fields(qx: Dict[str, jnp.ndarray]):
    qs = qx["qs"].astype(jnp.int32)          # (..., K)
    d8 = qx["d"].astype(jnp.float32)         # (..., K//256)
    bsums = qx["bsums"].astype(jnp.int32)    # (..., K//16)
    return qs, d8, bsums


def matmul_q8k_ref(qx: Dict[str, jnp.ndarray], t: QTensor,
                   out_dtype=jnp.float32) -> jnp.ndarray:
    """Integer-accumulation reference. qx: Q8_K activation dict over (M, K)."""
    K, N = t.shape
    nsb = K // 256
    qs, d8, bsums = _q8_fields(qx)
    M = qs.shape[0]
    x_blk = qs.reshape(M, nsb, 16, 16)                       # int32

    if t.variant == "q2_k":
        q = slab_unpack(t.data["qs"], 2, 256).astype(jnp.int32)
        q = q.reshape(nsb, 16, 16, N)
        sc = (t.data["scales"] & 0xF).astype(jnp.int32).reshape(nsb, 16, N)
        mn = (t.data["scales"] >> 4).astype(jnp.int32).reshape(nsb, 16, N)
        d = t.data["d"].astype(jnp.float32)                  # (nsb, N)
        dmin = t.data["dmin"].astype(jnp.float32)
        # int dot per 16-block: (M, nsb, 16blk, N)
        idot = jnp.einsum("msbi,sbin->msbn", x_blk, q).astype(jnp.float32)
        scaled = jnp.einsum("msbn,sbn->msn", idot, sc.astype(jnp.float32))
        # min correction uses the Q8 block sums (the paper's bsum trick)
        bs = bsums.reshape(M, nsb, 16).astype(jnp.float32)
        mins = jnp.einsum("msb,sbn->msn", bs, mn.astype(jnp.float32))
        acc = (scaled * d[None] - mins * dmin[None]) * d8[:, :, None]
        return acc.sum(axis=1).astype(out_dtype)

    if t.variant == "q3_k":
        lo = slab_unpack(t.data["qs"], 2, 256).astype(jnp.int32)
        hi = slab_unpack(t.data["hmask"], 1, 256).astype(jnp.int32)
        q = (lo + (hi << 2) - 4).reshape(nsb, 16, 16, N)     # [-4, 3]
        sc = t.data["scales"].astype(jnp.int32).reshape(nsb, 16, N) - 32
        d = t.data["d"].astype(jnp.float32)
        idot = jnp.einsum("msbi,sbin->msbn", x_blk, q).astype(jnp.float32)
        scaled = jnp.einsum("msbn,sbn->msn", idot, sc.astype(jnp.float32))
        acc = scaled * d[None] * d8[:, :, None]
        return acc.sum(axis=1).astype(out_dtype)

    raise NotImplementedError(
        f"integer reference only models the paper's native variants "
        f"(q2_k, q3_k); got {t.variant}")


def dequant_ref(t: QTensor, dtype=jnp.float32) -> jnp.ndarray:
    return dequantize(t, dtype=dtype)
