"""Fault-tolerant training loop: checkpoint/restart, straggler watchdog.

The loop is preemption-safe end to end:
  * auto-resume from the latest valid checkpoint (atomic MANIFEST check)
  * async checkpoint every ``ckpt_every`` steps + final sync save
  * data batches are pure functions of step -> restart is bit-identical
  * SIGTERM triggers a synchronous save before exit (cluster preemption)
  * step-time watchdog tracks p50/p99 and flags stragglers (steps slower
    than ``straggler_factor`` x p50); on a real pod this feeds the
    skip-and-rebalance hook (here: logged)
"""
from __future__ import annotations

import signal
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint.ckpt import Checkpointer
from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, DataPipeline
from repro.optim.adamw import AdamWConfig
from repro.training import steps as S


class Watchdog:
    def __init__(self, straggler_factor: float = 2.0):
        self.times: List[float] = []
        self.factor = straggler_factor
        self.stragglers = 0

    def record(self, dt: float) -> bool:
        self.times.append(dt)
        if len(self.times) >= 8:
            p50 = float(np.percentile(self.times[-100:], 50))
            if dt > self.factor * p50:
                self.stragglers += 1
                return True
        return False

    def summary(self) -> Dict[str, float]:
        if not self.times:
            return {}
        t = np.asarray(self.times[1:] or self.times)   # drop compile step
        return dict(p50=float(np.percentile(t, 50)),
                    p99=float(np.percentile(t, 99)),
                    mean=float(t.mean()), stragglers=self.stragglers)


def run_training(cfg: ModelConfig, *, steps: int, global_batch: int,
                 seq_len: int, ckpt_dir: Optional[str] = None,
                 ckpt_every: int = 50, microbatches: int = 1,
                 opt: Optional[AdamWConfig] = None, seed: int = 0,
                 log_every: int = 10,
                 log_fn: Callable[[str], None] = print) -> Dict[str, Any]:
    opt = opt or AdamWConfig(total_steps=steps)
    data = DataPipeline(DataConfig(vocab_size=cfg.vocab_size,
                                   seq_len=seq_len,
                                   global_batch=global_batch, seed=seed))
    train_step = jax.jit(S.make_train_step(cfg, opt,
                                           microbatches=microbatches),
                         donate_argnums=(0,))

    ckpt = Checkpointer(ckpt_dir) if ckpt_dir else None
    start_step = 0
    state = None
    if ckpt:
        latest, restored = ckpt.restore_latest()
        if latest is not None:
            state = restored
            start_step = latest
            log_fn(f"[resume] restored checkpoint step={latest}")
    if state is None:
        state = S.init_train_state(cfg, opt, jax.random.PRNGKey(seed))

    # preemption hook: save synchronously on SIGTERM
    preempted = {"flag": False}

    def _on_term(signum, frame):
        preempted["flag"] = True
    old = signal.signal(signal.SIGTERM, _on_term)

    wd = Watchdog()
    losses: List[float] = []
    try:
        for step in range(start_step, steps):
            t0 = time.perf_counter()
            batch = {k: jax.numpy.asarray(v)
                     for k, v in data.batch(step).items()}
            state, metrics = train_step(state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            dt = time.perf_counter() - t0
            if wd.record(dt):
                log_fn(f"[watchdog] straggler step {step}: {dt:.3f}s")
            if step % log_every == 0:
                log_fn(f"step {step:5d} loss {loss:.4f} "
                       f"gnorm {float(metrics['grad_norm']):.3f} "
                       f"lr {float(metrics['lr']):.2e} ({dt:.3f}s)")
            if ckpt and ((step + 1) % ckpt_every == 0):
                ckpt.save_async(step + 1, state)
            if preempted["flag"]:
                log_fn(f"[preempt] SIGTERM at step {step}; saving + exiting")
                if ckpt:
                    ckpt.save(step + 1, state)
                break
    finally:
        signal.signal(signal.SIGTERM, old)
        if ckpt:
            ckpt.wait()
    if ckpt and not preempted["flag"]:
        ckpt.save(min(steps, len(losses) + start_step), state)
    return dict(state=state, losses=losses, timing=wd.summary(),
                preempted=preempted["flag"])
