"""Loss + train/serve step functions (the units the launcher jits/lowers).

``make_train_step`` builds a donated, microbatch-accumulating train step:
  * params master fp32, compute cast bf16 (mixed precision)
  * optional gradient accumulation via lax.scan over microbatches,
    accumulated in ``accum_dtype`` (bf16 halves accumulation HBM -- a
    gradient-compression knob; cross-replica reduction precision is
    XLA-controlled, see distributed/compress.py for the explicit path)
  * remat is a model-config knob (scan-over-layers + jax.checkpoint)

``make_serve_steps`` builds prefill/decode against (optionally quantized)
serve params -- decode with BFP weights is the paper's deployment shape.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.optim import adamw


def cast_params(params, dtype):
    def c(x):
        return x.astype(dtype) if (hasattr(x, "dtype")
                                   and jnp.issubdtype(x.dtype, jnp.floating)
                                   and x.ndim >= 2) else x
    return jax.tree.map(c, params)


def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray,
                 z_loss: float = 1e-4) -> jnp.ndarray:
    """logits (..., V) f32, labels (...) int32. Mean token loss + z-loss.

    Uses one-hot contraction (not take_along_axis) so a vocab-sharded
    logits tensor reduces with a tiny all-reduce instead of an all-gather.
    """
    lse = jax.nn.logsumexp(logits, axis=-1)
    oh = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    ll = jnp.sum(logits * oh, axis=-1)
    loss = (lse - ll).mean()
    if z_loss:
        loss = loss + z_loss * (lse ** 2).mean()
    return loss


def chunked_xent(h: jnp.ndarray, head, labels: jnp.ndarray, *,
                 tie_wte=None, chunk: int = 2048,
                 z_loss: float = 1e-4) -> jnp.ndarray:
    """Cross entropy from hidden states, chunked over tokens.

    Never materializes the full (B, S, V) fp32 logits: each chunk of
    ``chunk`` tokens computes its own head matmul + lse (rematerialized in
    the backward pass). This is the standard memory/collective fix for
    large-vocab training -- see EXPERIMENTS.md §Perf.
    """
    B, S, d = h.shape
    hf = h.reshape(B * S, d)
    lf = labels.reshape(B * S)
    n = B * S
    c = min(chunk, n)
    if n % c:
        pad = c - n % c
        hf = jnp.pad(hf, ((0, pad), (0, 0)))
        lf = jnp.concatenate([lf, jnp.full((pad,), -1, lf.dtype)])
        n = n + pad
    hf = hf.reshape(n // c, c, d)
    lf = lf.reshape(n // c, c)

    @jax.checkpoint
    def body(acc, xs):
        hc, lc = xs
        if tie_wte is not None:
            logits = jnp.einsum("td,vd->tv", hc.astype(jnp.float32),
                                tie_wte.astype(jnp.float32))
        else:
            logits = jnp.dot(hc, head.astype(hc.dtype)).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        oh = jax.nn.one_hot(lc, logits.shape[-1], dtype=logits.dtype)
        ll = jnp.sum(logits * oh, axis=-1)
        valid = (lc >= 0).astype(jnp.float32)
        loss_sum = jnp.sum((lse - ll) * valid)
        z_sum = jnp.sum((lse ** 2) * valid)
        nvalid = valid.sum()
        return (acc[0] + loss_sum, acc[1] + z_sum, acc[2] + nvalid), None

    (loss_sum, z_sum, nvalid), _ = jax.lax.scan(
        body, (jnp.zeros(()), jnp.zeros(()), jnp.zeros(())), (hf, lf))
    return loss_sum / nvalid + z_loss * z_sum / nvalid


def make_loss_fn(cfg: ModelConfig, aux_weight: float = 1e-2):
    use_chunked = cfg.loss_chunk and cfg.vocab_size >= 8192

    def loss_fn(params, batch):
        compute = cast_params(params, jnp.dtype(cfg.dtype))
        if use_chunked:
            h, aux, _ = T.forward_seq(
                compute, cfg, return_hidden=True,
                tokens=batch.get("tokens"), embeds=batch.get("embeds"),
                positions=batch.get("positions"))
            tie = compute["wte"] if cfg.tie_embeddings else None
            head = None if cfg.tie_embeddings else compute["lm_head"]
            loss = chunked_xent(h, head, batch["labels"], tie_wte=tie,
                                chunk=cfg.loss_chunk)
        else:
            logits, aux, _ = T.forward_seq(
                compute, cfg,
                tokens=batch.get("tokens"), embeds=batch.get("embeds"),
                positions=batch.get("positions"))
            loss = softmax_xent(logits, batch["labels"])
        loss = loss + aux_weight * aux
        return loss, dict(loss=loss, aux=aux)
    return loss_fn


def make_train_step(cfg: ModelConfig, opt: adamw.AdamWConfig,
                    microbatches: int = 1, accum_dtype=jnp.float32):
    loss_fn = make_loss_fn(cfg)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: Dict[str, Any], batch: Dict[str, Any]):
        params = state["params"]
        if microbatches <= 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def micro(accum, mb):
                (l, m), g = grad_fn(params, mb)
                accum = jax.tree.map(
                    lambda a, b: a + b.astype(accum_dtype), accum, g)
                return accum, (l, m["aux"])

            def split(x):
                B = x.shape[0]
                mb = B // microbatches
                return x.reshape((microbatches, mb) + x.shape[1:])
            # M-RoPE positions carry a leading (3,) dim: split on batch dim
            mbs = {}
            for k, v in batch.items():
                if k == "positions" and v.ndim == 3:
                    mbs[k] = jnp.moveaxis(split(jnp.moveaxis(v, 0, 1)), 2, 1)
                else:
                    mbs[k] = split(v)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params)
            grads, (losses, auxes) = jax.lax.scan(
                micro, zeros, mbs, unroll=True if cfg.scan_unroll else 1)
            grads = jax.tree.map(lambda g: (g / microbatches).astype(
                jnp.float32), grads)
            loss = losses.mean()
            metrics = dict(loss=loss, aux=auxes.mean())
        new_params, new_opt, om = adamw.apply_updates(
            opt, params, grads, state["opt"])
        metrics.update(om)
        return dict(params=new_params, opt=new_opt,
                    step=state["step"] + 1), metrics

    return train_step


def make_serve_steps(cfg: ModelConfig):
    def prefill(params, batch):
        # logits for the LAST position only: never materializes the
        # (B, S, V) tensor (it would dominate prefill memory+collectives)
        h, _, caches = T.forward_seq(
            params, cfg, tokens=batch.get("tokens"),
            embeds=batch.get("embeds"), positions=batch.get("positions"),
            want_cache=True, return_hidden=True)
        logits = T._logits(params, cfg, h[:, -1])
        return logits, caches

    def decode(params, cache, batch):
        return T.decode_step(params, cfg, cache,
                             tokens=batch.get("tokens"),
                             embeds=batch.get("embeds"),
                             position=batch["position"])

    return prefill, decode


def init_train_state(cfg: ModelConfig, opt: adamw.AdamWConfig, key):
    params = T.init_params(cfg, key, dtype=jnp.float32)
    return dict(params=params, opt=adamw.init_state(opt, params),
                step=jnp.zeros((), jnp.int32))
